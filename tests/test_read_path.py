"""The read/verify pipeline (ISSUE 2).

Covers the acceptance criteria: tpu-verified reads are bit-identical to
cpu-verified reads across ca modes, replica failover still verifies,
corrupted blocks raise IOError on both sync and pipelined reads, a burst
of reads coalesces verify requests into fewer fused launches, a verified
read of an n-block file issues at most ceil(n / max_batch) engine
launches with zero per-block host hashlib calls on the tpu path, and
short CDC inputs (len < window) fall back to one whole-buffer chunk.
"""
import numpy as np
import pytest

from repro.core import CrystalTPU, SAI, SAIConfig, make_store


def _cfg(ca="fixed", hasher="tpu", **kw):
    return SAIConfig(ca=ca, hasher=hasher, block_size=4096, avg_chunk=4096,
                     min_chunk=1024, max_chunk=16384, **kw)


@pytest.mark.parametrize("ca", ["fixed", "cdc", "cdc-gear", "none"])
def test_tpu_read_bit_identical_to_cpu_read(rng, ca):
    """One store, two readers: engine-verified and hashlib-verified reads
    return identical bytes for every ca mode."""
    mgr, _ = make_store(4)
    data = rng.integers(0, 256, 20_000, dtype=np.uint8).tobytes()
    SAI(mgr, _cfg(ca=ca, hasher="cpu")).write("/f", data)
    eng = CrystalTPU()
    try:
        got_tpu = SAI(mgr, _cfg(ca=ca, hasher="tpu"),
                      crystal=eng).read("/f")
        got_cpu = SAI(mgr, _cfg(ca=ca, hasher="cpu")).read("/f")
        assert got_tpu == got_cpu == data
    finally:
        eng.shutdown()


def test_replica_failover_still_verifies(rng):
    mgr, nodes = make_store(4, replication=2)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        data = rng.integers(0, 256, 30_000, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        nodes[0].fail()
        assert sai.read("/f") == data
        assert sai.read_async("/f").result(timeout=120) == data
    finally:
        sai.close()
        eng.shutdown()


def test_corrupted_block_raises_ioerror(rng):
    mgr, nodes = make_store(4)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        data = rng.integers(0, 256, 30_000, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        digest = next(iter(mgr.block_registry))
        for n in nodes:
            if digest in n.blocks:
                n.blocks[digest] = bytes(len(n.blocks[digest]))
        with pytest.raises(IOError):
            sai.read("/f")
        with pytest.raises(IOError):
            sai.read_async("/f").result(timeout=120)
        # unverified read still assembles the (corrupt) bytes
        assert len(sai.read("/f", verify=False)) == len(data)
    finally:
        sai.close()
        eng.shutdown()


def test_read_burst_coalesces_verify_requests(rng):
    """A burst of >= 4 pipelined reads fuses their verify hash requests:
    launches stay below submitted jobs (acceptance criterion)."""
    mgr, _ = make_store(4)
    eng = CrystalTPU(coalesce_window_s=0.2)
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        datas = [rng.integers(0, 256, 20_000, dtype=np.uint8).tobytes()
                 for _ in range(6)]
        for i, d in enumerate(datas):
            sai.write(f"/f{i}", d)
        sai.read("/f0")                       # warm the verify shapes
        s0 = eng.snapshot_stats()
        futs = [sai.read_async(f"/f{i}") for i in range(6)]
        got = [f.result(timeout=120) for f in futs]
        assert got == datas
        s1 = eng.snapshot_stats()
        jobs = s1["jobs"] - s0["jobs"]
        launches = s1["launches"] - s0["launches"]
        assert jobs >= 6
        assert launches < jobs, (launches, jobs)
    finally:
        sai.close()
        eng.shutdown()


def test_read_single_fused_launch_no_host_hashlib(rng, monkeypatch):
    """A verified read of an n-block file is ONE fused engine request —
    at most ceil(n / max_batch) launches and zero per-block host hashlib
    calls on the tpu path."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        data = rng.integers(0, 256, 16 * 4096, dtype=np.uint8).tobytes()
        sai.write("/f", data)                 # 16 blocks
        sai.read("/f")                        # warm shapes
        import repro.core.sai as sai_mod

        def _boom(_):
            raise AssertionError("host hashlib call on the tpu read path")

        monkeypatch.setattr(sai_mod, "block_digest_cpu", _boom)
        s0 = eng.snapshot_stats()
        assert sai.read("/f") == data
        s1 = eng.snapshot_stats()
        n_blocks = 16
        max_launches = -(-n_blocks // eng.max_batch)    # ceil
        assert s1["launches"] - s0["launches"] <= max_launches
        assert s1["jobs"] - s0["jobs"] == 1
    finally:
        sai.close()
        eng.shutdown()


@pytest.mark.parametrize("hasher", ["cpu", "tpu"])
def test_short_cdc_input_single_chunk(hasher):
    """len(data) < window: the sliding pass returns an empty hash array
    and boundary selection falls back to one whole-buffer chunk."""
    mgr, _ = make_store(4)
    eng = CrystalTPU() if hasher == "tpu" else None
    sai = SAI(mgr, _cfg(ca="cdc", hasher=hasher), crystal=eng)
    try:
        data = b"short-input!"                # 12 bytes < window 48
        st = sai.write("/tiny", data)
        assert st.new_blocks == 1
        assert sai.read("/tiny") == data
    finally:
        sai.close()
        if eng is not None:
            eng.shutdown()


def test_read_async_missing_file_fails():
    mgr, _ = make_store(4)
    sai = SAI(mgr, _cfg(hasher="cpu"))
    try:
        with pytest.raises(FileNotFoundError):
            sai.read_async("/nope").result(timeout=120)
    finally:
        sai.close()


def test_checkpoint_restore_pipelined(rng):
    """Restore reads every leaf through read_async; verify requests from
    successive leaves coalesce and the state round-trips exactly."""
    from repro.train.checkpoint import CACheckpointer
    mgr, _ = make_store(4)
    eng = CrystalTPU(coalesce_window_s=0.05)
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        params = {f"layer{i}": rng.standard_normal(2000).astype(np.float32)
                  for i in range(6)}
        ckpt = CACheckpointer(sai)
        ckpt.save(3, params)
        s0 = eng.snapshot_stats()
        step, state, _ = ckpt.restore()
        s1 = eng.snapshot_stats()
        assert step == 3
        for k, v in params.items():
            np.testing.assert_array_equal(state["params"][k], v)
        delta_jobs = s1["jobs"] - s0["jobs"]
        delta_launches = s1["launches"] - s0["launches"]
        assert delta_jobs >= len(params)
        assert delta_launches < delta_jobs, (delta_launches, delta_jobs)
    finally:
        sai.close()
        eng.shutdown()


def test_speculative_refetch_on_verify_failure(rng):
    """ISSUE 3 satellite: a verify mismatch retries the next replica
    instead of raising — the read succeeds, the corrupt copy is
    quarantined (repair hint), and later reads avoid it."""
    mgr, nodes = make_store(4, replication=2)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        data = rng.integers(0, 256, 4 * 4096, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        digest = next(iter(mgr.block_registry))
        bad_nid = mgr.block_registry[digest][0]
        blk = nodes[bad_nid].blocks[digest]
        nodes[bad_nid].blocks[digest] = bytes([blk[0] ^ 0xFF]) + blk[1:]

        assert sai.read("/f") == data            # no IOError
        assert sai.read_stats["refetches"] >= 1
        assert mgr.is_quarantined(digest, bad_nid)
        assert bad_nid not in mgr.lookup_block(digest)
        assert sai.read_async("/f").result(timeout=120) == data
    finally:
        sai.close()
        eng.shutdown()


def test_read_cache_hits_skip_fetch_and_verify(rng, monkeypatch):
    """ISSUE 3 satellite: with read_cache_bytes set, a repeat read is
    served from the verified block cache — no node fetches, no
    re-hashing — and hit/miss counters track it."""
    mgr, nodes = make_store(4)
    sai = SAI(mgr, _cfg(hasher="cpu", read_cache_bytes=1 << 20))
    data = rng.integers(0, 256, 4 * 4096, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    assert sai.read("/f") == data
    assert sai.read_stats["cache_misses"] == 4
    assert sai.read_stats["cache_hits"] == 0

    gets_before = sum(n.get_count for n in nodes)
    import repro.core.sai as sai_mod

    def _boom(_):
        raise AssertionError("hash recomputed for a cached block")

    monkeypatch.setattr(sai_mod, "block_digest_cpu", _boom)
    assert sai.read("/f") == data                # pure cache hits
    assert sai.read_stats["cache_hits"] == 4
    assert sum(n.get_count for n in nodes) == gets_before


def test_read_cache_evicts_lru_and_defaults_off(rng):
    mgr, _ = make_store(4)
    # budget for two 4 KiB blocks
    sai = SAI(mgr, _cfg(hasher="cpu", read_cache_bytes=8192))
    data = rng.integers(0, 256, 4 * 4096, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    assert sai.read("/f") == data
    assert len(sai._cache) <= 2
    assert sai._cache_used <= 8192

    sai_off = SAI(mgr, _cfg(hasher="cpu"))       # default: cache off
    assert sai_off.read("/f") == data
    assert sai_off.read("/f") == data
    assert sai_off.read_stats["cache_hits"] == 0
    assert sai_off.read_stats["cache_misses"] == 0


def test_read_cache_invalidated_on_quarantine(rng):
    """ISSUE 4 satellite: a cached block whose on-node copy is
    quarantined is evicted — the next read re-fetches and re-verifies
    from the surviving replicas instead of serving the stale entry."""
    mgr, nodes = make_store(4, replication=2)
    sai = SAI(mgr, _cfg(hasher="cpu", read_cache_bytes=1 << 20))
    data = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    assert sai.read("/f") == data                # populate the cache
    digest = mgr.get_blockmap("/f").blocks[0].digest
    assert digest in sai._cache
    used = sai._cache_used

    bad_nid = mgr.block_registry[digest][0]
    mgr.quarantine_block(digest, bad_nid)
    assert digest not in sai._cache              # invalidated, not stale
    assert sai._cache_used < used
    assert sai.read_stats["cache_invalidations"] == 1

    gets_before = sum(n.get_count for n in nodes)
    assert sai.read("/f") == data                # re-fetch + re-verify
    assert sum(n.get_count for n in nodes) > gets_before
    assert digest in sai._cache                  # re-admitted verified


def test_read_cache_lru_eviction_order(rng):
    """LRU regression: touching an entry moves it to the MRU end, so a
    later insert evicts the genuinely least-recently-used block."""
    mgr, _ = make_store(4)
    sai = SAI(mgr, _cfg(hasher="cpu", read_cache_bytes=8192))  # 2 blocks
    d1 = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
    d2 = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    sai.write("/f1", d1)
    sai.write("/f2", d2)
    assert sai.read("/f1") == d1                 # cache [A, B]
    dig_a, dig_b = [b.digest for b in mgr.get_blockmap("/f1").blocks]
    assert sai._cache_get(dig_a) is not None     # touch A: order [B, A]
    assert sai.read("/f2") == d2                 # insert C: evicts B
    dig_c = mgr.get_blockmap("/f2").blocks[0].digest
    assert dig_b not in sai._cache
    assert dig_a in sai._cache and dig_c in sai._cache


# ----------------------------------------------------------------------
# Merkle-proof partial reads (ISSUE 4 satellite)
# ----------------------------------------------------------------------
def test_read_range_slices_and_fetches_only_covering_blocks(rng):
    """read_range returns the exact byte slice for aligned, straddling,
    tail-clamped, and out-of-range requests — and fetches ONLY the
    covering blocks (node get counts prove it)."""
    mgr, nodes = make_store(4)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        data = rng.integers(0, 256, 10 * 4096 + 123,
                            dtype=np.uint8).tobytes()
        sai.write("/f", data)
        for off, ln in [(0, 100), (4096, 4096), (5000, 9000),
                        (10 * 4096, 1000), (0, 1 << 40),
                        (len(data) - 10, 10), (3, 0)]:
            assert sai.read_range("/f", off, ln) == data[off:off + ln], \
                (off, ln)
        gets0 = sum(n.get_count for n in nodes)
        assert sai.read_range("/f", 4096, 4096) == data[4096:8192]
        assert sum(n.get_count for n in nodes) - gets0 == 1
        with pytest.raises(ValueError):
            sai.read_range("/f", -1, 10)
        with pytest.raises(FileNotFoundError):
            sai.read_range("/nope", 0, 10)
    finally:
        sai.close()
        eng.shutdown()


def test_read_range_verifies_against_merkle_root(rng):
    """A corrupt covering block is caught by the recomputed digest and
    healed from the next replica; a tampered block-map (stored root no
    longer matches the leaves) fails the membership proof with IOError
    even though the block bytes match their own digest."""
    mgr, nodes = make_store(4, replication=2)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        data = rng.integers(0, 256, 6 * 4096, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        fv = mgr.get_blockmap("/f")
        b = fv.blocks[2]
        bad_nid = mgr.block_registry[b.digest][0]
        blk = nodes[bad_nid].blocks[b.digest]
        nodes[bad_nid].blocks[b.digest] = bytes([blk[0] ^ 0xFF]) + blk[1:]
        # corrupt copy: speculative re-fetch (full-read semantics)
        assert sai.read_range("/f", 2 * 4096, 4096) == \
            data[2 * 4096:3 * 4096]
        assert sai.read_stats["refetches"] >= 1
        assert mgr.is_quarantined(b.digest, bad_nid)
        # metadata tamper: the stored root stops matching the leaves
        fv.merkle_root = b"\x00" * 16
        with pytest.raises(IOError):
            sai.read_range("/f", 0, 4096)
        # unverified range read still serves bytes
        assert sai.read_range("/f", 0, 4096, verify=False) == data[:4096]
    finally:
        sai.close()
        eng.shutdown()


def test_read_range_root_check_covers_cached_blocks(rng):
    """Regression: a warm read cache must not bypass the root check —
    a tampered block-map fails the membership proof even when every
    covering block is served from the verified cache."""
    mgr, _ = make_store(4)
    sai = SAI(mgr, _cfg(hasher="cpu", read_cache_bytes=1 << 20))
    data = rng.integers(0, 256, 4 * 4096, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    assert sai.read("/f") == data                # warm the cache
    assert sai.read_range("/f", 4096, 4096) == data[4096:8192]
    mgr.get_blockmap("/f").merkle_root = b"\x00" * 16
    with pytest.raises(IOError):
        sai.read_range("/f", 4096, 4096)         # cache-warm, still caught


def test_read_range_eof_edges(rng):
    """EOF edge cases (ISSUE 5 satellite): offset exactly at EOF and
    zero-length reads return b'' (no block is fetched), a range ending
    inside the final partial block returns exactly the partial tail,
    and an offset strictly past EOF raises ValueError cleanly instead
    of silently reading empty."""
    mgr, nodes = make_store(4)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(), crystal=eng)
    try:
        tail = 123                               # final partial block
        data = rng.integers(0, 256, 3 * 4096 + tail,
                            dtype=np.uint8).tobytes()
        sai.write("/f", data)
        gets0 = sum(n.get_count for n in nodes)
        assert sai.read_range("/f", len(data), 10) == b""    # at EOF
        assert sai.read_range("/f", len(data), 0) == b""
        assert sai.read_range("/f", 100, 0) == b""           # zero len
        assert sai.read_range("/f", 0, 0) == b""
        assert sum(n.get_count for n in nodes) == gets0      # no fetch
        # range ending inside the final partial block
        assert sai.read_range("/f", 3 * 4096 + 3, 40) == \
            data[3 * 4096 + 3:3 * 4096 + 43]
        # range extending past the partial tail clamps to it
        assert sai.read_range("/f", 3 * 4096, 4096) == data[3 * 4096:]
        for off in (len(data) + 1, len(data) + 5000, 1 << 40):
            with pytest.raises(ValueError):
                sai.read_range("/f", off, 10)
            with pytest.raises(ValueError):
                sai.read_range("/f", off, 0)     # past EOF beats len=0
    finally:
        sai.close()
        eng.shutdown()


def test_read_range_matches_full_read_across_ca_modes(rng):
    """Partial reads agree with full reads for CDC chunkings too (the
    covering-block walk handles ragged chunk lengths)."""
    for ca in ("fixed", "cdc", "cdc-gear"):
        mgr, _ = make_store(4)
        sai = SAI(mgr, _cfg(ca=ca, hasher="cpu"))
        data = rng.integers(0, 256, 30_000, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        for off, ln in [(0, 30_000), (1234, 5000), (17_000, 13_000)]:
            assert sai.read_range("/f", off, ln) == data[off:off + ln], \
                (ca, off, ln)
