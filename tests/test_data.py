"""Data pipeline determinism (restart + elastic resharding)."""
import numpy as np

from repro.configs import get_smoke_config
from repro.data import make_pipeline


def test_restart_determinism():
    cfg = get_smoke_config("llama3-8b")
    p1 = make_pipeline(cfg, 64, 4, seed=3)
    p2 = make_pipeline(cfg, 64, 4, seed=3)
    for step in (0, 7, 123):
        np.testing.assert_array_equal(p1.batch(step)["tokens"],
                                      p2.batch(step)["tokens"])


def test_shards_partition_global_batch():
    cfg = get_smoke_config("llama3-8b")
    full = make_pipeline(cfg, 64, 8, num_shards=1).batch(5)["tokens"]
    parts = [make_pipeline(cfg, 64, 8, shard=s, num_shards=4).batch(5)
             ["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_stream_is_learnable_not_uniform():
    cfg = get_smoke_config("llama3-8b")
    p = make_pipeline(cfg, 256, 4)
    toks = p.batch(0)["tokens"]
    counts = np.bincount(toks.ravel(), minlength=cfg.vocab_size)
    # Zipf-ish: top-10 tokens should dominate uniform expectation
    assert counts[np.argsort(-counts)[:10]].sum() > toks.size * 0.2


def test_vlm_embeds_present():
    cfg = get_smoke_config("internvl2-2b")
    p = make_pipeline(cfg, 64, 2)
    b = p.batch(0)
    assert b["embeds"].shape == (2, cfg.frontend_embeds, cfg.d_model)
    assert b["tokens"].shape == (2, 64 - cfg.frontend_embeds)
