"""Merkle-tree integrity."""
import pytest
from _hypcompat import given, settings, strategies as st

from repro.core.integrity import merkle_proof, merkle_root, merkle_verify


@settings(max_examples=20, deadline=None)
@given(st.lists(st.binary(min_size=16, max_size=16), min_size=1,
                max_size=33))
def test_proofs_verify(leaves):
    root = merkle_root(leaves)
    for i, leaf in enumerate(leaves):
        proof = merkle_proof(leaves, i)
        assert merkle_verify(leaf, i, proof, root)


def test_tamper_detected():
    leaves = [bytes([i]) * 16 for i in range(9)]
    root = merkle_root(leaves)
    proof = merkle_proof(leaves, 4)
    assert merkle_verify(leaves[4], 4, proof, root)
    assert not merkle_verify(b"x" * 16, 4, proof, root)
    other_root = merkle_root(leaves[:-1])
    assert not merkle_verify(leaves[4], 4, proof, other_root)
