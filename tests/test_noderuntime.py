"""Storage-node runtime (ISSUE 3): offloaded scrubbing, refcounted GC,
repair/re-replication.

Covers the acceptance criteria: a corrupted-block injection is detected
by the scrubber via fused scrub-lane engine submissions, quarantined,
repaired back to full replica count from a healthy copy (verified
through the engine), and a subsequent read returns correct data; the
engine's scrub counters show coalescing (scrub_launches < scrub_jobs);
a block claimed/pinned by a concurrent writer is never garbage
collected; retire events drive refcounted GC; the Merkle spot-checker
flags corruption against the file-level root; and the background
supervisor lifecycle (start/pause/resume/stop) heals injected
corruption without synchronous driving.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (ClusterRuntime, CrystalTPU, NodeRuntimeConfig,
                        SAI, SAIConfig, integrity, make_store)
from repro.core.crystal import LaneQueue


def _cfg(hasher="cpu", **kw):
    return SAIConfig(ca="fixed", hasher=hasher, block_size=4096,
                     avg_chunk=4096, min_chunk=1024, max_chunk=16384, **kw)


def _corrupt(node, digest):
    blk = node.blocks[digest]
    node.blocks[digest] = bytes([blk[0] ^ 0xFF]) + blk[1:]


def test_scrub_detects_quarantines_and_repairs(rng):
    """The acceptance scenario: inject corruption into one replica,
    scrub detects it through fused scrub-lane submissions, repair
    restores the replica count from the healthy copy, and a subsequent
    read returns correct data without error."""
    mgr, nodes = make_store(4, replication=2)
    eng = CrystalTPU(coalesce_window_s=0.05)
    sai = SAI(mgr, _cfg(hasher="tpu"), crystal=eng)
    try:
        data = rng.integers(0, 256, 12 * 4096, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        digest = next(iter(mgr.block_registry))
        bad_nid = mgr.block_registry[digest][0]
        _corrupt(nodes[bad_nid], digest)

        rt = ClusterRuntime(mgr, engine=eng)
        res = rt.scrub_once()
        assert res["corrupt"] == 1
        assert mgr.is_quarantined(digest, bad_nid)
        assert bad_nid not in mgr.lookup_block(digest)

        placed = rt.repair_once()
        assert placed >= 1
        healthy = [n for n in mgr.lookup_block(digest)
                   if mgr.nodes[n].has(digest)]
        assert len(healthy) >= 2          # replica count restored
        assert sai.read("/f") == data     # verified read, no error

        s = rt.snapshot_stats()
        assert s["corrupt_found"] == 1
        assert s["repaired_copies"] >= 1
        # fused background burst signature
        assert 0 < s["scrub_launches"] < s["scrub_jobs"]
    finally:
        sai.close()
        eng.shutdown()


def test_gc_never_collects_claimed_or_pinned_blocks(rng):
    """Regression for GC vs the claim protocol: a block pinned by an
    in-flight writer (the dedup claim -> store -> commit span) must
    never be collected even at refcount zero."""
    mgr, _ = make_store(4)
    sai = SAI(mgr, _cfg())
    data = rng.integers(0, 256, 8 * 4096, dtype=np.uint8).tobytes()
    sai.write("/a", data)
    fv = mgr.get_blockmap("/a")
    digests = [b.digest for b in fv.blocks]

    # writer B is mid-flight: it pinned its digests (as _store_chunks
    # does) but has not committed yet; /a retires meanwhile
    mgr.pin_blocks(digests)
    mgr.delete_file("/a")
    assert mgr.gc_collect() == 0                  # pinned: survives
    assert all(mgr.lookup_block(d) for d in digests)

    # a digest actively claimed by a concurrent writer is skipped too
    claimed_digest = b"\x01" * 16
    _, claimed, _ = mgr.claim_blocks([claimed_digest])
    assert claimed_digest in claimed
    mgr.register_block(claimed_digest, (0,))
    mgr.nodes[0].put(claimed_digest, b"payload")
    assert mgr.gc_collect([claimed_digest]) == 0  # claimed: survives
    mgr.finish_claim(claimed_digest, (0,))

    # B commits: blocks are refcounted again and GC still spares them
    mgr.commit_blockmap("/b", fv.blocks, fv.total_len)
    mgr.unpin_blocks(digests)
    mgr.gc_collect()
    assert sai.read("/b") == data

    # only after /b retires do the blocks become collectible
    mgr.delete_file("/b")
    assert mgr.gc_collect() > 0
    assert not mgr.lookup_block(digests[0])


def test_concurrent_dedup_writes_survive_gc_loop(rng):
    """Chaos variant: a GC loop spins while writers dedup against
    retiring content; every committed file must remain readable."""
    mgr, _ = make_store(4)
    sai = SAI(mgr, _cfg())
    data = rng.integers(0, 256, 6 * 4096, dtype=np.uint8).tobytes()
    sai.write("/seed", data)
    stop = threading.Event()

    def gc_loop():
        while not stop.is_set():
            mgr.gc_collect()

    t = threading.Thread(target=gc_loop)
    t.start()
    try:
        prev = "/seed"
        for i in range(8):
            sai.write(f"/gen{i}", data)   # dedup-claims retiring blocks
            mgr.delete_file(prev)
            prev = f"/gen{i}"
    finally:
        stop.set()
        t.join()
    assert sai.read(prev) == data


def test_retire_events_drive_runtime_gc(rng):
    """Version retirement reports orphans to the runtime, whose GC
    reclaims exactly the no-longer-referenced blocks."""
    mgr, _ = make_store(4)
    sai = SAI(mgr, _cfg())
    rt = ClusterRuntime(mgr)              # subscribes to retire events
    v0 = rng.integers(0, 256, 12 * 4096, dtype=np.uint8).tobytes()
    v1 = v0[: 6 * 4096]                   # shares the first 6 blocks
    sai.write("/f", v0)
    sai.write("/f", v1)
    blocks_before = mgr.stats()["unique_blocks"]

    # keep_latest beyond the version count must retire nothing
    assert mgr.retire_versions("/f", keep_latest=5) == []
    assert sai.read("/f", version=0) == v0

    orphans = mgr.retire_versions("/f", keep_latest=1)
    assert len(orphans) == 6              # v0-only blocks
    removed = rt.gc_once()
    assert removed == 6
    assert mgr.stats()["unique_blocks"] == blocks_before - 6
    assert sai.read("/f") == v1           # latest version intact
    assert rt.snapshot_stats()["gc_collected"] == 6


def test_merkle_root_and_spot_check(rng):
    """commit_blockmap stores the file-level Merkle root; the runtime's
    spot-checker verifies sampled blocks against it via merkle_proof and
    flags corruption."""
    mgr, nodes = make_store(4, replication=1)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(hasher="tpu"), crystal=eng)
    try:
        data = rng.integers(0, 256, 4 * 4096, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        fv = mgr.get_blockmap("/f")
        assert fv.merkle_root == integrity.merkle_root(
            [b.digest for b in fv.blocks])

        rt = ClusterRuntime(mgr, engine=eng)
        assert rt.merkle_check_once(samples=4) == 0
        assert rt.snapshot_stats()["merkle_checks"] == 4

        for b in fv.blocks:               # corrupt every copy
            for nid in mgr.lookup_block(b.digest):
                _corrupt(nodes[nid], b.digest)
        assert rt.merkle_check_once(samples=4) > 0
        assert rt.snapshot_stats()["merkle_failures"] > 0
        assert mgr.stats()["quarantined"] > 0
    finally:
        sai.close()
        eng.shutdown()


def test_under_replication_scan_and_repair(rng):
    """A silently lost replica (no failure event) is found by the
    under-replication scan and re-replicated from the surviving copy."""
    mgr, nodes = make_store(4, replication=2)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(hasher="tpu"), crystal=eng)
    try:
        data = rng.integers(0, 256, 4 * 4096, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        digest = next(iter(mgr.block_registry))
        lost_nid = mgr.block_registry[digest][0]
        del nodes[lost_nid].blocks[digest]          # silent loss

        rt = ClusterRuntime(mgr, engine=eng)
        assert rt.scan_under_replicated() >= 1
        assert rt.repair_once() >= 1
        healthy = [n for n in mgr.lookup_block(digest)
                   if mgr.nodes[n].has(digest)]
        assert len(healthy) >= 2
        assert sai.read("/f") == data
    finally:
        sai.close()
        eng.shutdown()


def test_background_supervisor_heals_corruption(rng):
    """Lifecycle: start() alone detects and repairs injected corruption;
    pause/resume/stop work."""
    mgr, nodes = make_store(4, replication=2)
    eng = CrystalTPU(coalesce_window_s=0.02)
    sai = SAI(mgr, _cfg(hasher="tpu"), crystal=eng)
    rt = ClusterRuntime(
        mgr, engine=eng,
        config=NodeRuntimeConfig(scrub_interval_s=0.0,
                                 scrub_cycle_idle_s=0.01,
                                 repair_poll_s=0.01))
    try:
        data = rng.integers(0, 256, 4 * 4096, dtype=np.uint8).tobytes()
        sai.write("/f", data)
        digest = next(iter(mgr.block_registry))
        bad_nid = mgr.block_registry[digest][0]
        _corrupt(nodes[bad_nid], digest)

        rt.start()
        deadline = time.time() + 120
        while time.time() < deadline:
            healthy = [n for n in mgr.lookup_block(digest)
                       if mgr.nodes[n].has(digest)]
            if rt.snapshot_stats()["corrupt_found"] >= 1 \
                    and len(healthy) >= 2:
                break
            time.sleep(0.05)
        rt.pause()
        rt.resume()
        healthy = [n for n in mgr.lookup_block(digest)
                   if mgr.nodes[n].has(digest)]
        assert rt.snapshot_stats()["corrupt_found"] >= 1
        assert len(healthy) >= 2
        assert sai.read("/f") == data
    finally:
        rt.stop()
        sai.close()
        eng.shutdown()


def test_lane_queue_priority_order():
    """Foreground jobs dequeue before batch jobs, batch before scrub;
    shutdown sentinels dequeue only once every lane is drained."""
    q = LaneQueue()
    q.put("s1", lane="scrub")
    q.put(None)                            # shutdown sentinel
    q.put("b1", lane="batch")
    q.put("f1")
    q.put("s2", lane="scrub")
    q.put("f2", lane="fg")
    assert [q.get_nowait() for _ in range(6)] == \
        ["f1", "f2", "b1", "s1", "s2", None]
    with pytest.raises(Exception):
        q.get_nowait()
    assert q.depth() == 0
    q.put("x", lane="batch")
    assert q.depth("batch") == 1 and q.depth("fg") == 0


def test_scrub_backs_off_under_foreground_load(rng):
    """ISSUE 4 satellite (ROADMAP open item): with the engine's
    foreground queue backlogged past scrub_backoff_depth, the scrubber
    defers its burst (scrub_backoffs counts the trigger) and scans
    nothing; with the backlog gone it scans normally."""
    mgr, _ = make_store(2)
    sai = SAI(mgr, _cfg(hasher="cpu"))
    data = rng.integers(0, 256, 8 * 4096, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    # managerless engine: queued foreground jobs never drain, so the
    # backlog is a deterministic load signal (nothing waits on them)
    idle = CrystalTPU(devices=[])
    from repro.core.sai import pack_blocks
    for _ in range(6):
        rows, lens = pack_blocks([b"load"])
        idle.submit("direct", rows, {"lens": lens})
    rt = ClusterRuntime(mgr, engine=idle, config=NodeRuntimeConfig(
        scrub_backoff_depth=2, scrub_backoff_s=0.01))
    res = rt.scrub_once()
    s = rt.snapshot_stats()
    assert res["scanned"] == 0                 # sweep yielded
    assert s["scrub_backoffs"] >= 1            # and the counter proves it
    idle.shutdown()

    eng = CrystalTPU()                         # drained engine: no backoff
    rt2 = ClusterRuntime(mgr, engine=eng, config=NodeRuntimeConfig(
        scrub_backoff_depth=2, scrub_backoff_s=0.01))
    try:
        res2 = rt2.scrub_once()
        assert res2["scanned"] == 8
        assert rt2.snapshot_stats()["scrub_backoffs"] == 0
    finally:
        eng.shutdown()


def test_scrub_lane_yields_to_foreground(rng):
    """End-to-end lane behavior: with a busy scrub backlog queued, a
    foreground write still completes promptly and correctly."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    sai = SAI(mgr, _cfg(hasher="tpu"), crystal=eng)
    try:
        datas = [rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
                 for _ in range(32)]
        from repro.core.sai import pack_blocks
        jobs = []
        for d in datas:                    # pile up background traffic
            rows, lens = pack_blocks([d])
            jobs.append(eng.submit("direct", rows, {"lens": lens},
                                   lane="scrub"))
        data = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
        sai.write("/fg", data)             # foreground jumps the queue
        assert sai.read("/fg") == data
        for j in jobs:
            j.wait()                       # backlog still completes
        s = eng.snapshot_stats()
        assert s["scrub_jobs"] == 32
        assert s["scrub_launches"] < s["scrub_jobs"]
    finally:
        sai.close()
        eng.shutdown()
