"""Chunking invariants (property-based)."""
import numpy as np
from _hypcompat import HealthCheck, given, settings, strategies as st

from repro.core import chunking
from repro.kernels import ops


def _chunk(data: bytes, avg=1024, mn=256, mx=4096):
    h = ops.gear_hash(data)
    bounds = chunking.select_boundaries(
        h, len(data), window=1, stride=1, avg_chunk=avg, min_chunk=mn,
        max_chunk=mx)
    return bounds


@settings(max_examples=15, deadline=None)
@given(st.binary(min_size=1, max_size=20_000))
def test_concat_identity(data):
    bounds = _chunk(data)
    chunks = chunking.split_chunks(data, bounds)
    assert b"".join(chunks) == data


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.large_base_example,
                                 HealthCheck.data_too_large])
@given(st.binary(min_size=6000, max_size=20_000))
def test_chunk_size_limits(data):
    mn, mx = 256, 4096
    bounds = _chunk(data, mn=mn, mx=mx)
    spans = chunking.chunk_spans(bounds)
    for i, (s, e) in enumerate(spans):
        assert e - s <= mx
        if i < len(spans) - 1:                 # last chunk may be short
            assert e - s >= mn


def test_insertion_locality(rng):
    """The classic CDC property: a local edit changes only local chunks."""
    data = rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes()
    edited = data[:30_000] + b"HELLO!" + data[30_000:]
    c1 = set()
    for s, e in chunking.chunk_spans(_chunk(data)):
        c1.add(data[s:e])
    c2 = set()
    for s, e in chunking.chunk_spans(_chunk(edited)):
        c2.add(edited[s:e])
    shared = sum(len(c) for c in (c1 & c2))
    total = sum(len(c) for c in c2)
    assert shared / total > 0.8, f"only {shared/total:.2f} shared after edit"


def test_fixed_vs_cdc_shift_behaviour(rng):
    """Fixed-size blocks lose dedup after an insertion; CDC keeps it —
    the tradeoff the paper quantifies (similarity 21-23% vs 76-90%)."""
    data = rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes()
    edited = b"X" * 7 + data                    # shift everything by 7
    # fixed 4K
    fixed = lambda d: {d[i:i + 4096] for i in range(0, len(d), 4096)}
    f_shared = fixed(data) & fixed(edited)
    # cdc
    c1 = {data[s:e] for s, e in chunking.chunk_spans(_chunk(data))}
    c2 = {edited[s:e] for s, e in chunking.chunk_spans(_chunk(edited))}
    cdc_ratio = sum(map(len, c1 & c2)) / len(edited)
    fixed_ratio = sum(map(len, f_shared)) / len(edited)
    assert cdc_ratio > 0.8
    assert fixed_ratio < 0.1


def test_max_chunk_forced_boundaries():
    """Data with no natural boundaries still chunks at max_chunk."""
    data = b"\x00" * 50_000
    bounds = _chunk(data, avg=1024, mn=256, mx=4096)
    spans = chunking.chunk_spans(bounds)
    assert all(e - s <= 4096 for s, e in spans)
    assert b"".join(data[s:e] for s, e in spans) == data
