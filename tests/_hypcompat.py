"""Make ``hypothesis`` optional for the tier-1 suite.

Re-exports ``given`` / ``settings`` / ``strategies`` / ``HealthCheck``
from the real hypothesis when it is installed.  Otherwise provides a
deterministic fallback: each ``@given`` test runs ``max_examples`` times
over examples drawn from a seeded PRNG via minimal strategy stand-ins
(only the strategy surface this test suite uses: ``binary`` and
``lists``).  Property coverage is thinner than real hypothesis (no
shrinking, no edge-case bias) but the invariants still execute on every
machine, with or without the dev extra installed.
"""
try:
    from hypothesis import HealthCheck, given, settings, strategies
    HAVE_HYPOTHESIS = True
except ImportError:                                   # thin fallback
    HAVE_HYPOTHESIS = False
    import functools
    import inspect
    import random

    class HealthCheck:
        large_base_example = "large_base_example"
        data_too_large = "data_too_large"
        too_slow = "too_slow"

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rnd):
            return self._draw(rnd)

    class _Strategies:
        @staticmethod
        def binary(min_size=0, max_size=64):
            def draw(rnd):
                return rnd.randbytes(rnd.randint(min_size, max_size))
            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=8):
            def draw(rnd):
                n = rnd.randint(min_size, max_size)
                return [elements.example_from(rnd) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            def draw(rnd):
                return rnd.randint(min_value, max_value)
            return _Strategy(draw)

    strategies = _Strategies()

    def settings(max_examples=10, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rnd = random.Random(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    drawn = [s.example_from(rnd) for s in strats]
                    fn(*args, *drawn, **kwargs)
            # hide the drawn parameters from pytest's fixture resolution
            params = list(inspect.signature(fn).parameters.values())
            n_keep = len(params) - len(strats)
            wrapper.__signature__ = inspect.Signature(params[:n_keep])
            return wrapper
        return deco
