"""End-to-end observability plane (ISSUE 8).

Acceptance coverage: a write through ``GatewayClient`` over a real
``SocketChannel`` yields (a) an ``OP_STATS`` reply whose JSON carries
engine per-device launch histograms with non-zero p50/p99 and WAL
fsync percentiles, and (b) a completed trace in the gateway's ring
whose span tree covers transport decode -> WDRR queue -> SAI hash ->
engine launch -> WAL commit with monotonic, nested timestamps.  The
metric primitives ride along: histogram percentile math, the
CounterGroup dict facade, race-free concurrent increments (the
unsynchronized ``stats[...] += 1`` fix), Prometheus exposition, and
the slow-request log dump.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import CrystalTPU, SAIConfig, make_store
from repro.obs import (Histogram, MetricsRegistry, Tracer, dump_slow_log,
                       flatten, prometheus_text)
from repro.serve.storage_client import GatewayClient
from repro.serve.storage_service import (GatewayConfig, StorageGateway,
                                         encode_request, decode_request,
                                         OP_WRITE)
from repro.serve.transport import GatewayServer


@pytest.fixture
def rng():
    return np.random.default_rng(42)


# ----------------------------------------------------------------------
# metric primitives
# ----------------------------------------------------------------------
def test_histogram_percentiles_log_buckets():
    h = Histogram("t")
    for _ in range(1000):
        h.record(1e-3)
    # pow-2 ns buckets are good to ~±41%: the geometric bucket midpoint
    # for 1 ms must land within a factor of sqrt(2)
    for p in (50.0, 95.0, 99.0):
        assert 1e-3 / 1.5 <= h.percentile(p) <= 1e-3 * 1.5
    s = h.summary()
    assert s["count"] == 1000
    assert s["max_s"] == pytest.approx(1e-3)
    assert s["sum_s"] == pytest.approx(1.0)
    # a bimodal tail shows up in p99 but not p50
    h2 = Histogram("t2")
    for _ in range(98):
        h2.record(1e-4)
    for _ in range(2):
        h2.record(1.0)
    assert h2.percentile(50.0) < 1e-3
    assert h2.percentile(99.0) > 0.5


def test_histogram_edge_buckets():
    h = Histogram()
    h.record(0.0)                    # sub-ns -> bucket 0 -> 0.0
    assert h.percentile(50.0) == 0.0
    h.record(1e12)                   # clamped to the top bucket, no raise
    assert h.count == 2
    assert h.percentile(99.0) > 0.0
    assert h.summary()["max_s"] == pytest.approx(1e12)


def test_counter_group_is_a_dict_facade():
    reg = MetricsRegistry()
    stats = reg.group(("jobs", "launches"), prefix="eng/")
    assert stats["jobs"] == 0
    stats.inc("jobs", 3)
    stats.inc("launches")
    assert dict(stats) == {"jobs": 3, "launches": 1}
    assert {**stats} == {"jobs": 3, "launches": 1}
    assert stats == {"jobs": 3, "launches": 1}
    stats["jobs"] = 10               # absolute set (owner-lock callers)
    assert stats["jobs"] == 10
    stats.max_update("jobs", 7)      # no-op below the high-water mark
    assert stats["jobs"] == 10
    stats.max_update("jobs", 12)
    assert stats["jobs"] == 12
    # the registry sees the prefixed names
    assert reg.snapshot()["counters"]["eng/jobs"] == 12
    # unknown keys materialize on first inc (dynamic stat sites)
    stats.inc("errors")
    assert stats["errors"] == 1


def test_concurrent_increments_lose_no_updates():
    """The satellite-1 regression test: ``stats[k] += 1`` from many
    threads loses updates (read-modify-write race); ``stats.inc(k)``
    must not, even with concurrent snapshot readers."""
    reg = MetricsRegistry()
    stats = reg.group(("a", "b", "c"))
    hist = reg.histogram("lat")
    n_threads, n_iter = 8, 5000
    stop = threading.Event()

    def hammer():
        for i in range(n_iter):
            stats.inc("a")
            stats.inc("b", 2)
            stats.inc("c", i % 3)
            hist.record(1e-6)

    def reader():
        while not stop.is_set():
            dict(stats)
            hist.summary()

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in readers + threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert stats["a"] == n_threads * n_iter
    assert stats["b"] == 2 * n_threads * n_iter
    assert stats["c"] == n_threads * sum(i % 3 for i in range(n_iter))
    assert hist.count == n_threads * n_iter


def test_flatten_separator_in_key_cannot_collide():
    # a tenant literally named "a/b" must not flatten to the same metric
    # name as the genuinely nested path a -> b
    tree = {"tenants": {"a": {"b": 1}, "a/b": 2}}
    flat = flatten(tree)
    assert flat["tenants/a/b"] == 1.0
    assert flat["tenants/a%2Fb"] == 2.0
    assert len(flat) == 2
    # '%' itself round-trips unambiguously too
    flat2 = flatten({"x%2Fy": 1, "x/y": 2})
    assert flat2["x%252Fy"] == 1.0
    assert flat2["x%2Fy"] == 2.0


def test_flatten_and_prometheus_text():
    tree = {"tenants": {"acme": {"completed": 3, "qos": "batch"}},
            "engine": {"per_device": {0: {"jobs": 5, "p50_s": 0.25}}},
            "ok": True,
            "depths": [1, 2]}
    flat = flatten(tree)
    assert flat["tenants/acme/completed"] == 3.0
    assert flat["engine/per_device/0/jobs"] == 5.0
    assert flat["ok"] == 1.0
    assert flat["depths/0"] == 1.0
    assert "tenants/acme/qos" not in flat        # strings dropped
    text = prometheus_text(tree)
    assert "repro_tenants_acme_completed 3\n" in text
    assert "repro_engine_per_device_0_p50_s 0.25" in text
    assert "# TYPE repro_tenants_acme_completed counter\n" in text
    for line in text.strip().splitlines():
        if line.startswith("#"):                  # TYPE annotations
            assert line.split(" ")[1] == "TYPE"
            continue
        name, value = line.split(" ")
        float(value)                              # every line parses
        assert name.startswith("repro_")


def test_dump_slow_log(tmp_path):
    path = str(tmp_path / "slow.json")
    assert dump_slow_log([], path) is False
    assert not (tmp_path / "slow.json").exists()
    entries = [{"trace_id": 7, "name": "write", "spans": []}]
    assert dump_slow_log(entries, path) is True
    with open(path) as fh:
        assert json.load(fh)["slow_requests"][0]["trace_id"] == 7


def test_tracer_ring_is_bounded():
    tr = Tracer(capacity=4, slow_threshold_s=0.0)
    for i in range(10):
        t = tr.start(i + 1, "op")
        t.add_span("stage", t.t0, t.t0 + 1e-6)
        tr.finish(t)
    st = tr.stats()
    assert st["finished"] == 10
    assert st["in_ring"] == 4
    assert [t.trace_id for t in tr.completed()] == [7, 8, 9, 10]
    # threshold 0.0: everything lands in the slow log too (bounded)
    assert st["slow"] == 10
    assert len(tr.slow_entries()) <= 64


# ----------------------------------------------------------------------
# trace-id propagation on the wire
# ----------------------------------------------------------------------
def test_trace_id_rides_the_request_frame():
    frame = encode_request(OP_WRITE, 3, 9, path="/p", data=b"d",
                           trace=0x1122334455667788)
    _op, _sess, _rid, fields = decode_request(frame)
    assert fields["trace"] == 0x1122334455667788
    # trace 0 = untraced: omitted from decoded fields so untraced
    # frames round-trip byte-identically through encode(**decode())
    frame0 = encode_request(OP_WRITE, 3, 9, path="/p", data=b"d")
    _op, _sess, _rid, fields0 = decode_request(frame0)
    assert "trace" not in fields0


# ----------------------------------------------------------------------
# acceptance: socket e2e — stats over the wire + span tree in the ring
# ----------------------------------------------------------------------
def _sai_cfg():
    return SAIConfig(ca="fixed", hasher="tpu", block_size=4096,
                     avg_chunk=4096, min_chunk=1024, max_chunk=16384)


def test_socket_write_yields_stats_and_span_tree(tmp_path, rng):
    gw = StorageGateway(None, engine=CrystalTPU(), config=GatewayConfig(
        sai=_sai_cfg(), data_dir=str(tmp_path / "store"),
        n_nodes=3, replication=2))
    eng = gw.engine
    server = GatewayServer(gw)
    try:
        client = GatewayClient(server, "acme")       # real SocketChannel
        datas = [rng.integers(0, 256, 8 * 4096, dtype=np.uint8).tobytes()
                 for _ in range(4)]
        for i, d in enumerate(datas):
            client.write(f"/obs/{i}", d)
        assert client.read("/obs/0") == datas[0]

        # (a) the OP_STATS wire snapshot: engine per-device launch
        # histograms with non-zero p50/p99, WAL fsync percentiles
        snap = client.stats()
        assert snap["obs"]["request"]["write"]["count"] == len(datas)
        assert snap["obs"]["request"]["write"]["p50_s"] > 0.0
        per_dev = snap["engine"]["per_device"]       # JSON: string keys
        hot = [d for d in per_dev.values()
               if d["launch_hist"]["count"] > 0]
        assert hot, f"no device recorded a launch: {per_dev}"
        for d in hot:
            assert d["launch_hist"]["p50_s"] > 0.0
            assert d["launch_hist"]["p99_s"] >= d["launch_hist"]["p50_s"]
        fsync = snap["wal"]["fsync_hist"]
        assert fsync["count"] > 0 and fsync["p50_s"] > 0.0
        assert snap["blockstore"]["puts"] > 0
        assert snap["obs"]["traces"]["finished"] >= len(datas) + 1
        client.close()

        # (b) a completed write trace whose span tree covers
        # transport -> WDRR queue -> SAI hash -> engine launch -> WAL
        # commit with monotonic, nested timestamps
        writes = [t for t in gw.tracer.completed() if t.name == "write"]
        assert writes
        trace = writes[-1]
        by_name = {}
        for s in trace.spans:
            by_name.setdefault(s.name, []).append(s)
        for needed in ("transport/decode", "gateway/queue", "sai/chunk",
                       "sai/hash", "sai/store", "engine/launch",
                       "wal/commit"):
            assert needed in by_name, (needed, sorted(by_name))
        for s in trace.spans:                        # nesting
            assert trace.t0 <= s.t0 <= s.t1 <= trace.t1, s.name
        order = [min(s.t0 for s in by_name[n])       # monotonic stages
                 for n in ("transport/decode", "gateway/queue",
                           "sai/hash", "engine/launch", "wal/commit")]
        assert order == sorted(order)
        launch = by_name["engine/launch"][0]
        assert "device" in launch.meta and "lane" in launch.meta

        # the read trace covers the fetch/verify path
        reads = [t for t in gw.tracer.completed() if t.name == "read"]
        assert reads
        read_names = {s.name for s in reads[-1].spans}
        assert {"transport/decode", "gateway/queue",
                "sai/fetch", "sai/verify"} <= read_names
    finally:
        server.close()
        gw.close()
        eng.shutdown()
