"""HLO analyzer: loop scaling, dot FLOPs, collective wire bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_analysis import analyze_hlo
from repro.roofline.analysis import model_flops, HW


def test_scan_flops_scaled_by_trip_count():
    """cost_analysis counts a while body once; the analyzer must scale
    by trip count (the whole point of the module)."""
    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    wN = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    compiled = jax.jit(scanned).lower(x, wN).compile()
    an = analyze_hlo(compiled.as_text())
    one_matmul = 2 * 128 * 256 * 256
    assert an["flops"] == pytest.approx(10 * one_matmul, rel=0.01)
    from repro.compat import cost_analysis
    xla_flops = cost_analysis(compiled)["flops"]
    assert xla_flops == pytest.approx(one_matmul, rel=0.01)


def test_single_dot_flops():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 32), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((32, 16), jnp.bfloat16)
    an = analyze_hlo(jax.jit(f).lower(a, b).compile().as_text())
    assert an["flops"] == pytest.approx(2 * 64 * 32 * 16, rel=0.01)


def test_dynamic_slice_bytes_not_full_operand():
    """A scan that slices one row per step must charge slice-sized reads,
    not the full stacked array each iteration."""
    def scanned(x, ws):
        def body(c, w):
            return c * 1.0 + jnp.sum(w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    ws = jax.ShapeDtypeStruct((100, 1024, 1024), jnp.float32)
    an = analyze_hlo(jax.jit(scanned).lower(x, ws).compile().as_text())
    full = 100 * 1024 * 1024 * 4
    # floor must be ~ 2x the data read once (slice read+write per step),
    # far below trips x full-array
    assert an["bytes_accessed"] < 4 * full
    assert an["bytes_accessed"] > 0.5 * full


def test_nested_scan_multiplies():
    def nested(x, ws):
        def outer(c, w):
            def inner(ci, wi):
                return jnp.tanh(ci @ wi), None
            ci, _ = jax.lax.scan(inner, c, w)
            return ci, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 3, 64, 64), jnp.float32)
    an = analyze_hlo(jax.jit(nested).lower(x, ws).compile().as_text())
    assert an["flops"] == pytest.approx(15 * 2 * 32 * 64 * 64, rel=0.01)


def test_model_flops_conventions():
    t = model_flops("llama3-8b", "train_4k")
    assert t == pytest.approx(6 * 8.03e9 * 256 * 4096, rel=0.02)
    d = model_flops("llama3-8b", "decode_32k")
    assert d == pytest.approx(2 * 8.03e9 * 128, rel=0.02)
    m = model_flops("mixtral-8x7b", "train_4k")     # active, not total
    assert m < 6 * 46.7e9 * 256 * 4096 * 0.5
