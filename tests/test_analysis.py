"""Tests for the invariant lint suite (src/repro/analysis).

Fixture-driven: each rule has a known-bad and a known-good snippet under
tests/fixtures/analysis/, with `# ra-selftest: RAxx` markers on exactly
the lines the checker must report.  Plus the end-to-end contract: the
merged src/repro tree is clean and the committed baseline byte-stable.
"""
import os
import subprocess
import sys

import pytest

from repro.analysis.engine import (SourceFile, format_baseline,
                                   load_baseline, run_analysis, selftest)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "fixtures", "analysis")
SRC_TREE = os.path.join(ROOT, "src", "repro")
BASELINE = os.path.join(ROOT, "analysis-baseline.txt")


def _marks(path, rel_root):
    """Expected (display, line, rule) triples from a fixture's markers."""
    display = os.path.relpath(path, rel_root).replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as fh:
        src = SourceFile(path, display, fh.read())
    return {(display, line, rule) for line, rule in src.selftest_marks}


# ---------------------------------------------------------------------------
# per-rule: bad fixture reports exactly its markers, good fixture nothing

_BAD_FIXTURES = [
    ("RA01", "ra01_bad.py"),
    ("RA02", "ra02_bad.py"),
    ("RA03", os.path.join("serve", "ra03_bad.py")),
    ("RA04", "ra04_bad.py"),
    ("RA05", "ra05_bad.py"),
]

_GOOD_FIXTURES = [
    ("RA01", "ra01_good.py"),
    ("RA02", "ra02_good.py"),
    ("RA03", os.path.join("serve", "ra03_good.py")),
    ("RA04", "ra04_good.py"),
    ("RA05", "ra05_good.py"),
]


@pytest.mark.parametrize("rule,rel", _BAD_FIXTURES)
def test_bad_fixture_exact_findings(rule, rel):
    path = os.path.join(FIXTURES, rel)
    expected = _marks(path, FIXTURES)
    assert expected, f"fixture {rel} carries no ra-selftest markers"
    assert all(r == rule for _, _, r in expected)
    result = run_analysis([path], root=FIXTURES)
    actual = {(f.path, f.line, f.rule) for f in result.findings}
    assert actual == expected, (
        f"{rule}: reported {sorted(actual)} != marked {sorted(expected)}")


@pytest.mark.parametrize("rule,rel", _GOOD_FIXTURES)
def test_good_fixture_is_clean(rule, rel):
    path = os.path.join(FIXTURES, rel)
    result = run_analysis([path], root=FIXTURES)
    assert result.findings == [], [f.render() for f in result.findings]


def test_ra06_bad_fixture_exact_findings():
    tree = os.path.join(FIXTURES, "ra06_bad")
    svc = os.path.join(tree, "serve", "svc.py")
    expected = _marks(svc, tree)
    result = run_analysis([tree], root=tree)
    actual = {(f.path, f.line, f.rule) for f in result.findings}
    assert actual == expected
    # the three drift families are all present in the messages
    msgs = " | ".join(f.message for f in result.findings)
    assert "OP_NAMES is missing OP_CLOSE" in msgs
    assert "does not handle OP_CLOSE" in msgs
    assert "not documented" in msgs or "drifted" in msgs


def test_ra06_good_fixture_is_clean():
    tree = os.path.join(FIXTURES, "ra06_good")
    result = run_analysis([tree], root=tree)
    assert result.findings == [], [f.render() for f in result.findings]


def test_selftest_whole_fixture_tree():
    ok, report = selftest(FIXTURES)
    assert ok, report


# ---------------------------------------------------------------------------
# waivers and baseline machinery

def test_waiver_suppresses_and_counts(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(
        "import threading, time\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "    def f(self):\n"
        "        with self._lock:\n"
        "            time.sleep(1)  # ra: disable=RA04(test waiver)\n")
    result = run_analysis([str(bad)], root=str(tmp_path))
    assert result.findings == []
    assert result.waived == 1


def test_def_level_waiver_covers_body(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(
        "import threading, time\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "    def f(self):  # ra: disable=RA04(whole function exempt)\n"
        "        with self._lock:\n"
        "            time.sleep(1)\n"
        "            time.sleep(2)\n")
    result = run_analysis([str(bad)], root=str(tmp_path))
    assert result.findings == []
    assert result.waived == 2


def test_baseline_roundtrip(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(
        "import time, threading\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "    def f(self):\n"
        "        with self._lock:\n"
        "            time.sleep(1)\n")
    result = run_analysis([str(bad)], root=str(tmp_path))
    assert len(result.findings) == 1
    baseline = load_baseline(format_baseline(result.findings))
    assert result.non_baselined(baseline) == []
    assert result.non_baselined(set()) == result.findings


def test_syntax_error_reports_ra00(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    result = run_analysis([str(bad)], root=str(tmp_path))
    assert [f.rule for f in result.findings] == ["RA00"]


# ---------------------------------------------------------------------------
# end-to-end over the real tree

def test_src_tree_is_clean():
    result = run_analysis([SRC_TREE], root=ROOT)
    assert result.findings == [], [f.render() for f in result.findings]
    # the waivers documented in docs/STATIC_ANALYSIS.md are really there
    assert result.waived > 0


def test_committed_baseline_is_byte_stable():
    result = run_analysis([SRC_TREE], root=ROOT)
    regenerated = format_baseline(result.findings).encode("utf-8")
    with open(BASELINE, "rb") as fh:
        committed = fh.read()
    assert committed == regenerated, (
        "analysis-baseline.txt is stale — regenerate with "
        "--write-baseline analysis-baseline.txt")


def test_wire_doc_matches_code():
    # RA06 runs against the real docs/WIRE_PROTOCOL.md; a clean tree
    # above already proves it, but assert the doc exists and carries all
    # eight opcodes so a doc deletion cannot slip through as "no rows"
    doc = os.path.join(ROOT, "docs", "WIRE_PROTOCOL.md")
    with open(doc, "r", encoding="utf-8") as fh:
        text = fh.read()
    for op in ("OP_OPEN", "OP_WRITE", "OP_READ", "OP_DELETE", "OP_STAT",
               "OP_CLOSE", "OP_STATS", "OP_HEALTH"):
        assert op in text, f"{op} missing from docs/WIRE_PROTOCOL.md"


# ---------------------------------------------------------------------------
# CLI contract (what make lint-invariants / CI actually run)

def _cli(*args, cwd=ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_clean_tree_exits_zero():
    proc = _cli("src/repro", "--baseline", "analysis-baseline.txt")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_fixture_violations_exit_nonzero():
    proc = _cli("tests/fixtures/analysis",
                "--root", "tests/fixtures/analysis")
    assert proc.returncode == 1, proc.stdout + proc.stderr
    # correct rule id and file:line on stdout for every rule
    for rule in ("RA01", "RA02", "RA03", "RA04", "RA05", "RA06"):
        assert rule in proc.stdout, f"{rule} missing from CLI output"
    assert "ra01_bad.py:14 RA01" in proc.stdout


def test_cli_selftest_mode():
    proc = _cli("--selftest", "tests/fixtures/analysis")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "selftest: OK" in proc.stdout


def test_cli_list_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rule in ("RA01", "RA02", "RA03", "RA04", "RA05", "RA06"):
        assert rule in proc.stdout
