"""RA02 fixture: raw read-modify-write on a CounterGroup.

Never imported — scanned by the analysis selftest only.
"""


class BadGateway:
    def __init__(self, stats):
        self.stats = stats

    def on_frame(self, nbytes):
        self.stats["frames"] += 1  # ra-selftest: RA02
        self.stats.setdefault("bytes_in", nbytes)  # ra-selftest: RA02
