"""RA01 fixture: a guarded attribute touched outside its lock.

Never imported — scanned by the analysis selftest only.
"""
import threading


class BadCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0  # guarded by self._lock

    def bump(self):
        self._n += 1  # ra-selftest: RA01

    def read(self):
        with self._lock:
            return self._n
