"""RA04 fixture (good): the lock covers only state mutation; blocking
work happens outside, on snapshots taken under the lock."""
import os
import queue
import threading
import time


class GoodFlusher:
    def __init__(self):
        self._lock = threading.Lock()
        self.writeq = queue.Queue(maxsize=8)
        self._dirty = b""

    def flush(self, fh, fut):
        with self._lock:
            data = self._dirty
            self._dirty = b""
            self.writeq.put(b"frame", block=False)  # non-blocking is fine
        fh.write(data)
        os.fsync(fh.fileno())
        time.sleep(0.01)
        return fut.result()
