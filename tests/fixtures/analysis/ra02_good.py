"""RA02 fixture (good): mutation through the atomic CounterGroup API;
plain assignment routes through Counter.set and is allowed."""


class GoodGateway:
    def __init__(self, stats):
        self.stats = stats
        self.stats["frames"] = 0

    def on_frame(self, nbytes):
        self.stats.inc("frames")
        self.stats.inc("bytes_in", nbytes)
        self.stats.max_update("peak_frame_bytes", nbytes)
