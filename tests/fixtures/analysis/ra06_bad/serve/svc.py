"""RA06 fixture: wire-table drift, three ways at once.

* the dispatch switch never handles OP_CLOSE (a close frame would hang);
* OP_NAMES skips OP_CLOSE (tracing labels silently lost);
* the documented table says OP_READ is 7 and has no OP_CLOSE row.

Never imported — scanned by the analysis selftest only.
"""

(OP_OPEN, OP_WRITE, OP_READ, OP_CLOSE) = range(4)  # ra-selftest: RA06

OP_NAMES = {OP_OPEN: "open", OP_WRITE: "write", OP_READ: "read"}  # ra-selftest: RA06


def _handle(op):  # ra-selftest: RA06
    if op == OP_OPEN:
        return "open"
    if op == OP_WRITE:
        return "write"
    if op == OP_READ:
        return "read"
    return None
