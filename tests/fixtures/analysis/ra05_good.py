"""RA05 fixture (good): the loop beats its Heartbeat each iteration and
parks before blocking; one-shot targets need no heartbeat at all."""
import threading


class GoodWorker:
    def __init__(self, heartbeat):
        self.stop = False
        self.hb = heartbeat
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._oneshot = threading.Thread(target=self._drain, daemon=True)

    def _loop(self):
        while not self.stop:
            self.hb.beat()
            self._step()
        self.hb.park()

    def _drain(self):
        # no while loop: a one-shot worker is outside RA05's scope
        self._step()

    def _step(self):
        pass
