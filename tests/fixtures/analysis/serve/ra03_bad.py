"""RA03 fixture: raw unpack of wire bytes, and a wire-decoded length
reaching an allocation before any bound check.

Never imported — scanned by the analysis selftest only.  Lives under
``serve/`` because RA03 only applies to wire/durable-format modules.
"""
import struct

_HDR = struct.Struct("!BIQ")


def decode_request(frame):
    op, session, length = _HDR.unpack_from(frame)  # ra-selftest: RA03
    return op, session, length


def read_payload(sock, header):
    if len(header) < 4:
        raise ValueError("short header")
    (n,) = struct.unpack("!I", header)
    return sock.recv(n)  # ra-selftest: RA03
