"""RA03 fixture (good): bounds check before unpack, domain error on
malformed bytes, and the length capped before allocation."""
import struct

_HDR = struct.Struct("!BIQ")
MAX_FRAME_BYTES = 64 << 20


class CodecError(ValueError):
    pass


def decode_request(frame):
    if len(frame) < _HDR.size:
        raise CodecError("truncated header")
    op, session, length = _HDR.unpack_from(frame)
    if length > MAX_FRAME_BYTES:
        raise CodecError("oversized payload")
    return op, session, bytes(frame[_HDR.size:_HDR.size + length])


def decode_trusted(frame):
    try:
        return _HDR.unpack_from(frame)
    except struct.error as e:
        raise CodecError(str(e)) from None


def read_payload(sock, header):
    if len(header) < 4:
        raise CodecError("short header")
    (n,) = struct.unpack("!I", header)
    if n > MAX_FRAME_BYTES:
        raise CodecError("oversized frame")
    return sock.recv(n)
