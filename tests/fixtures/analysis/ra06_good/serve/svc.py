"""RA06 fixture (good): opcodes, OP_NAMES, the dispatch switch, and the
documented table all agree."""

(OP_OPEN, OP_WRITE, OP_READ, OP_CLOSE) = range(4)

OP_NAMES = {OP_OPEN: "open", OP_WRITE: "write", OP_READ: "read",
            OP_CLOSE: "close"}


def _handle(op):
    if op == OP_OPEN:
        return "open"
    if op == OP_WRITE:
        return "write"
    if op == OP_READ:
        return "read"
    if op == OP_CLOSE:
        return "close"
    return None
