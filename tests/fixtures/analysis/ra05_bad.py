"""RA05 fixture: a looping thread target that never beats a Heartbeat.

Never imported — scanned by the analysis selftest only.
"""
import threading


class BadWorker:
    def __init__(self):
        self.stop = False
        self._thread = threading.Thread(target=self._main, daemon=True)  # ra-selftest: RA05

    def _main(self):
        # indirection on purpose: the checker chases the in-module call
        # graph, so hiding the while loop one call down doesn't help
        self._loop()

    def _loop(self):
        while not self.stop:
            self._step()

    def _step(self):
        pass
