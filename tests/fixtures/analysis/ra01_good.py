"""RA01 fixture (good): every touch of the guarded attribute is locked,
via the lock itself, a Condition alias, a `_locked` suffix, or an
explicit holds annotation."""
import threading


class GoodCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._n = 0  # guarded by self._lock

    def bump(self):
        with self._lock:
            self._n += 1

    def bump_via_alias(self):
        with self._cv:  # Condition(self._lock): same lock, two names
            self._n += 1
            self._cv.notify()

    def _drain_locked(self):
        return self._n

    def _predicate(self):  # ra: holds self._lock
        return self._n > 0
