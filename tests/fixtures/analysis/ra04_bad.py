"""RA04 fixture: blocking calls lexically inside `with <lock>:`.

Never imported — scanned by the analysis selftest only.
"""
import os
import queue
import threading
import time


class BadFlusher:
    def __init__(self):
        self._lock = threading.Lock()
        self.writeq = queue.Queue(maxsize=8)

    def flush(self, fh, fut):
        with self._lock:
            time.sleep(0.01)  # ra-selftest: RA04
            os.fsync(fh.fileno())  # ra-selftest: RA04
            self.writeq.put(b"frame")  # ra-selftest: RA04
            return fut.result()  # ra-selftest: RA04
