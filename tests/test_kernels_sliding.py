"""Sliding-window MD5 kernel vs per-window hashlib (paper-faithful CDC)."""
import hashlib

import numpy as np
import pytest

from repro.kernels import ops


@pytest.mark.parametrize("stride", [1, 2, 4])
def test_sliding_vs_hashlib(rng, stride):
    L, w = 2500, 48
    buf = rng.integers(0, 256, L, dtype=np.uint8)
    h = ops.sliding_window_hash(buf.tobytes(), window=w, stride=stride)
    n_off = (L - w) // stride + 1
    assert h.shape == (n_off,)
    idx = list(rng.integers(0, n_off, 12)) + [0, n_off - 1]
    for o in idx:
        bo = int(o) * stride
        want = int.from_bytes(
            hashlib.md5(buf[bo:bo + w].tobytes()).digest()[:4], "little")
        assert int(h[o]) == want, (stride, o)


@pytest.mark.parametrize("window", [16, 32, 48])
def test_sliding_window_sizes(rng, window):
    L = 1200
    buf = rng.integers(0, 256, L, dtype=np.uint8)
    h = ops.sliding_window_hash(buf.tobytes(), window=window, stride=4)
    for o in [0, 7, (L - window) // 4]:
        bo = o * 4
        want = int.from_bytes(
            hashlib.md5(buf[bo:bo + window].tobytes()).digest()[:4],
            "little")
        assert int(h[o]) == want


def test_sliding_matches_ref(rng):
    import jax.numpy as jnp
    from repro.kernels import ref
    L = 800
    buf = rng.integers(0, 256, L, dtype=np.uint8)
    got = ops.sliding_window_hash(buf.tobytes(), window=48, stride=1)
    want = np.asarray(ref.sliding_md5_ref(jnp.asarray(buf), 48, 1))
    np.testing.assert_array_equal(got, want)
