"""Crash/restart matrix for the durable metadata WAL + block stores
(ISSUE 7 tentpole).

Each scenario arms a deterministic fault (repro.core.faultinject), runs
a workload until the injected "process death", reopens the same data
directory with a fresh object graph, and asserts the crash-consistency
invariants:

  * every version committed before the crash reads back verified;
  * ``resync_refcounts`` is a no-op (replay agrees with commit logic);
  * no committed block was GC'd, and retrying writers dedup against
    adopted claims instead of double-storing.
"""
import hashlib
import os
import time

import pytest

from repro.core import (SAI, ClusterRuntime, CrashPoint, CrystalTPU,
                        FaultInjector, SAIConfig, StoreIOError, make_store)
from repro.core.castore import (REC_CLAIM_DONE, REC_COMMIT,
                                open_durable_store)


def _open(td, fault=None, **kw):
    kw.setdefault("n_nodes", 3)
    kw.setdefault("replication", 2)
    kw.setdefault("flush_interval_s", 0)    # inline fsync: deterministic
    return open_durable_store(str(td), fault=fault, **kw)


def _cfg(**kw):
    kw.setdefault("ca", "fixed")
    kw.setdefault("hasher", "cpu")
    kw.setdefault("block_size", 1024)
    return SAIConfig(**kw)


def _kill(mgr):
    """Simulated SIGKILL for whatever the armed fault didn't take down:
    the durable state on disk stops changing from here."""
    mgr.wal.crash()
    for node in mgr.nodes:
        node.store.crash()


def _assert_consistent(mgr, sai, expect):
    """expect: {path: bytes} — committed data that must survive."""
    assert sorted(mgr.files) == sorted(expect)
    for path, data in expect.items():
        assert sai.read(path, verify=True) == data
    assert mgr.resync_refcounts() == 0


# ---------------------------------------------------------------------------
# baseline durability (no fault)
# ---------------------------------------------------------------------------

def test_durable_write_survives_reopen(tmp_path):
    mgr, nodes, rep0 = _open(tmp_path)
    sai = SAI(mgr, _cfg())
    payload = {f"/f{i}": os.urandom(3000 + 100 * i) for i in range(3)}
    for p, d in payload.items():
        sai.write(p, d)
    assert rep0.replayed == 0
    mgr.close()

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.refcount_drift == 0
    _assert_consistent(mgr2, sai2, payload)
    # reopen again through the compaction snapshot close() took: the
    # tail must be near-empty
    mgr2.close()
    mgr3, _, rep3 = _open(tmp_path)
    assert rep3.snapshot_seq > 0 and rep3.replayed == 0
    _assert_consistent(mgr3, SAI(mgr3, _cfg()), payload)
    mgr3.close()


def test_durable_rewrite_dedups_no_double_store(tmp_path):
    mgr, nodes, _ = _open(tmp_path)
    sai = SAI(mgr, _cfg())
    data = os.urandom(4096)
    sai.write("/a", data)
    puts_before = [n.store.stats["puts"] for n in nodes]
    st = sai.write("/b", data)              # same content, new path
    assert st.new_blocks == 0 and st.dup_blocks > 0
    assert [n.store.stats["puts"] for n in nodes] == puts_before
    _assert_consistent(mgr, sai, {"/a": data, "/b": data})
    mgr.close()


# ---------------------------------------------------------------------------
# the crash matrix
# ---------------------------------------------------------------------------

def test_crash_mid_claim_releases_unstored_claims(tmp_path):
    """Die during the store stage: the CLAIM record is durable, the
    block bytes and CLAIM_DONE are not.  Recovery must release the
    half-open claims so a retrying writer isn't blocked."""
    fault = FaultInjector()
    mgr, nodes, _ = _open(tmp_path, fault=fault)
    sai = SAI(mgr, _cfg())
    keep = os.urandom(2500)
    sai.write("/keep", keep)
    # co-crash: the first block put dies, and the WAL dies with the
    # process before the abort CLAIM_DONE cleanup can reach disk
    fault.arm("blockstore.put", action="crash")
    fault.arm("wal.append", when={"kind": REC_CLAIM_DONE}, action="crash")
    with pytest.raises(CrashPoint):
        sai.write("/lost", os.urandom(3000))
    _kill(mgr)

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.released_claims and not rep.adopted_claims
    assert rep.dropped_pins > 0             # crashed writer's pins
    assert rep.refcount_drift == 0
    _assert_consistent(mgr2, sai2, {"/keep": keep})
    retry = os.urandom(3000)
    sai2.write("/lost", retry)              # claims were released
    _assert_consistent(mgr2, sai2, {"/keep": keep, "/lost": retry})
    mgr2.close()


def test_crash_mid_claim_adopts_resident_block(tmp_path):
    """Die between storing a claimed block and logging CLAIM_DONE: the
    bytes are on disk but unregistered.  Recovery adopts the claim —
    registers the surviving locations — so a retrying writer dedups
    instead of double-storing."""
    mgr, nodes, _ = _open(tmp_path)
    data = os.urandom(2048)
    digest = hashlib.md5(data).digest()
    locmap, claimed, _ = mgr.claim_blocks([digest])
    assert digest in claimed
    for nid in (0, 1):
        nodes[nid].put(digest, data)
        nodes[nid].flush()                  # data durable...
    _kill(mgr)                              # ...but CLAIM_DONE is not

    mgr2, nodes2, rep = _open(tmp_path)
    assert rep.adopted_claims == [digest] and not rep.released_claims
    assert mgr2.lookup_block(digest) == (0, 1)
    assert rep.refcount_drift == 0
    # a retrying writer claiming the digest dedup-hits the adoption
    puts = [n.store.stats["puts"] for n in nodes2]
    locmap2, claimed2, _ = mgr2.claim_blocks([digest])
    assert locmap2 == {digest: (0, 1)} and not claimed2
    assert [n.store.stats["puts"] for n in nodes2] == puts
    mgr2.close()


def test_crash_mid_commit(tmp_path):
    """Die on the COMMIT append: blocks may be durable but the version
    must not exist after recovery — and must not poison refcounts."""
    fault = FaultInjector()
    mgr, nodes, _ = _open(tmp_path, fault=fault)
    sai = SAI(mgr, _cfg())
    keep = os.urandom(5000)
    sai.write("/keep", keep)
    fault.kill_after("wal.append", 1, when={"kind": REC_COMMIT})
    with pytest.raises(CrashPoint):
        sai.write("/lost", os.urandom(4000))
    _kill(mgr)

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.refcount_drift == 0 and rep.dropped_pins > 0
    _assert_consistent(mgr2, sai2, {"/keep": keep})
    # the committed file survives a full GC sweep: its blocks are
    # referenced; the crashed write's registered orphans are reclaimed
    mgr2.gc_unreferenced()
    _assert_consistent(mgr2, sai2, {"/keep": keep})
    mgr2.close()


def test_crash_mid_gc(tmp_path):
    """Die between logging REC_GC and finishing the node-side drops:
    replay re-erases the registry entries and the recovery sweep
    reclaims whatever copies the crash left behind."""
    fault = FaultInjector()
    mgr, nodes, _ = _open(tmp_path, fault=fault)
    sai = SAI(mgr, _cfg())
    keep = os.urandom(3000)
    dead = os.urandom(3000)
    sai.write("/keep", keep)
    sai.write("/dead", dead)
    orphans = mgr.delete_file("/dead")
    assert orphans
    fault.arm("blockstore.drop", action="crash")
    with pytest.raises(CrashPoint):
        mgr.gc_collect(orphans)
    _kill(mgr)

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.refcount_drift == 0
    for d in orphans:                       # gone from metadata AND disk
        assert mgr2.lookup_block(d) == ()
        assert not any(n.store.has(d) for n in nodes2)
    _assert_consistent(mgr2, sai2, {"/keep": keep})
    mgr2.close()


def test_crash_mid_snapshot_falls_back_to_tail(tmp_path):
    """Die inside snapshot compaction: recovery must fall back to the
    previous snapshot (here: none) and a longer record tail."""
    fault = FaultInjector()
    mgr, nodes, _ = _open(tmp_path, fault=fault, snapshot_every=12)
    sai = SAI(mgr, _cfg())
    fault.arm("wal.snapshot", action="crash")
    committed = {}
    with pytest.raises(CrashPoint):
        for i in range(10):
            p, d = f"/f{i}", os.urandom(1500)
            sai.write(p, d)
            committed[p] = d                # durable_sync: commit is
            #                                 on disk once write returns
    _kill(mgr)

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.snapshot_seq == 0 and rep.replayed > 10
    assert rep.refcount_drift == 0
    # every write that returned before the crash is present; the write
    # the crash interrupted may have committed (the COMMIT record lands
    # before the snapshot attempt) — if so it must still verify
    assert set(committed) <= set(mgr2.files)
    for p, d in committed.items():
        assert sai2.read(p, verify=True) == d
    extra = set(mgr2.files) - set(committed)
    assert len(extra) <= 1
    for p in extra:
        sai2.read(p, verify=True)
    assert mgr2.resync_refcounts() == 0
    mgr2.close()


def test_crash_torn_commit_record(tmp_path):
    """A torn final COMMIT frame: recovery truncates the garbage and the
    half-written version never existed."""
    fault = FaultInjector()
    mgr, nodes, _ = _open(tmp_path, fault=fault)
    sai = SAI(mgr, _cfg())
    keep = os.urandom(2200)
    sai.write("/keep", keep)
    fault.arm("wal.append", when={"kind": REC_COMMIT}, action="torn")
    with pytest.raises(CrashPoint):
        sai.write("/lost", os.urandom(2200))
    _kill(mgr)

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.torn_tail and rep.refcount_drift == 0
    _assert_consistent(mgr2, sai2, {"/keep": keep})
    after = os.urandom(1000)
    sai2.write("/after", after)             # log resumes cleanly
    _assert_consistent(mgr2, sai2, {"/keep": keep, "/after": after})
    mgr2.close()


def test_crash_mid_repair(tmp_path):
    """Die while repair is re-replicating a quarantined block: after
    restart the quarantine is still known (REC_QUAR durable), the torn
    target segment is truncated, and a fresh runtime completes the
    repair."""
    fault = FaultInjector()
    mgr, nodes, _ = _open(tmp_path, fault=fault)
    sai = SAI(mgr, _cfg())
    data = os.urandom(900)                  # single block
    sai.write("/f", data)
    digest = mgr.files["/f"][-1].blocks[0].digest
    locs = mgr.lookup_block(digest)
    bad = locs[0]
    garbage = bytes([data[0] ^ 0xFF]) + data[1:]
    nodes[bad].store.put(digest, garbage, replace=True)
    nodes[bad].blocks[digest] = garbage
    mgr.quarantine_block(digest, bad)       # REC_QUAR durable

    eng = CrystalTPU(coalesce_window_s=0.02)
    try:
        runtime = ClusterRuntime(mgr, engine=eng)
        assert runtime.scan_under_replicated() == 1
        fault.arm("blockstore.put", action="crash")
        with pytest.raises(CrashPoint):
            runtime.repair_once()
    finally:
        eng.shutdown()
    _kill(mgr)

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.refcount_drift == 0
    assert digest in mgr2.quarantined       # quarantine survived
    assert nodes2[bad].tainted == {digest}  # corrupt copy re-tainted
    eng2 = CrystalTPU(coalesce_window_s=0.02)
    try:
        runtime2 = ClusterRuntime(mgr2, engine=eng2)
        assert runtime2.scan_under_replicated() >= 1
        assert runtime2.repair_once() >= 1
    finally:
        eng2.shutdown()
    healthy = [nid for nid in mgr2.lookup_block(digest)
               if mgr2.nodes[nid].has(digest)]
    assert len(healthy) >= mgr2.replication
    _assert_consistent(mgr2, sai2, {"/f": data})
    mgr2.close()


def test_crash_after_fsync_lied(tmp_path):
    """A lying fsync drops the tail records with the process, but the
    surviving prefix is still consistent: lost commits vanish whole,
    and their now-unreferenced block bytes are swept."""
    fault = FaultInjector()
    mgr, nodes, _ = _open(tmp_path, fault=fault)
    sai = SAI(mgr, _cfg())
    keep = os.urandom(2000)
    sai.write("/keep", keep)
    fault.arm("wal.fsync", action="skip", times=10_000)
    lost = os.urandom(2000)
    sai.write("/lost", lost)                # "durable" per the disk
    assert sai.read("/lost") == lost        # visible pre-crash
    _kill(mgr)

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert rep.refcount_drift == 0
    assert rep.dropped_unregistered >= 1    # /lost's block bytes swept
    _assert_consistent(mgr2, sai2, {"/keep": keep})
    mgr2.close()


def test_recovery_scrub_suspects_catches_trailing_corruption(tmp_path):
    """End-to-end recovery scrub: corrupt a block in the final segment
    on disk, reopen, hand report.suspects to the engine scrubber — it
    must quarantine exactly the corrupt copy."""
    mgr, nodes, _ = _open(tmp_path)
    sai = SAI(mgr, _cfg())
    data = os.urandom(800)
    sai.write("/f", data)
    digest = mgr.files["/f"][-1].blocks[0].digest
    bad = mgr.lookup_block(digest)[0]
    nodes[bad].store.put(digest, b"\x00" * len(data), replace=True)
    nodes[bad].store.flush()
    mgr.wal.crash()                         # skip close-time compaction
    mgr.close()

    mgr2, nodes2, rep = _open(tmp_path)
    sai2 = SAI(mgr2, _cfg())
    assert digest in rep.suspects[bad]
    eng = CrystalTPU(coalesce_window_s=0.02)
    try:
        runtime = ClusterRuntime(mgr2, engine=eng)
        res = runtime.scrub_suspects(rep.suspects)
        assert res["corrupt"] == 1
        assert runtime.repair_once() >= 1   # and repair heals it
    finally:
        eng.shutdown()
    _assert_consistent(mgr2, sai2, {"/f": data})
    mgr2.close()


# ---------------------------------------------------------------------------
# durability error surfacing + recovery performance
# ---------------------------------------------------------------------------

def test_write_async_surfaces_store_ioerror(tmp_path):
    """Satellite: a failed block put during the async pipeline's store
    stage lands on the WriteFuture as StoreIOError naming the path and
    digest."""
    mgr, nodes = make_store(3, replication=2)
    sai = SAI(mgr, _cfg())
    boom = PermissionError("disk says no")

    def bad_put(digest, data):
        raise boom
    for n in nodes:
        n.put = bad_put
    fut = sai.write_async("/doomed", os.urandom(2048))
    with pytest.raises(StoreIOError) as ei:
        fut.result(timeout=30)
    err = ei.value
    assert err.path == "/doomed" and len(err.digest) == 16
    assert err.__cause__ is boom
    assert "/doomed" in str(err) and err.digest.hex() in str(err)
    sai.close()


def test_recovery_replays_1k_tail_under_1s(tmp_path):
    """Acceptance: cold recovery of a 1k-record tail in under a second."""
    mgr, nodes, _ = _open(tmp_path, flush_interval_s=0.002,
                          snapshot_every=10 ** 9)
    sai = SAI(mgr, _cfg(durable_sync=False))
    for i in range(180):                    # 6 records per write
        sai.write(f"/f{i}", os.urandom(1100))
    mgr.wait_durable()
    assert mgr.wal.last_seq >= 1000
    mgr.wal.crash()                         # no close-time compaction
    mgr.close()

    t0 = time.perf_counter()
    mgr2, _, rep = _open(tmp_path)
    wall = time.perf_counter() - t0
    assert rep.replayed >= 1000 and rep.refcount_drift == 0
    assert wall < 1.0, f"cold recovery took {wall:.3f}s"
    assert sorted(mgr2.files) == sorted(f"/f{i}" for i in range(180))
    mgr2.close()
