"""Flash-attention forward kernel vs naive softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_fwd


def _naive_causal(q, k, v):
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    S, Sk = q.shape[1], k.shape[1]
    mask = jnp.arange(Sk)[None, :] <= jnp.arange(S)[:, None]
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("S,hd,bq,bk", [(256, 64, 64, 128),
                                        (512, 32, 128, 256),
                                        (128, 128, 128, 128)])
def test_flash_matches_naive(rng, S, hd, bq, bk):
    BH = 3
    q = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
    got = flash_attention_fwd(q, k, v, bq=bq, bk=bk)
    want = _naive_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16(rng):
    BH, S, hd = 2, 256, 64
    q = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.bfloat16)
    got = flash_attention_fwd(q, k, v, bq=128, bk=128)
    want = _naive_causal(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=3e-2, rtol=3e-2)


def test_flash_under_jit(rng):
    BH, S, hd = 2, 256, 64
    q = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
    f = jax.jit(lambda a, b, c: flash_attention_fwd(a, b, c, bq=128,
                                                    bk=128))
    got = f(q, k, v)
    want = _naive_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
