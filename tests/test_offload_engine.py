"""The unified offload engine + async write pipeline (ISSUE 1).

Covers the acceptance criteria: coalesced batch digests are identical to
the per-chunk CPU oracle, ``write_async`` matches sync ``write`` (stats,
stored bytes, read-back), dedup ratios are invariant under sync/async and
1-vs-N device configurations, fused launch counts stay below submitted
request counts for bursts and multi-leaf checkpoint saves, and empty
writes commit an empty block-map instead of crashing.
"""
import numpy as np
import pytest

from repro.core import CrystalTPU, SAI, SAIConfig, make_store
from repro.core.sai import block_digest_cpu
from repro.train.checkpoint import CACheckpointer


def _sai(engine=None, ca="fixed", hasher="tpu", **kw):
    mgr, nodes = make_store(4)
    cfg = SAIConfig(ca=ca, hasher=hasher, block_size=4096, avg_chunk=4096,
                    min_chunk=1024, max_chunk=16384, **kw)
    return SAI(mgr, cfg, crystal=engine), mgr


# ----------------------------------------------------------------------
# engine: coalescing correctness + launch accounting
# ----------------------------------------------------------------------
def test_coalesced_burst_digests_match_cpu(rng):
    """A burst of ragged direct requests fuses into fewer launches and
    every digest equals the per-chunk hashlib oracle."""
    eng = CrystalTPU(coalesce_window_s=0.1, max_batch=64)
    sai, _ = _sai(engine=eng)
    try:
        sizes = [100, 4096, 377, 2048, 8191, 64, 1500, 4097]
        chunk_sets = [[rng.integers(0, 256, s, dtype=np.uint8).tobytes()]
                      for s in sizes]
        handles = [sai._submit_hash(cs) for cs in chunk_sets]
        for handle, cs in zip(handles, chunk_sets):
            assert handle.wait() == [block_digest_cpu(c) for c in cs]
        stats = eng.snapshot_stats()
        assert stats["jobs"] == len(sizes)
        assert stats["launches"] < stats["jobs"]
        assert stats["coalesced"] == stats["jobs"] - stats["launches"]
    finally:
        eng.shutdown()


def test_coalescing_off_launches_per_request(rng):
    eng = CrystalTPU(coalesce=False)
    sai, _ = _sai(engine=eng)
    try:
        for _ in range(3):
            sai.write("/f", rng.integers(0, 256, 10_000,
                                         dtype=np.uint8).tobytes())
        stats = eng.snapshot_stats()
        assert stats["launches"] == stats["jobs"]
        assert stats["coalesced"] == 0
    finally:
        eng.shutdown()


@pytest.mark.parametrize("kind,meta", [("sliding", {"window": 48,
                                                    "stride": 4}),
                                       ("gear", {})])
def test_stream_burst_coalesces(rng, kind, meta):
    """A burst of >= 4 same-config sliding/gear jobs fuses into one
    padded multi-row launch; every result matches the single-job ops
    oracle (acceptance criterion)."""
    from repro.kernels import ops
    eng = CrystalTPU(coalesce_window_s=0.2, max_batch=64)
    try:
        bufs = [rng.integers(0, 256, 2048 + 512 * i, dtype=np.uint8)
                for i in range(6)]
        jobs = [eng.submit(kind, b, dict(meta)) for b in bufs]
        for j, b in zip(jobs, bufs):
            if kind == "sliding":
                want = ops.sliding_window_hash(b.tobytes(), 48, 4)
            else:
                want = ops.gear_hash(b.tobytes())
            np.testing.assert_array_equal(j.wait(), want)
        stats = eng.snapshot_stats()
        assert stats["jobs"] == len(bufs)
        assert stats["launches"] < stats["jobs"], stats
        assert stats["coalesced"] == stats["jobs"] - stats["launches"]
    finally:
        eng.shutdown()


def test_mixed_config_sliding_jobs_never_fuse(rng):
    """Sliding jobs with different window/stride have different fuse
    keys: all results stay correct (via the carry path)."""
    from repro.kernels import ops
    eng = CrystalTPU(coalesce_window_s=0.05)
    try:
        buf = rng.integers(0, 256, 4096, dtype=np.uint8)
        configs = [(48, 4), (32, 4), (48, 2), (48, 4)]
        jobs = [eng.submit("sliding", buf, {"window": w, "stride": s})
                for w, s in configs]
        for j, (w, s) in zip(jobs, configs):
            np.testing.assert_array_equal(
                j.wait(), ops.sliding_window_hash(buf.tobytes(), w, s))
    finally:
        eng.shutdown()


def test_short_stream_job_returns_empty(rng):
    """len(data) < window yields an empty hash array, not a crash."""
    eng = CrystalTPU()
    try:
        job = eng.submit("sliding", np.frombuffer(b"tiny", np.uint8),
                         {"window": 48, "stride": 4})
        assert job.wait().shape == (0,)
        gj = eng.submit("gear", np.frombuffer(b"xy", np.uint8), {})
        assert gj.wait().shape == (2,)
    finally:
        eng.shutdown()


def test_concurrent_identical_content_never_double_stores(rng):
    """Store lanes racing on the same novel digests: the claim protocol
    guarantees exactly one lane stores each block — placement, stored
    bytes, and new/dup accounting stay exact."""
    sai, mgr = _sai(hasher="cpu", store_lanes=4)
    data = rng.integers(0, 256, 40_000, dtype=np.uint8).tobytes()
    futs = [sai.write_async(f"/dup/p{i}", data) for i in range(8)]
    stats = [f.result(timeout=120) for f in futs]
    n_unique = len(mgr.block_registry)
    assert sum(s.new_blocks for s in stats) == n_unique
    total = sum(s.new_blocks + s.dup_blocks for s in stats)
    assert sum(s.dup_blocks for s in stats) == total - n_unique
    for locs in mgr.block_registry.values():
        assert len(locs) == 1              # replication=1: stored once
    assert mgr.stats()["stored_bytes"] == len(data)
    for i in range(8):
        assert sai.read(f"/dup/p{i}") == data
    sai.close()


def test_same_shape_jobs_across_managers_complete(rng):
    """Jobs must compare by identity, not array equality: two managers
    concurrently running same-shape jobs used to crash the manager
    thread on running-list membership (dataclass eq over numpy fields)
    and hang every waiter."""
    import jax
    eng = CrystalTPU(devices=list(jax.devices()) * 2)
    try:
        data = rng.integers(0, 256, 8192, dtype=np.uint8)
        from repro.kernels import ops
        want = ops.direct_hash(data.reshape(2, 4096))
        jobs = [eng.submit("direct", data, {"seg_bytes": 4096})
                for _ in range(4)]
        for j in jobs:
            np.testing.assert_array_equal(j.wait(), want)
    finally:
        eng.shutdown()


def test_max_fused_bytes_caps_stream_batches(rng):
    """The staging-byte budget bounds stream fusion: 6 8KB jobs under a
    16KB budget need >= 3 launches, results intact."""
    from repro.kernels import ops
    eng = CrystalTPU(coalesce_window_s=0.2, max_fused_bytes=16 << 10)
    try:
        bufs = [rng.integers(0, 256, 8192, dtype=np.uint8)
                for _ in range(6)]
        jobs = [eng.submit("sliding", b, {"window": 48, "stride": 4})
                for b in bufs]
        for j, b in zip(jobs, bufs):
            np.testing.assert_array_equal(
                j.wait(), ops.sliding_window_hash(b.tobytes(), 48, 4))
        assert eng.snapshot_stats()["launches"] >= 3
    finally:
        eng.shutdown()


def test_max_fused_rows_caps_direct_batches(rng):
    """The fused-row cap bounds the padded staging matrix: 6 two-row
    jobs under a 4-row cap need at least 3 launches, results intact."""
    from repro.kernels import ops
    eng = CrystalTPU(coalesce_window_s=0.2, max_fused_rows=4)
    try:
        data = rng.integers(0, 256, 8192, dtype=np.uint8)
        jobs = [eng.submit("direct", data, {"seg_bytes": 4096})
                for _ in range(6)]
        want = ops.direct_hash(data.reshape(2, 4096))
        for j in jobs:
            np.testing.assert_array_equal(j.wait(), want)
        assert eng.snapshot_stats()["launches"] >= 3
    finally:
        eng.shutdown()


def test_store_lanes_commit_all_paths(rng):
    """Sharded store lanes: concurrent writers to many paths all commit,
    and per-path version order still matches submission order."""
    sai, mgr = _sai(hasher="cpu", store_lanes=3)
    payloads = [bytes([i]) * 4000 for i in range(9)]
    futs = [sai.write_async(f"/lane{i % 3}", p)
            for i, p in enumerate(payloads)]
    for f in futs:
        f.result(timeout=120)
    for p in range(3):
        assert mgr.num_versions(f"/lane{p}") == 3
        for v in range(3):
            assert sai.read(f"/lane{p}", version=v) == payloads[3 * v + p]
    sai.close()


def test_mixed_kind_burst_preserves_all_results(rng):
    """Direct jobs coalesce around interleaved sliding/gear jobs (the
    carry path) without losing or corrupting any result."""
    eng = CrystalTPU(coalesce_window_s=0.05)
    try:
        data = rng.integers(0, 256, 8192, dtype=np.uint8)
        jobs = []
        for i in range(3):
            jobs.append(("direct", eng.submit("direct", data,
                                              {"seg_bytes": 4096})))
            jobs.append(("gear", eng.submit("gear", data, {})))
        from repro.kernels import ops
        want_direct = ops.direct_hash(data.reshape(2, 4096))
        want_gear = ops.gear_hash(data.tobytes())
        for kind, job in jobs:
            got = job.wait()
            if kind == "direct":
                np.testing.assert_array_equal(got, want_direct)
            else:
                np.testing.assert_array_equal(got, want_gear)
    finally:
        eng.shutdown()


# ----------------------------------------------------------------------
# write_async == write
# ----------------------------------------------------------------------
@pytest.mark.parametrize("ca", ["fixed", "cdc-gear", "none"])
def test_write_async_equals_sync(rng, ca):
    datas = [rng.integers(0, 256, n, dtype=np.uint8).tobytes()
             for n in (30_000, 10_000, 30_000)]   # third dups the first
    sai_s, mgr_s = _sai(ca=ca)
    sai_a, mgr_a = _sai(ca=ca)
    sync_stats = [sai_s.write(f"/f{i}", d) for i, d in enumerate(datas)]
    futs = [sai_a.write_async(f"/f{i}", d) for i, d in enumerate(datas)]
    async_stats = [f.result(timeout=120) for f in futs]
    for st_s, st_a in zip(sync_stats, async_stats):
        assert (st_s.total_bytes, st_s.new_bytes, st_s.new_blocks,
                st_s.dup_blocks) == (st_a.total_bytes, st_a.new_bytes,
                                     st_a.new_blocks, st_a.dup_blocks)
    for i, d in enumerate(datas):
        assert sai_a.read(f"/f{i}") == d
    assert mgr_s.stats()["stored_bytes"] == mgr_a.stats()["stored_bytes"]
    assert mgr_s.stats()["unique_blocks"] == mgr_a.stats()["unique_blocks"]


def test_write_async_orders_versions(rng):
    """Back-to-back async writes to one path commit in submission order."""
    sai, mgr = _sai(hasher="cpu")
    payloads = [bytes([i]) * 5000 for i in range(5)]
    futs = [sai.write_async("/v", p) for p in payloads]
    for f in futs:
        f.result(timeout=120)
    assert mgr.num_versions("/v") == 5
    for i, p in enumerate(payloads):
        assert sai.read("/v", version=i) == p


def test_dedup_invariant_across_devices_and_modes(rng):
    """Dedup ratio depends only on content — not on sync vs async nor on
    how many engine managers/devices service the hash requests."""
    import jax
    base = rng.integers(0, 256, 50_000, dtype=np.uint8)
    mod = base.copy()
    mod[:5000] = rng.integers(0, 256, 5000, dtype=np.uint8)
    ratios = []
    for devices, use_async in ((None, False), (list(jax.devices()) * 3,
                                               False), (None, True)):
        eng = CrystalTPU(devices=devices, coalesce_window_s=0.02)
        sai, _ = _sai(engine=eng)
        try:
            if use_async:
                sai.write_async("/f", base.tobytes()).result(timeout=120)
                st = sai.write_async("/f", mod.tobytes()).result(timeout=120)
            else:
                sai.write("/f", base.tobytes())
                st = sai.write("/f", mod.tobytes())
            ratios.append((st.similarity, st.new_bytes, st.dup_blocks))
        finally:
            eng.shutdown()
    assert ratios[0] == ratios[1] == ratios[2]
    assert ratios[0][0] > 0.5          # most blocks unchanged -> dup


# ----------------------------------------------------------------------
# empty writes
# ----------------------------------------------------------------------
@pytest.mark.parametrize("ca", ["fixed", "cdc", "cdc-gear"])
def test_empty_write_commits_empty_blockmap(ca):
    sai, mgr = _sai(ca=ca, hasher="cpu")
    st = sai.write("/empty", b"")
    assert (st.new_blocks, st.dup_blocks, st.new_bytes) == (0, 0, 0)
    assert sai.read("/empty") == b""
    assert mgr.num_versions("/empty") == 1
    fut = sai.write_async("/empty", b"")
    assert fut.result(timeout=120).new_blocks == 0
    assert sai.read("/empty") == b""


def test_empty_write_tpu_path():
    sai, _ = _sai(ca="fixed", hasher="tpu",
                  engine=None)       # shared default engine
    assert sai.write("/e", b"").new_blocks == 0
    assert sai.read("/e") == b""


# ----------------------------------------------------------------------
# checkpoint save: batched streaming submission
# ----------------------------------------------------------------------
def test_checkpoint_save_coalesces_and_restores(rng):
    eng = CrystalTPU(coalesce_window_s=0.05)
    sai, _ = _sai(engine=eng, ca="fixed")
    try:
        params = {f"layer{i}": rng.standard_normal(3000).astype(np.float32)
                  for i in range(8)}
        ckpt = CACheckpointer(sai)
        rec = ckpt.save(11, params)
        stats = eng.snapshot_stats()
        # fused launch count < submitted request count (acceptance)
        assert stats["launches"] < stats["jobs"], stats
        assert rec["total_bytes"] == sum(p.nbytes for p in params.values())
        step, state, _ = ckpt.restore()
        assert step == 11
        for k, v in params.items():
            np.testing.assert_array_equal(state["params"][k], v)
    finally:
        eng.shutdown()


def test_submit_after_shutdown_raises():
    eng = CrystalTPU()
    eng.shutdown()
    with pytest.raises(RuntimeError):
        eng.submit("direct", np.zeros(8, np.uint8), {"seg_bytes": 4})


def test_default_engine_recreated_after_shutdown():
    from repro.core.crystal import default_engine
    e1 = default_engine()
    e1.shutdown()
    e2 = default_engine()
    assert e2 is not e1 and e2._alive


def test_shutdown_idempotent(rng):
    """ISSUE 4 satellite: repeat shutdown() calls are no-ops — no
    double-posted sentinels, no re-joins — and in-flight work still
    completes before the first shutdown drains the queue."""
    eng = CrystalTPU()
    data = rng.integers(0, 256, 4096, dtype=np.uint8)
    job = eng.submit("direct", data, {"seg_bytes": 4096})
    eng.shutdown()
    eng.shutdown()
    eng.shutdown()
    assert job.wait().shape == (1, 16)
    assert not eng._alive
    # managers joined exactly once; queue holds no stray sentinels
    assert all(not t.is_alive() for t in eng._managers)
    assert eng.outstanding._sentinels == 0
    assert all(d.queue._sentinels == 0 for d in eng._dev_states)


def test_default_engine_registers_atexit_shutdown():
    """ISSUE 4 satellite: creating the process-wide default engine
    registers the atexit hook, so interpreter exit never races live
    manager threads; the hook itself is safe to run repeatedly and
    against an explicitly shut-down engine."""
    from repro.core import crystal as crystal_mod
    eng = crystal_mod.default_engine()
    assert crystal_mod._ATEXIT_REGISTERED
    crystal_mod._shutdown_default_engine()       # what atexit will run
    assert not eng._alive
    assert crystal_mod._DEFAULT is None
    crystal_mod._shutdown_default_engine()       # idempotent, no default
    e2 = crystal_mod.default_engine()            # recreated on next use
    assert e2._alive
    e2.shutdown()


def test_carried_job_completes_across_shutdown(rng):
    """A non-direct job popped as the coalescing carry must still run
    even if shutdown() lands while the fused batch executes."""
    eng = CrystalTPU(coalesce_window_s=0.2)
    data = rng.integers(0, 256, 4096, dtype=np.uint8)
    d1 = eng.submit("direct", data, {"seg_bytes": 4096})
    g = eng.submit("gear", data, {})          # becomes the carry
    d1.wait()
    eng.shutdown()                            # while/after batch runs
    assert g.wait().shape == (4096,)


def test_pipeline_close_and_restart(rng):
    sai, _ = _sai(hasher="cpu")
    sai.write_async("/a", b"x" * 10_000).result(timeout=120)
    sai.close()
    assert sai._pipe_threads == []
    sai.write_async("/b", b"y" * 10_000).result(timeout=120)
    assert sai.read("/b") == b"y" * 10_000
    sai.close()
    sai.close()                               # idempotent


def test_sai_has_no_direct_kernel_calls():
    """All hashing flows through the engine: sai.py must not call the
    kernel ops layer directly (acceptance criterion)."""
    import inspect
    import repro.core.sai as sai_mod
    src = inspect.getsource(sai_mod)
    assert "ops.direct_hash" not in src
    assert "from repro.kernels" not in src
