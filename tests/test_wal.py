"""WAL framing, snapshot compaction, block-store persistence, and the
fault-injection harness (ISSUE 7).

The framing tests mirror the gateway codec-fuzz discipline
(tests/test_gateway.py): hostile bytes — truncated length prefixes, bad
CRCs, trailing garbage, zero-length records — must stop replay cleanly
at the last good record, never surface ``struct.error``/``IndexError``.
"""
import os
import random
import struct
import zlib

import pytest

from repro.core.blockstore import BlockStore
from repro.core.faultinject import CrashPoint, FaultInjector, tear_tail
from repro.core.wal import (MAX_RECORD_BYTES, WALError, WriteAheadLog,
                            encode_frame, iter_frames)
from repro.core import castore


def _records(n, start=1):
    return [(start + i, 1 + (i % 5), bytes([i % 251]) * (i % 37))
            for i in range(n)]


def _log_bytes(recs):
    return b"".join(encode_frame(seq, kind, body)
                    for seq, kind, body in recs)


# ---------------------------------------------------------------------------
# frame codec vs hostile bytes
# ---------------------------------------------------------------------------

def test_frame_roundtrip():
    recs = _records(20)
    out = [(s, k, b) for s, k, b, _ in iter_frames(_log_bytes(recs))]
    assert out == recs


def test_truncated_length_prefix_stops_cleanly():
    buf = _log_bytes(_records(3))
    # every truncation point inside the trailing frame's header
    for cut in range(1, 8):
        got = list(iter_frames(buf + buf[:cut]))
        assert len(got) == 3           # never raises, never over-reads


def test_truncated_payload_stops_at_last_good():
    recs = _records(5)
    buf = _log_bytes(recs)
    tail = encode_frame(6, 1, b"x" * 100)
    for cut in range(9, len(tail)):    # header present, payload short
        got = list(iter_frames(buf + tail[:cut]))
        assert [(s, k, b) for s, k, b, _ in got] == recs


def test_bad_crc_stops_replay():
    buf = bytearray(_log_bytes(_records(4)))
    # flip one bit in the third frame's payload
    frames = list(iter_frames(bytes(buf)))
    third_start = frames[1][3]
    buf[third_start + 12] ^= 0x40
    got = list(iter_frames(bytes(buf)))
    assert len(got) == 2


def test_zero_length_record_stops_replay():
    buf = _log_bytes(_records(2))
    evil = struct.Struct("<II").pack(0, zlib.crc32(b""))
    got = list(iter_frames(buf + evil + _log_bytes(_records(2, start=10))))
    assert len(got) == 2               # zero-length stops; later valid
    #                                    frames after the gap are NOT
    #                                    trusted


def test_giant_length_stops_replay():
    buf = _log_bytes(_records(2))
    evil = struct.Struct("<II").pack(MAX_RECORD_BYTES + 1, 0)
    assert len(list(iter_frames(buf + evil + b"\x00" * 64))) == 2


def test_non_monotonic_seq_stops_replay():
    buf = _log_bytes([(1, 1, b"a"), (2, 1, b"b"), (2, 1, b"c")])
    assert len(list(iter_frames(buf))) == 2


def test_frame_fuzz_random_truncation_and_garbage():
    """Codec-fuzz style: random truncations and random garbage tails
    always yield a clean prefix of the original records."""
    recs = _records(12)
    buf = _log_bytes(recs)
    r = random.Random(0)
    for _ in range(200):
        cut = r.randrange(len(buf) + 1)
        junk = bytes(r.randrange(256) for _ in range(r.randrange(16)))
        got = [(s, k, b) for s, k, b, _ in iter_frames(buf[:cut] + junk)]
        assert got == recs[:len(got)]  # always a prefix, never a raise


# ---------------------------------------------------------------------------
# record payload codecs (castore semantics layer)
# ---------------------------------------------------------------------------

def test_record_codecs_roundtrip():
    d1, d2 = os.urandom(16), os.urandom(16)
    fv = castore.FileVersion(
        blocks=[castore.BlockMeta(d1, 4096, (0, 2)),
                castore.BlockMeta(d2, 100, (1,))],
        total_len=4196, timestamp=123.5, merkle_root=os.urandom(16))
    path, got = castore.dec_commit(castore.enc_commit("/a/b", fv))
    assert path == "/a/b" and got.total_len == 4196
    assert got.timestamp == 123.5 and got.merkle_root == fv.merkle_root
    assert [(b.digest, b.length, b.nodes) for b in got.blocks] == \
        [(d1, 4096, (0, 2)), (d2, 100, (1,))]

    assert castore.dec_retire(castore.enc_retire("/x", 3)) == ("/x", 3)
    assert castore.dec_digest_list(
        castore.enc_digest_list([d1, d2])) == [d1, d2]
    assert castore.dec_digest_nodes(
        castore.enc_digest_nodes(d1, (1, 2))) == (d1, (1, 2))
    assert castore.dec_digest_node(
        castore.enc_digest_node(d2, 7)) == (d2, 7)


def test_record_codecs_hostile_bytes_raise_walerror_only():
    d = os.urandom(16)
    bodies = [castore.enc_commit("/p", castore.FileVersion(
                  blocks=[castore.BlockMeta(d, 10, (0,))], total_len=10,
                  merkle_root=os.urandom(16))),
              castore.enc_retire("/p", 1),
              castore.enc_digest_list([d, os.urandom(16)]),
              castore.enc_digest_nodes(d, (0, 1)),
              castore.enc_digest_node(d, 3)]
    decoders = [castore.dec_commit, castore.dec_retire,
                castore.dec_digest_list, castore.dec_digest_nodes,
                castore.dec_digest_node]
    r = random.Random(1)
    for body, dec in zip(bodies, decoders):
        for cut in range(len(body)):
            with pytest.raises(WALError):
                dec(body[:cut])
        with pytest.raises(WALError):       # trailing garbage
            dec(body + b"\x00")
        for _ in range(50):                 # random corruption
            mut = bytearray(body)
            for _ in range(r.randrange(1, 4)):
                mut[r.randrange(len(mut))] = r.randrange(256)
            try:
                dec(bytes(mut))
            except WALError:
                pass                        # struct.error/IndexError fail


def test_bad_record_kind_in_replay_counts_and_stops(tmp_path):
    mgr, nodes, _ = castore.open_durable_store(str(tmp_path), n_nodes=1,
                                               flush_interval_s=0)
    mgr.wal.append(200, b"future-kind")     # unknown record kind
    mgr.wal.append(castore.REC_RETIRE, b"\x01")  # truncated body
    mgr.wal.crash()                         # die before compaction can
    mgr.close()                             # tidy the junk tail away
    mgr2, _, rep = castore.open_durable_store(str(tmp_path), n_nodes=1)
    assert rep.bad_records == 1             # stopped at first bad record
    mgr2.close()


# ---------------------------------------------------------------------------
# WriteAheadLog behaviour
# ---------------------------------------------------------------------------

def test_wal_append_sync_reopen(tmp_path):
    wal = WriteAheadLog(str(tmp_path), flush_interval_s=0.001)
    seqs = [wal.append(k % 3 + 1, bytes([k])) for k in range(50)]
    assert seqs == list(range(1, 51))
    wal.sync()
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path))
    assert [(s, b) for s, _, b in wal2.recovered_records] == \
        [(k + 1, bytes([k])) for k in range(50)]
    assert not wal2.torn_tail
    assert wal2.append(1, b"more") == 51    # appends resume past tail
    wal2.close()


def test_wal_inline_fsync_mode(tmp_path):
    wal = WriteAheadLog(str(tmp_path), flush_interval_s=0)
    wal.append(1, b"a")
    wal.sync()                              # immediate no-op
    wal.close()
    assert len(WriteAheadLog(str(tmp_path)).recovered_records) == 1


def test_wal_torn_tail_truncated_on_reopen(tmp_path):
    wal = WriteAheadLog(str(tmp_path), flush_interval_s=0)
    for k in range(5):
        wal.append(1, os.urandom(64))
    log_path = wal._active_path
    wal.close()
    tear_tail(log_path, keep_frac=0.5)
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.torn_tail
    assert 0 < len(wal2.recovered_records) < 5
    n = len(wal2.recovered_records)
    wal2.append(2, b"after-tear")           # clean append boundary
    wal2.close()
    wal3 = WriteAheadLog(str(tmp_path))
    assert len(wal3.recovered_records) == n + 1
    assert wal3.recovered_records[-1][2] == b"after-tear"
    wal3.close()


def test_wal_snapshot_compacts_and_replays(tmp_path):
    wal = WriteAheadLog(str(tmp_path), flush_interval_s=0)
    for k in range(10):
        wal.append(1, bytes([k]))
    snap_seq = wal.snapshot(b"state-at-10")
    assert snap_seq == 10 and wal.records_since_snapshot == 0
    for k in range(3):
        wal.append(2, bytes([100 + k]))
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.recovered_snapshot == b"state-at-10"
    assert wal2.recovered_seq == 10
    assert [b for _, _, b in wal2.recovered_records] == \
        [bytes([100 + k]) for k in range(3)]
    # old log files were purged
    logs = [n for n in os.listdir(str(tmp_path)) if n.startswith("wal-")]
    assert len(logs) == 1
    wal2.close()


def test_wal_fsync_skip_loses_unwritten_records(tmp_path):
    """A lying fsync (action='skip') reports durability but loses the
    bytes with the process — recovery still lands on a clean prefix."""
    fault = FaultInjector()
    wal = WriteAheadLog(str(tmp_path), flush_interval_s=0, fault=fault)
    wal.append(1, b"durable")
    fault.arm("wal.fsync", action="skip", times=1000)
    wal.append(1, b"lost-1")
    wal.append(1, b"lost-2")
    wal.sync()                              # "succeeds" — disk lied
    wal.crash()
    wal2 = WriteAheadLog(str(tmp_path))
    assert [b for _, _, b in wal2.recovered_records] == [b"durable"]
    wal2.close()


def test_wal_crash_point_kill_after_n_records(tmp_path):
    fault = FaultInjector()
    wal = WriteAheadLog(str(tmp_path), flush_interval_s=0, fault=fault)
    fault.kill_after("wal.append", 3)
    wal.append(1, b"a")
    wal.append(1, b"b")
    with pytest.raises(CrashPoint):
        wal.append(1, b"c")
    with pytest.raises(CrashPoint):         # dead stays dead
        wal.append(1, b"d")
    wal2 = WriteAheadLog(str(tmp_path))
    assert len(wal2.recovered_records) == 2
    wal2.close()


def test_wal_torn_append_action(tmp_path):
    fault = FaultInjector()
    wal = WriteAheadLog(str(tmp_path), flush_interval_s=0, fault=fault)
    wal.append(1, b"good")
    fault.arm("wal.append", action="torn")
    with pytest.raises(CrashPoint):
        wal.append(1, b"torn-record" * 10)
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.torn_tail
    assert [b for _, _, b in wal2.recovered_records] == [b"good"]
    wal2.append(1, b"resumed")              # truncated to a clean boundary
    wal2.close()


# ---------------------------------------------------------------------------
# FaultInjector semantics
# ---------------------------------------------------------------------------

def test_fault_injector_when_filter_and_times():
    inj = FaultInjector()
    inj.arm("site", after=2, when={"kind": 7}, times=2, action="skip")
    assert inj.fire("site", kind=1) is None      # non-matching: no count
    assert inj.fire("site", kind=7) is None      # hit 1 of matching
    assert inj.fire("site", kind=7) == "skip"    # hit 2 -> trigger
    assert inj.fire("site", kind=7) == "skip"    # times=2
    assert inj.fire("site", kind=7) is None      # exhausted
    assert inj.hits["site"] == 5


def test_fault_injector_callable_action():
    inj = FaultInjector()
    seen = []
    inj.arm("s", action=lambda **ctx: seen.append(ctx) or "custom")
    assert inj.fire("s", digest=b"x") == "custom"
    assert seen[0]["digest"] == b"x"


# ---------------------------------------------------------------------------
# BlockStore persistence
# ---------------------------------------------------------------------------

def test_blockstore_roundtrip_and_dedup(tmp_path):
    bs = BlockStore(str(tmp_path))
    d1, d2 = os.urandom(16), os.urandom(16)
    bs.put(d1, b"one")
    bs.put(d2, b"two" * 100)
    bs.put(d1, b"one")                      # content-addressed no-op
    assert bs.stats["skipped_puts"] == 1
    assert bs.get(d1) == b"one"             # served from the write buffer
    bs.flush()
    assert bs.get(d2) == b"two" * 100       # served from disk
    assert sorted(bs.digests()) == sorted([d1, d2])
    bs.close()
    bs2 = BlockStore(str(tmp_path))         # scan re-derives the index
    assert bs2.get(d1) == b"one"
    assert bs2.get(d2) == b"two" * 100
    assert set(bs2.suspects) <= {d1, d2}    # final-segment residents
    bs2.close()


def test_blockstore_replace_and_tombstone(tmp_path):
    bs = BlockStore(str(tmp_path))
    d = os.urandom(16)
    bs.put(d, b"corrupt")
    bs.put(d, b"repaired", replace=True)
    assert bs.get(d) == b"repaired" and bs.stats["replaced"] == 1
    d2 = os.urandom(16)
    bs.put(d2, b"gone")
    bs.drop(d2)
    assert not bs.has(d2)
    bs.close()
    bs2 = BlockStore(str(tmp_path))
    assert bs2.get(d) == b"repaired"        # later record wins the scan
    assert not bs2.has(d2)                  # tombstone survived
    bs2.close()


def test_blockstore_segment_rotation_limits_suspects(tmp_path):
    bs = BlockStore(str(tmp_path), segment_bytes=1024)
    digs = [os.urandom(16) for _ in range(8)]
    for d in digs:
        bs.put(d, os.urandom(400))          # ~2 blocks per segment
    bs.close()
    bs2 = BlockStore(str(tmp_path), segment_bytes=1024)
    assert sorted(bs2.digests()) == sorted(digs)
    # only the FINAL segment's blocks are suspect — rotation fsyncs
    assert 0 < len(bs2.suspects) < len(digs)
    bs2.close()


def test_blockstore_torn_segment_truncated(tmp_path):
    bs = BlockStore(str(tmp_path))
    d1, d2 = os.urandom(16), os.urandom(16)
    bs.put(d1, b"a" * 200)
    bs.put(d2, b"b" * 200)
    bs.close()
    seg = os.path.join(str(tmp_path), sorted(
        n for n in os.listdir(str(tmp_path)) if n.startswith("seg-"))[-1])
    tear_tail(seg, keep_frac=0.6)           # tear through d2's record
    bs2 = BlockStore(str(tmp_path))
    assert bs2.get(d1) == b"a" * 200
    assert not bs2.has(d2)
    assert bs2.stats["truncated_bytes"] > 0
    d3 = os.urandom(16)
    bs2.put(d3, b"after")                   # appends resume cleanly
    bs2.flush()
    assert bs2.get(d3) == b"after"
    bs2.close()


def test_blockstore_torn_put_action(tmp_path):
    fault = FaultInjector()
    bs = BlockStore(str(tmp_path), fault=fault)
    d1 = os.urandom(16)
    bs.put(d1, b"whole")
    fault.arm("blockstore.put", action="torn")
    with pytest.raises(CrashPoint):
        bs.put(os.urandom(16), b"partial-segment-write" * 50)
    bs2 = BlockStore(str(tmp_path))
    assert bs2.get(d1) == b"whole"          # torn record truncated away
    assert len(bs2.digests()) == 1
    bs2.close()
