"""Direct-hashing kernel: shape/dtype sweep vs the pure-jnp oracle AND
hashlib ground truth."""
import hashlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("seg_bytes", [64, 128, 512, 1024, 4096, 16384])
def test_direct_hash_vs_hashlib(rng, seg_bytes):
    N = 5
    segs = rng.integers(0, 256, (N, seg_bytes), dtype=np.uint8)
    digs = ops.direct_hash(segs)
    for i in range(N):
        assert digs[i].tobytes() == hashlib.md5(segs[i].tobytes()).digest()


def test_direct_hash_ragged_lengths(rng):
    seg = 2048
    N = 9
    segs = rng.integers(0, 256, (N, seg), dtype=np.uint8)
    lens = (rng.integers(1, seg // 4 + 1, N) * 4).astype(np.int64)
    digs = ops.direct_hash(segs, lens)
    for i in range(N):
        want = hashlib.md5(segs[i, :lens[i]].tobytes()).digest()
        assert digs[i].tobytes() == want


def test_kernel_matches_ref_oracle(rng):
    """Pallas kernel vs ref.py pure-jnp oracle on identical word input.
    Kernel contract: the word buffer must cover message + 3 padding words
    (the ops wrapper guarantees this; here lens <= W - 3)."""
    from repro.kernels.md5 import md5_pallas
    N, W = 128, 64
    data = rng.integers(0, 2 ** 32, (N, W), dtype=np.uint32)
    lens = rng.integers(1, W - 2, N).astype(np.int32)
    want = np.asarray(ref.md5_words_ref(jnp.asarray(data),
                                        jnp.asarray(lens)))
    got = np.asarray(md5_pallas(jnp.asarray(data.T),
                                jnp.asarray(lens))).T
    np.testing.assert_array_equal(got, want)


def test_batch_padding_lanes(rng):
    """N not a multiple of TILE_N exercises lane padding."""
    segs = rng.integers(0, 256, (3, 256), dtype=np.uint8)
    digs = ops.direct_hash(segs)
    assert digs.shape == (3, 16)
    for i in range(3):
        assert digs[i].tobytes() == hashlib.md5(segs[i].tobytes()).digest()


def test_hash_blocks_final_digest(rng):
    data = rng.integers(0, 256, 50_000, dtype=np.uint8).tobytes()
    digs, final = ops.hash_blocks(data, 4096)
    assert digs.shape[0] == 13
    assert final == hashlib.md5(digs.tobytes()).digest()
    # first full block must equal plain hashlib
    assert digs[0].tobytes() == hashlib.md5(data[:4096]).digest()


def test_empty_and_single_word(rng):
    segs = np.zeros((1, 4), np.uint8)
    digs = ops.direct_hash(segs, np.array([4]))
    assert digs[0].tobytes() == hashlib.md5(b"\x00" * 4).digest()
