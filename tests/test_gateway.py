"""Multi-tenant storage gateway (ISSUE 4).

Covers the acceptance criteria: the wire codec round-trips every
request/response shape; a burst from >= 4 concurrent client sessions
shows cross-client coalescing (engine ``launches < jobs``); with two
equal-weight tenants — one flooding, one trickling — the trickler's
completed-request share stays within 2x of its weight share while the
flooder gets RetryLater backpressure and its queue stays bounded; QoS
classes map onto the engine's priority lanes; and the gateway can own a
cluster runtime whose scrub/repair heals injected corruption behind the
same front end.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import CrystalTPU, NodeRuntimeConfig, SAIConfig, make_store
from repro.serve import storage_service as svc
from repro.serve.storage_client import (GatewayClient, GatewayError,
                                        RetryLater)
from repro.serve.storage_service import GatewayConfig, StorageGateway


def _sai_cfg(**kw):
    return SAIConfig(ca="fixed", hasher="tpu", block_size=4096,
                     avg_chunk=4096, min_chunk=1024, max_chunk=16384, **kw)


def _gateway(mgr, engine, **kw):
    cfg = dict(sai=_sai_cfg())
    cfg.update(kw)
    return StorageGateway(mgr, engine=engine, config=GatewayConfig(**cfg))


# ----------------------------------------------------------------------
# wire-format codec
# ----------------------------------------------------------------------
def test_wire_codec_roundtrip_requests():
    cases = [
        (svc.OP_OPEN, 0, 1,
         dict(tenant="acme", qos="batch", weight=2.5,
              token=b"\x01signed-token")),
        (svc.OP_WRITE, 7, 2, dict(path="/a/b", data=b"\x00\xffdata")),
        (svc.OP_READ, 7, 3, dict(path="/a", version=-2, verify=False)),
        (svc.OP_DELETE, 7, 4, dict(path="/a")),
        (svc.OP_STAT, 7, 5, dict(path="/a")),
        (svc.OP_CLOSE, 7, 6, {}),
        (svc.OP_STATS, 7, 7, {}),
        (svc.OP_HEALTH, 7, 9, {}),
        (svc.OP_WRITE, 7, 8,
         dict(trace=0xABCDEF0123456789, path="/traced", data=b"td")),
    ]
    for op, sess, rid, fields in cases:
        frame = svc.encode_request(op, sess, rid, **fields)
        assert isinstance(frame, bytes)
        got_op, got_sess, got_rid, got = svc.decode_request(frame)
        assert (got_op, got_sess, got_rid) == (op, sess, rid)
        assert got == fields
        with pytest.raises(svc.CodecError):
            svc.decode_request(frame[:-1] if len(frame) > 13
                               else frame + b"x")


def test_wire_codec_roundtrip_responses():
    cases = [
        (svc.ST_OK, svc.OP_OPEN, 1, dict(session=9)),
        (svc.ST_OK, svc.OP_WRITE, 2,
         dict(total_bytes=1 << 40, new_bytes=12, new_blocks=3,
              dup_blocks=1)),
        (svc.ST_OK, svc.OP_READ, 3, dict(data=b"payload\x00")),
        (svc.ST_OK, svc.OP_DELETE, 4, dict(orphans=2)),
        (svc.ST_OK, svc.OP_STAT, 5,
         dict(versions=2, total_len=4096, blocks=1)),
        (svc.ST_OK, svc.OP_CLOSE, 6, {}),
        (svc.ST_RETRY, svc.OP_WRITE, 7, dict(reason="over budget")),
        (svc.ST_ERROR, svc.OP_READ, 8,
         dict(errtype="IOError", msg="bad block")),
        (svc.ST_OK, svc.OP_STATS, 9, dict(data=b'{"obs": {}}')),
        (svc.ST_OK, svc.OP_HEALTH, 10,
         dict(data=b'{"status": "ok", "verdicts": []}')),
    ]
    for status, op, rid, fields in cases:
        frame = svc.encode_response(status, op, rid, **fields)
        got_status, got_op, got_rid, got = svc.decode_response(frame)
        assert (got_status, got_op, got_rid) == (status, op, rid)
        assert got == fields


# ----------------------------------------------------------------------
# basic framed ops through one session
# ----------------------------------------------------------------------
def test_gateway_basic_ops_roundtrip(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    try:
        client = GatewayClient(gw, "solo")
        data = rng.integers(0, 256, 3 * 4096, dtype=np.uint8).tobytes()
        res = client.write("/d/f", data)
        assert res["total_bytes"] == len(data)
        assert res["new_blocks"] == 3
        assert client.read("/d/f") == data
        st = client.stat("/d/f")
        assert st == {"versions": 1, "total_len": len(data), "blocks": 3}
        assert client.delete("/d/f") == 3          # orphaned digests
        with pytest.raises(FileNotFoundError):
            client.read("/d/f")
        with pytest.raises(FileNotFoundError):
            client.stat("/d/f")
        client.close()
    finally:
        gw.close()
        eng.shutdown()


def test_unknown_session_and_bad_qos(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    try:
        frame = svc.encode_request(svc.OP_READ, 999, 1, path="/x",
                                   version=-1, verify=True)
        status, op, _rid, fields = svc.decode_response(
            gw.handle_frame(frame).result(30))
        assert status == svc.ST_ERROR
        assert fields["errtype"] == "UnknownSession"
        with pytest.raises(ValueError):
            GatewayClient(gw, "t", qos="bogus")
    finally:
        gw.close()
        eng.shutdown()


# ----------------------------------------------------------------------
# acceptance: cross-client coalescing with >= 4 concurrent sessions
# ----------------------------------------------------------------------
def test_cross_client_burst_coalesces(rng):
    """Four client sessions submit a concurrent write burst; their hash
    requests funnel through the shared engine and fuse: engine launches
    stay below the submitted jobs (== client requests here)."""
    mgr, _ = make_store(4)
    eng = CrystalTPU(coalesce_window_s=0.2)
    gw = _gateway(mgr, eng)
    try:
        clients = [GatewayClient(gw, f"t{i}") for i in range(4)]
        datas = {(i, j): rng.integers(0, 256, 4 * 4096,
                                      dtype=np.uint8).tobytes()
                 for i in range(4) for j in range(3)}
        s0 = eng.snapshot_stats()
        pending = [(key, clients[key[0]].submit_write(
            f"/t{key[0]}/f{key[1]}", blob))
            for key, blob in datas.items()]
        for _key, p in pending:
            assert p.result(120)["new_blocks"] == 4
        s1 = eng.snapshot_stats()
        jobs = s1["jobs"] - s0["jobs"]
        launches = s1["launches"] - s0["launches"]
        assert jobs >= len(datas)                 # one per request
        assert launches < jobs, (launches, jobs)  # cross-client fusion
        for (i, j), blob in datas.items():
            assert clients[i].read(f"/t{i}/f{j}") == blob
        stats = gw.snapshot_stats()
        assert stats["launches"] < stats["jobs"]
        assert len(stats["tenants"]) == 4
    finally:
        gw.close()
        eng.shutdown()


# ----------------------------------------------------------------------
# acceptance: fair share + admission backpressure
# ----------------------------------------------------------------------
def test_fair_share_flooder_vs_trickler(rng):
    """Equal-weight tenants, one flooding 64 KiB writes and one
    trickling sequential 4 KiB writes: the trickler is never starved
    (completed-request share within 2x of its 1/2 weight share), the
    flooder sees RetryLater rejections, and its queue stays inside the
    admission budget instead of growing without bound."""
    mgr, _ = make_store(4)
    eng = CrystalTPU(coalesce_window_s=0.01)
    gw = _gateway(mgr, eng, max_inflight=2, max_outstanding=8,
                  max_queued_bytes=512 << 10, quantum_bytes=32 << 10)
    try:
        flood = GatewayClient(gw, "flood")
        trick = GatewayClient(gw, "trick")
        flood_blob = rng.integers(0, 256, 16 * 4096,
                                  dtype=np.uint8).tobytes()
        trick_blob = rng.integers(0, 256, 4096,
                                  dtype=np.uint8).tobytes()
        stop = threading.Event()
        flood_n = {"ok": 0, "retry": 0}

        def flooder():
            pending = []
            i = 0
            while not stop.is_set():
                pending.append(flood.submit_write(f"/fl/{i}",
                                                  flood_blob))
                i += 1
                if len(pending) >= 12:
                    try:
                        pending.pop(0).result(120)
                        flood_n["ok"] += 1
                    except RetryLater:
                        flood_n["retry"] += 1
                        time.sleep(0.001)
            for p in pending:
                try:
                    p.result(120)
                    flood_n["ok"] += 1
                except RetryLater:
                    flood_n["retry"] += 1

        th = threading.Thread(target=flooder, daemon=True)
        th.start()
        time.sleep(0.05)                        # flood underway first
        n_trick = 12
        for i in range(n_trick):                # sequential trickle
            trick.write_retrying(f"/tr/{i}", trick_blob, timeout=120)
            time.sleep(0.002)
        stop.set()
        th.join(timeout=120)
        stats = gw.snapshot_stats()
        tf, tt = stats["tenants"]["flood"], stats["tenants"]["trick"]
        # every trickled request completed
        assert tt["completed"] >= n_trick
        # flooder got backpressure, not unbounded queueing
        assert tf["rejected"] > 0
        assert flood_n["retry"] > 0
        assert tf["queue_depth"] + tf["inflight"] <= 8
        # completed-request share within 2x of the 1/2 weight share
        share = tt["completed"] / max(tt["completed"] + tf["completed"],
                                      1)
        assert share >= 0.25, (share, tf["completed"], tt["completed"])
        for i in range(n_trick):                # trickled data intact
            assert trick.read(f"/tr/{i}") == trick_blob
    finally:
        gw.close()
        eng.shutdown()


def test_admission_rejects_over_budget_burst(rng):
    """A burst past max_outstanding resolves the excess to RetryLater
    (counted per tenant and gateway-wide); a retrying client gets
    through once the backlog drains."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng, max_outstanding=2, max_inflight=1)
    try:
        client = GatewayClient(gw, "bursty")
        blob = rng.integers(0, 256, 8 * 4096, dtype=np.uint8).tobytes()
        pending = [client.submit_write(f"/b/{i}", blob)
                   for i in range(10)]
        ok = rejected = 0
        for p in pending:
            try:
                p.result(120)
                ok += 1
            except RetryLater:
                rejected += 1
        assert ok >= 1
        assert rejected >= 1
        stats = gw.snapshot_stats()
        assert stats["tenants"]["bursty"]["rejected"] == rejected
        assert stats["admission_rejections"] == rejected
        # the well-behaved retrier eventually lands
        client.write_retrying("/b/again", blob, timeout=120)
        assert client.read("/b/again") == blob
    finally:
        gw.close()
        eng.shutdown()


# ----------------------------------------------------------------------
# QoS classes -> engine lanes
# ----------------------------------------------------------------------
def test_qos_classes_map_to_engine_lanes(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    try:
        inter = GatewayClient(gw, "ui", qos="interactive")
        batch = GatewayClient(gw, "etl", qos="batch")
        bg = GatewayClient(gw, "sweeper", qos="scrub")
        assert gw._tenants["ui"].sai.cfg.lane == "fg"
        assert gw._tenants["etl"].sai.cfg.lane == "batch"
        assert gw._tenants["sweeper"].sai.cfg.lane == "scrub"
        blob = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
        s0 = eng.snapshot_stats()
        for c in (inter, batch, bg):
            c.write(f"/{c.tenant}/f", blob)
            assert c.read(f"/{c.tenant}/f") == blob
        s1 = eng.snapshot_stats()
        # the scrub-QoS tenant's hashing is accounted on the scrub lane
        assert s1["scrub_jobs"] > s0["scrub_jobs"]
    finally:
        gw.close()
        eng.shutdown()


# ----------------------------------------------------------------------
# sessions / stats / owned runtime
# ----------------------------------------------------------------------
def test_sessions_share_tenant_and_stats(rng):
    """Two sessions joining one tenant bill to the same fair-share
    bucket; snapshot_stats carries the per-tenant counters."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    try:
        a = GatewayClient(gw, "team", weight=2.0)
        b = GatewayClient(gw, "team", weight=99.0)   # joins as-is
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        a.write("/s/a", blob)
        b.write("/s/b", blob)
        stats = gw.snapshot_stats()
        assert stats["sessions"] == 2
        team = stats["tenants"]["team"]
        assert team["weight"] == 2.0                 # first open wins
        assert team["completed"] == 2
        assert team["bytes_in"] == 2 * len(blob)
        assert stats["dispatched"] == 2
    finally:
        gw.close()
        eng.shutdown()


def test_gateway_owned_cluster_runtime_heals(rng):
    """GatewayConfig(scrub=True): the gateway owns a ClusterRuntime on
    the same engine; injected corruption behind the gateway is detected
    and repaired, and the client read stays correct."""
    mgr, nodes = make_store(4, replication=2)
    eng = CrystalTPU()
    gw = StorageGateway(mgr, engine=eng, config=GatewayConfig(
        sai=_sai_cfg(), scrub=True,
        runtime=NodeRuntimeConfig(scrub_backoff_depth=0)))
    try:
        assert gw.runtime is not None
        client = GatewayClient(gw, "t")
        data = rng.integers(0, 256, 6 * 4096, dtype=np.uint8).tobytes()
        client.write("/f", data)
        digest = next(iter(mgr.block_registry))
        bad_nid = mgr.block_registry[digest][0]
        blk = nodes[bad_nid].blocks[digest]
        nodes[bad_nid].blocks[digest] = bytes([blk[0] ^ 0xFF]) + blk[1:]
        # the owned runtime's background loops race the manual cycles
        # here (either may detect/repair first) — drive synchronously
        # and poll until the replica count is restored
        gw.runtime.scrub_once()
        deadline = time.time() + 60
        while time.time() < deadline:
            gw.runtime.repair_once()
            healthy = [n for n in mgr.lookup_block(digest)
                       if mgr.nodes[n].has(digest)]
            if len(healthy) >= 2:
                break
            time.sleep(0.02)
        assert len(healthy) >= 2
        assert client.read("/f") == data
        assert gw.snapshot_stats()["runtime"]["corrupt_found"] >= 1
    finally:
        gw.close()
        eng.shutdown()
    assert not gw.runtime._threads                   # stopped with close


# ----------------------------------------------------------------------
# codec hardening (ISSUE 5 satellites)
# ----------------------------------------------------------------------
def test_codec_fuzz_truncations_and_trailing_bytes():
    """Random truncations and trailing garbage of every opcode's frames
    must raise CodecError — never struct.error or IndexError — because
    these bytes arrive off an untrusted socket."""
    import random
    rnd = random.Random(1234)
    req_frames = [
        svc.encode_request(svc.OP_OPEN, 0, 1, tenant="t", qos="batch",
                           weight=1.5, token=b"tok" * 7),
        svc.encode_request(svc.OP_WRITE, 3, 2, path="/p",
                           data=b"x" * 100),
        svc.encode_request(svc.OP_READ, 3, 3, path="/p", version=-1,
                           verify=True),
        svc.encode_request(svc.OP_DELETE, 3, 4, path="/p"),
        svc.encode_request(svc.OP_STAT, 3, 5, path="/p"),
        svc.encode_request(svc.OP_CLOSE, 3, 6),
        svc.encode_request(svc.OP_STATS, 3, 7),
        svc.encode_request(svc.OP_HEALTH, 3, 9),
        svc.encode_request(svc.OP_WRITE, 3, 8, path="/p", data=b"y" * 50,
                           trace=0xDEADBEEF12345678),
    ]
    rsp_frames = [
        svc.encode_response(svc.ST_OK, svc.OP_OPEN, 1, session=4),
        svc.encode_response(svc.ST_OK, svc.OP_WRITE, 2, total_bytes=9,
                            new_bytes=9, new_blocks=1, dup_blocks=0),
        svc.encode_response(svc.ST_OK, svc.OP_READ, 3, data=b"d" * 64),
        svc.encode_response(svc.ST_OK, svc.OP_DELETE, 4, orphans=1),
        svc.encode_response(svc.ST_OK, svc.OP_STAT, 5, versions=1,
                            total_len=9, blocks=1),
        svc.encode_response(svc.ST_OK, svc.OP_CLOSE, 6),
        svc.encode_response(svc.ST_RETRY, svc.OP_WRITE, 7, reason="r"),
        svc.encode_response(svc.ST_ERROR, svc.OP_READ, 8,
                            errtype="IOError", msg="m"),
        svc.encode_response(svc.ST_OK, svc.OP_STATS, 9,
                            data=b'{"frames": 3}'),
        svc.encode_response(svc.ST_OK, svc.OP_HEALTH, 10,
                            data=b'{"status": "ok"}'),
    ]
    for frames, decode in ((req_frames, svc.decode_request),
                           (rsp_frames, svc.decode_response)):
        for frame in frames:
            for _ in range(40):
                cut = rnd.randrange(len(frame))
                with pytest.raises(svc.CodecError):
                    decode(frame[:cut])
            for _ in range(10):
                junk = bytes(rnd.randrange(256)
                             for _ in range(rnd.randrange(1, 9)))
                with pytest.raises(svc.CodecError):
                    decode(frame + junk)
    # invalid utf-8 in a wire string field (CodecError, never
    # UnicodeDecodeError)
    with pytest.raises(svc.CodecError):
        svc.decode_request(svc._REQ_HDR.pack(svc.OP_STAT, 1, 1, 0)
                           + b"\x00\x02\xff\xfe")
    with pytest.raises(svc.CodecError):
        svc.decode_response(svc._RSP_HDR.pack(svc.ST_RETRY, svc.OP_WRITE,
                                              1) + b"\x00\x02\xff\xfe")
    # unknown opcodes
    for frame in req_frames:
        with pytest.raises(svc.CodecError):
            svc.decode_request(bytes([250]) + frame[1:])
    with pytest.raises(svc.CodecError):
        svc.decode_response(svc._RSP_HDR.pack(svc.ST_OK, 250, 1))


def test_stats_op_requires_session_and_returns_snapshot(rng):
    """OP_STATS is session-gated like every non-OPEN verb: a frame
    without a valid session bounces with UnknownSession, while a
    session-holding client gets the live JSON snapshot."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    try:
        frame = svc.encode_request(svc.OP_STATS, 999, 1)
        status, op, _rid, fields = svc.decode_response(
            gw.handle_frame(frame).result(30))
        assert (status, op) == (svc.ST_ERROR, svc.OP_STATS)
        assert fields["errtype"] == "UnknownSession"

        client = GatewayClient(gw, "solo")
        data = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
        client.write("/s/f", data)
        snap = client.stats()
        assert isinstance(snap, dict)
        assert snap["obs"]["request"]["write"]["count"] >= 1
        assert "per_device" in snap["engine"]
        client.close()
    finally:
        gw.close()
        eng.shutdown()


def test_health_op_requires_session_and_returns_report(rng):
    """OP_HEALTH is session-gated exactly like OP_STATS, and a
    session-holding client gets the verdict report (the background
    health plane is OFF here — the on-demand path samples lazily)."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    try:
        frame = svc.encode_request(svc.OP_HEALTH, 999, 1)
        status, op, _rid, fields = svc.decode_response(
            gw.handle_frame(frame).result(30))
        assert (status, op) == (svc.ST_ERROR, svc.OP_HEALTH)
        assert fields["errtype"] == "UnknownSession"

        client = GatewayClient(gw, "solo")
        data = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
        client.write("/h/f", data)
        report = client.health()
        assert report["status"] in ("ok", "warn", "critical")
        assert isinstance(report["verdicts"], list)
        # repeated polls accumulate on-demand samples
        again = client.health()
        assert again["samples"] >= report["samples"]
        assert again["evals"] > report["evals"]
        client.close()
    finally:
        gw.close()
        eng.shutdown()


def test_codec_oversized_payload_raises_codec_error():
    """Payloads whose length doesn't fit the u32 prefix raise CodecError
    at encode time (previously raw struct.error), without materializing
    4 GiB: a __len__-lying stand-in is rejected before any packing."""
    class _Huge(bytes):
        def __len__(self):
            return 1 << 32
    with pytest.raises(svc.CodecError):
        svc.encode_request(svc.OP_WRITE, 1, 1, path="/p", data=_Huge())
    with pytest.raises(svc.CodecError):
        svc.encode_response(svc.ST_OK, svc.OP_READ, 1, data=_Huge())
    with pytest.raises(svc.CodecError):
        svc.encode_request(svc.OP_OPEN, 0, 1, tenant="t", qos="batch",
                           weight=1.0, token=b"x" * 0x10001)


def test_decode_request_enforces_max_frame_bytes():
    frame = svc.encode_request(svc.OP_WRITE, 1, 1, path="/p",
                               data=b"x" * 4096)
    assert svc.decode_request(frame)[0] == svc.OP_WRITE
    with pytest.raises(svc.CodecError):
        svc.decode_request(frame, max_frame_bytes=1024)
    # a gateway configured with a small cap bounces the frame too —
    # and the ST_ERROR echoes the request's op/rid (salvaged from the
    # fixed header) so a socket client can route it, not rid=0
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng, max_frame_bytes=1024)
    try:
        status, op, rid, fields = svc.decode_response(
            gw.handle_frame(frame).result(30))
        assert (status, op, rid) == (svc.ST_ERROR, svc.OP_WRITE, 1)
        assert fields["errtype"] == "CodecError"
        # truncated body, intact header: same salvage
        status, op, rid, fields = svc.decode_response(
            gw.handle_frame(svc.encode_request(
                svc.OP_STAT, 1, 42, path="/p")[:-2]).result(30))
        assert (status, op, rid) == (svc.ST_ERROR, svc.OP_STAT, 42)
        assert fields["errtype"] == "CodecError"
    finally:
        gw.close()
        eng.shutdown()


def test_open_rejects_bad_weights(rng):
    """weight=0, negative, or NaN on the wire would zero (or poison)
    quantum_bytes * weight and starve the tenant's WDRR credit forever;
    _open_session answers ST_ERROR instead."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    try:
        for bad in (0.0, -1.0, float("nan"), float("inf"),
                    float("-inf")):
            frame = svc.encode_request(svc.OP_OPEN, 0, 1, tenant="w",
                                       qos="batch", weight=bad)
            status, _op, _rid, fields = svc.decode_response(
                gw.handle_frame(frame).result(30))
            assert status == svc.ST_ERROR, bad
            assert fields["errtype"] == "ValueError", bad
            with pytest.raises(ValueError):
                GatewayClient(gw, "w2", weight=bad)
        assert gw.snapshot_stats()["tenants"] == {}  # none created
        client = GatewayClient(gw, "ok", weight=0.5)  # sane weight fine
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        client.write("/f", blob)
        assert client.read("/f") == blob
    finally:
        gw.close()
        eng.shutdown()


def test_write_retrying_respects_total_deadline():
    """write_retrying used to pass the FULL timeout to every attempt,
    so one queued retry could overshoot the deadline by ~2x.  Against a
    channel that always answers ST_RETRY, total wall time must stay
    near the requested deadline and the loop must raise RetryLater."""
    class _RetryChannel:
        def request(self, frame):
            op, _sess, rid, _f = svc.decode_request(frame)
            fut = svc.ReplyFuture()
            if op == svc.OP_OPEN:
                fut._resolve(svc.encode_response(svc.ST_OK, op, rid,
                                                 session=1))
            else:
                fut._resolve(svc.encode_response(svc.ST_RETRY, op, rid,
                                                 reason="always busy"))
            return fut

        def close(self):
            pass

    class _Target:
        def connect(self):
            return _RetryChannel()

    client = GatewayClient(_Target(), "t")
    t0 = time.monotonic()
    with pytest.raises(RetryLater):
        client.write_retrying("/f", b"x", timeout=0.25, backoff_s=0.01)
    elapsed = time.monotonic() - t0
    assert elapsed < 0.25 * 1.5, elapsed        # no 2x overshoot
    # a pre-expired deadline raises immediately, zero attempts
    with pytest.raises(RetryLater):
        client.write_retrying("/f", b"x", timeout=0.0)


def test_gateway_close_idempotent(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)
    client = GatewayClient(gw, "t")
    blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    client.write("/f", blob)
    gw.close()
    gw.close()                                       # no-op
    with pytest.raises(RetryLater):
        client.write("/g", blob)                     # closed: backpressure
    eng.shutdown()


# ----------------------------------------------------------------------
# adaptive fusion default + durable mode (ISSUE 7 satellites)
# ----------------------------------------------------------------------
def test_gateway_default_engine_gets_adaptive_fusion(rng, monkeypatch):
    """The gateway turns measured adaptive fusion ON when it resolves
    the process-default engine (ROADMAP item 3 follow-on), and a soak
    of client bursts keeps the retuned caps inside the policy bounds."""
    eng = CrystalTPU()
    assert not eng.policy.adaptive                  # engine default: off
    monkeypatch.setattr(svc.crystal_mod, "default_engine", lambda: eng)
    gw = StorageGateway(make_store(4)[0], engine=None,
                        config=GatewayConfig(sai=_sai_cfg()))
    try:
        assert gw.engine is eng and eng.policy.adaptive
        client = GatewayClient(gw, "soak")
        for i in range(30):                         # soak: retune cycles
            blob = rng.integers(0, 256, 4096 * (1 + i % 4),
                                dtype=np.uint8).tobytes()
            client.write(f"/s/{i}", blob)
            if i % 3 == 0:
                client.read(f"/s/{i}")
        pol = eng.policy
        snap = gw.snapshot_stats()["engine"]["policy"]
        assert snap["adaptive"] == 1
        assert pol.rows_floor <= snap["max_fused_rows"] <= pol.rows_ceil
        assert pol.bytes_floor <= snap["max_fused_bytes"] <= pol.bytes_ceil
        assert 1 <= snap["octave_span"] <= 3
        client.close()
    finally:
        gw.close()
        eng.shutdown()


def test_gateway_explicit_engine_policy_untouched(rng):
    """An explicitly supplied engine keeps whatever fusion policy its
    owner configured — the adaptive default only covers the engine the
    gateway resolves itself."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng)                          # adaptive_fusion=True
    try:
        assert gw.engine is eng
        assert not eng.policy.adaptive
    finally:
        gw.close()
        eng.shutdown()


def test_gateway_durable_data_dir_roundtrip(rng, tmp_path):
    """GatewayConfig(data_dir=...): the gateway owns a WAL-backed store;
    data written through one gateway incarnation survives into the
    next."""
    eng = CrystalTPU()
    cfg = dict(sai=_sai_cfg(), data_dir=str(tmp_path),
               n_nodes=3, replication=2)
    blob = rng.integers(0, 256, 5 * 4096, dtype=np.uint8).tobytes()
    gw = StorageGateway(engine=eng, config=GatewayConfig(**cfg))
    try:
        assert gw.recovery_report is not None
        client = GatewayClient(gw, "t")
        client.write("/durable/f", blob)
        assert client.read("/durable/f") == blob
    finally:
        gw.close()                                   # closes owned store

    gw2 = StorageGateway(engine=eng, config=GatewayConfig(**cfg))
    try:
        assert gw2.recovery_report.refcount_drift == 0
        client2 = GatewayClient(gw2, "t")
        assert client2.read("/durable/f") == blob    # survived restart
    finally:
        gw2.close()
        eng.shutdown()


def test_gateway_manager_xor_data_dir(tmp_path):
    mgr, _ = make_store(2)
    with pytest.raises(ValueError):
        StorageGateway(mgr, config=GatewayConfig(
            sai=_sai_cfg(), data_dir=str(tmp_path)))
    with pytest.raises(ValueError):
        StorageGateway(None, config=GatewayConfig(sai=_sai_cfg()))
