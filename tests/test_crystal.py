"""CrystalTPU runtime: queueing, callbacks, ablation-equivalence."""
import threading

import numpy as np
import pytest

from repro.core import CrystalTPU
from repro.kernels import ops


@pytest.fixture(scope="module")
def crystal():
    c = CrystalTPU()
    yield c
    c.shutdown()


def test_stream_of_jobs(crystal, rng):
    bufs = [rng.integers(0, 256, 8192, dtype=np.uint8) for _ in range(6)]
    jobs = crystal.map_stream("direct", bufs, {"seg_bytes": 4096})
    for j, b in zip(jobs, bufs):
        got = j.wait()
        want = ops.direct_hash(b.reshape(2, 4096))
        np.testing.assert_array_equal(got, want)
    assert crystal.stats["jobs"] >= 6


def test_callbacks_fire(crystal, rng):
    done = threading.Event()
    res = {}

    def cb(job):
        res["r"] = job.result
        done.set()

    crystal.submit("gear", rng.integers(0, 256, 4096, dtype=np.uint8),
                   {}, callback=cb)
    assert done.wait(timeout=120)
    assert res["r"].shape == (4096,)


def test_error_propagation(crystal):
    job = crystal.submit("nonsense", np.zeros(4, np.uint8), {})
    with pytest.raises(ValueError):
        job.wait()


@pytest.mark.parametrize("reuse,overlap", [(True, True), (False, False),
                                           (True, False), (False, True)])
def test_ablations_equivalent_results(rng, reuse, overlap):
    """Optimization toggles change performance, never results."""
    c = CrystalTPU(buffer_reuse=reuse, overlap=overlap, n_slots=2)
    try:
        buf = rng.integers(0, 256, 8192, dtype=np.uint8)
        job = c.submit("sliding", buf, {"window": 48, "stride": 4})
        got = job.wait()
        want = ops.sliding_window_hash(buf.tobytes(), 48, 4)
        np.testing.assert_array_equal(got, want)
        assert set(job.timings) == {"in", "kernel", "out"}
    finally:
        c.shutdown()
