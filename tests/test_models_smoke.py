"""Per-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, output shapes + no NaNs; decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models.model import build_model
from repro.optim import make_optimizer, make_schedule
from repro.train.trainstep import make_train_step


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 2, 64
    F = cfg.frontend_embeds
    batch = {"tokens": jax.random.randint(rng, (B, S - F), 0,
                                          cfg.vocab_size)}
    if F:
        batch["embeds"] = jax.random.normal(rng, (B, F, cfg.d_model))
    opt = make_optimizer(cfg.optimizer,
                         make_schedule(cfg.lr_schedule, 1e-3, 100))
    step = jax.jit(make_train_step(model, opt))
    # step 1: past LR warmup (lr(0) == 0 by schedule definition)
    params2, _, m = step(params, opt.init(params), batch,
                         jnp.asarray(1, jnp.int32))
    assert jnp.isfinite(m["loss"]), arch
    assert jnp.isfinite(m["grad_norm"]), arch
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.max(jnp.abs(a - b)), params, params2))
    assert max(float(d) for d in delta) > 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 32
    F = cfg.frontend_embeds
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S - F), 0,
                                cfg.vocab_size)
    embeds = jax.random.normal(jax.random.PRNGKey(3),
                               (B, F, cfg.d_model)) if F else None
    logits, aux = jax.jit(lambda p, t, e: model.forward(p, t, e),
                          static_argnums=())(params, tokens, embeds)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_full_forward(arch):
    """prefill(S) + decode(1) logits == forward(S+1) last-position logits.
    MoE archs use capacity_factor high enough to disable dropping (the
    known train/serve asymmetry of capacity-based MoE, see DESIGN.md)."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    if cfg.frontend_embeds:
        cfg = dataclasses.replace(cfg, frontend_embeds=0)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 2, 32
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    toks2 = jnp.concatenate(
        [tokens, jnp.zeros((B, 1), tokens.dtype)], axis=1)
    cap = model.capacity_for(S + 1)
    cache, _ = jax.jit(
        lambda p, t: model.prefill(p, t, capacity=cap))(params, tokens)
    cache, lg_dec = jax.jit(model.decode_step)(
        params, cache, toks2[:, -1:], jnp.asarray(S, jnp.int32))
    full_logits, _ = jax.jit(
        lambda p, t: model.forward(p, t))(params, toks2)
    err = float(jnp.max(jnp.abs(lg_dec - full_logits[:, -1])))
    assert err < 2e-3, f"{arch}: decode/full divergence {err}"


def test_swa_ring_cache_long_decode():
    """Mixtral-family SWA ring cache: decode far past the window stays
    finite and consistent with a fresh prefill."""
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, swa_window=16,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 40                                   # S > window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    cache, logits = jax.jit(lambda p, t: model.prefill(p, t))(params,
                                                              tokens)
    dec = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(5):
        cache, logits = dec(params, cache, tok,
                            jnp.asarray(S + i, jnp.int32))
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None]


def test_param_count_sanity():
    """Analytic parameter counts match the published sizes (within 10%)."""
    expected = {
        "llama3-8b": 8.0e9, "mixtral-8x7b": 46.7e9,
        "kimi-k2-1t-a32b": 1.0e12, "mamba2-1.3b": 1.3e9,
        "starcoder2-15b": 15e9, "glm4-9b": 9e9, "minicpm-2b": 2.4e9,
        "musicgen-medium": 1.5e9, "internvl2-2b": 1.8e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.35, \
            f"{arch}: {got/1e9:.2f}B vs {want/1e9:.2f}B"


def test_active_params_moe():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert 25e9 < active < 45e9, active / 1e9       # "a32b"
