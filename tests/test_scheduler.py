"""Continuous-batching scheduler: ragged-position correctness vs
sequential single-request decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serve.scheduler import ContinuousBatcher


def _single_reference(model, params, prompt, n_new, capacity):
    cache, logits = jax.jit(
        lambda p, t: model.prefill(p, t, capacity=capacity))(
            params, prompt[None, :])
    toks = [int(jnp.argmax(logits, -1)[0])]
    dec = jax.jit(model.decode_step)
    for i in range(n_new - 1):
        pos = jnp.asarray(len(prompt) + i, jnp.int32)
        cache, logits = dec(params, cache,
                            jnp.asarray([[toks[-1]]], jnp.int32), pos)
        toks.append(int(jnp.argmax(logits, -1)[0]))
    return toks


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture()
def rng():
    """Module-local override of the session rng: argmax-continuation
    comparisons are sensitive to the exact prompt values, so these tests
    must not depend on how much of the shared stream earlier test files
    consumed."""
    return np.random.default_rng(0)


def test_ragged_matches_sequential(setup, rng):
    """3 requests with different prompt lengths, batched together, must
    produce the same continuations as independent decoding."""
    cfg, model, params = setup
    capacity = 64
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 19, 33)]
    n_new = 6

    want = [_single_reference(model, params, p, n_new, capacity)
            for p in prompts]

    cb = ContinuousBatcher(model, params, batch_slots=3, capacity=capacity)
    reqs = [cb.submit(p, n_new) for p in prompts]
    finished = cb.run_until_drained()
    assert len(finished) == 3
    got = {r.rid: r.out_tokens for r in finished}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)


def test_more_requests_than_slots(setup, rng):
    """Requests beyond the slot count queue and are served as slots free."""
    cfg, model, params = setup
    cb = ContinuousBatcher(model, params, batch_slots=2, capacity=32)
    reqs = [cb.submit(rng.integers(0, cfg.vocab_size, 5 + i
                                   ).astype(np.int32), 3 + i)
            for i in range(5)]
    finished = cb.run_until_drained()
    assert len(finished) == 5
    st = cb.stats()
    assert st["queued"] == 0 and st["active"] == 0
    assert st["mean_ttft_s"] >= 0.0
    for r in finished:
        assert len(r.out_tokens) == r.max_new
