"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import Adafactor, AdamW, make_schedule


def _converges(opt, steps=200):
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.mean(jnp.square(p["w"] - target))

    l0 = float(loss(params))
    step = jax.jit(lambda p, s, i: opt.update(jax.grad(loss)(p), s, p, i))
    for i in range(steps):
        params, state = step(params, state, jnp.asarray(i, jnp.int32))
    return l0, float(loss(params))


def test_adamw_converges():
    l0, l1 = _converges(AdamW(lambda s: 0.05, weight_decay=0.0))
    assert l1 < 0.01 * l0


def test_adafactor_converges():
    # Adafactor's update is RMS-normalized, so a constant lr plateaus at
    # lr-scale error; use the standard relative decaying step.
    import jax.numpy as _jnp
    lr = lambda s: 0.5 / _jnp.sqrt(s.astype(_jnp.float32) + 1.0)
    l0, l1 = _converges(Adafactor(lr), steps=600)
    assert l1 < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = Adafactor(lambda s: 1e-3)
    params = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((64,))}
    st = opt.init(params)
    assert st["v_row"]["w"].shape == (64,)
    assert st["v_col"]["w"].shape == (128,)
    assert st["v_row"]["b"].shape == (64,)
    # memory: factored state is tiny vs AdamW's 2x params
    adam_bytes = 2 * 64 * 128 * 4
    fact_bytes = (64 + 128) * 4
    assert fact_bytes < adam_bytes / 50


def test_wsd_schedule_shape():
    fn = make_schedule("wsd", 1.0, 1000, warmup_steps=100)
    assert float(fn(0)) == 0.0
    assert float(fn(50)) == pytest.approx(0.5)
    assert float(fn(500)) == pytest.approx(1.0)      # stable plateau
    assert float(fn(950)) < 0.5                      # decay phase
    assert float(fn(999)) <= 0.2


def test_cosine_schedule_shape():
    fn = make_schedule("cosine", 1.0, 1000, warmup_steps=10)
    assert float(fn(10)) == pytest.approx(1.0, abs=1e-2)
    assert float(fn(999)) == pytest.approx(0.1, abs=2e-2)
