"""Distributed behaviours that need >1 device: run in a subprocess with
forced host devices so the main pytest process keeps 1 device."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_runs_small_mesh():
    """A real sharded train step (4x2 mesh) runs and matches the
    single-device step numerically."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models.model import build_model
        from repro.models.sharding import ShardCtx
        from repro.optim import make_optimizer, make_schedule
        from repro.train.trainstep import make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = ShardCtx(mesh=mesh, dp_axes=("data",))
        cfg = get_smoke_config("llama3-8b")
        model_s = build_model(cfg, ctx)
        model_1 = build_model(cfg)
        params = model_1.init(jax.random.PRNGKey(0))
        opt = make_optimizer("adamw", make_schedule("cosine", 1e-3, 10))
        ostate = opt.init(params)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab_size)}

        # single device
        s1 = jax.jit(make_train_step(model_1, opt))
        p1, o1, m1 = s1(params, ostate, batch, jnp.int32(0))

        # sharded
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           model_s.param_pspecs(),
                           is_leaf=lambda x: isinstance(x, P))
        osh = opt.state_spec_like(psh)
        params_s = jax.device_put(params, psh)
        ostate_s = jax.device_put(ostate, osh)
        batch_s = jax.device_put(
            batch, {"tokens": NamedSharding(mesh, P("data", None))})
        with mesh:
            s2 = jax.jit(make_train_step(model_s, opt),
                         in_shardings=(psh, osh,
                                       {"tokens": NamedSharding(
                                           mesh, P("data", None))}, None))
            p2, o2, m2 = s2(params_s, ostate_s, batch_s, jnp.int32(0))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (
            float(m1["loss"]), float(m2["loss"]))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-3)
        print("SHARDED_OK", float(m1["loss"]), float(m2["loss"]))
    """)
    out = _run(code)
    assert "SHARDED_OK" in out


def test_grad_compression_cross_pod():
    """int8 compressed psum across a 'pod' axis approximates the mean and
    error feedback keeps the bias bounded over steps."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.grad_compress import (make_cross_pod_sync,
                                               init_error_state)
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        specs = {"w": P(None, None)}
        sync = make_cross_pod_sync(mesh, specs)
        rng = np.random.default_rng(0)
        accum_true = np.zeros((8, 16), np.float32)
        accum_q = np.zeros((8, 16), np.float32)
        err = init_error_state(
            {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)})
        for step in range(20):
            g = rng.standard_normal((8, 16)).astype(np.float32)
            grads = {"w": jnp.asarray(g)}
            out, err = sync(grads, err)
            accum_true += g            # pods hold identical grads here
            accum_q += np.asarray(out["w"])
        rel = np.abs(accum_q - accum_true).max() / np.abs(
            accum_true).max()
        assert rel < 0.05, rel
        print("COMPRESS_OK", rel)
    """)
    out = _run(code)
    assert "COMPRESS_OK" in out


def test_production_mesh_shapes():
    code = textwrap.dedent("""
        from repro.launch.mesh import make_production_mesh, make_shard_ctx
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.shape == {"data": 16, "model": 16}
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}
        ctx = make_shard_ctx(m2)
        assert ctx.dp_axes == ("pod", "data")
        print("MESH_OK")
    """)
    out = _run(code, devices=512)
    assert "MESH_OK" in out
