"""Continuous health plane (ISSUE 9): rolling time-series, heartbeat
watchdogs, rule verdicts, the HTTP scrape endpoint, bounded stats
replies, and the perf-regression gate.

The acceptance drills at the bottom are the point of the PR: an
injected WAL-flusher stall must flip ``/health`` to 503 with a
``wal_flusher_stalled`` verdict within two sampling intervals (while
writes keep committing via sync leader-election), an injected
per-device latency skew must yield ``device_straggler`` naming the slow
device, clearing the faults must return 200, and a cleanly
paused/drained runtime must stay healthy (parked heartbeats are
dormancy, not stalls).
"""
import http.client
import json
import math
import time

import numpy as np
import pytest

import jax

from benchmarks.compare import compare
from repro.core import SAI, CrystalTPU, SAIConfig, make_store
from repro.core.faultinject import FaultInjector
from repro.core.noderuntime import ClusterRuntime
from repro.obs import (HealthConfig, HealthEngine, HealthHTTPServer,
                       Heartbeat, HeartbeatBoard, MetricsSampler,
                       flatten, prometheus_text, truncate_tree)
from repro.serve import storage_service as svc
from repro.serve.storage_client import GatewayClient
from repro.serve.storage_service import GatewayConfig, StorageGateway


@pytest.fixture
def rng():
    return np.random.default_rng(29)


def _sai_cfg(**kw):
    cfg = dict(ca="fixed", hasher="tpu", block_size=16 << 10)
    cfg.update(kw)
    return SAIConfig(**cfg)


def _gateway(mgr, engine, **kw):
    cfg = dict(sai=_sai_cfg())
    cfg.update(kw)
    return StorageGateway(mgr, engine=engine, config=GatewayConfig(**cfg))


def _http_get(port, path):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=10)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def _poll(predicate, timeout_s=10.0, interval_s=0.05):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        got = predicate()
        if got:
            return got
        time.sleep(interval_s)
    return None


# ----------------------------------------------------------------------
# heartbeats
# ----------------------------------------------------------------------
def test_heartbeat_starts_parked_and_tracks_age():
    hb = Heartbeat("worker")
    st = hb.state()
    assert st["parked"] == 1 and st["beats"] == 0
    hb.beat()
    st = hb.state()
    assert st["parked"] == 0 and st["beats"] == 1
    assert st["age_s"] < 1.0
    hb.park()
    assert hb.state()["parked"] == 1
    hb.beat()                       # un-parks again
    assert hb.state()["parked"] == 0


def test_heartbeat_board_get_or_create_and_snapshot():
    board = HeartbeatBoard()
    a = board.heartbeat("a")
    assert board.heartbeat("a") is a
    board.heartbeat("b").beat()
    snap = board.snapshot()
    assert set(snap) == {"a", "b"}
    assert snap["a"]["parked"] == 1
    assert snap["b"]["parked"] == 0
    # JSON-safe (rides snapshot_stats / the wire)
    json.dumps(snap)


# ----------------------------------------------------------------------
# sampler
# ----------------------------------------------------------------------
def test_sampler_deltas_rates_and_series():
    tree = {"obs": {"request": {"write": {"count": 0}}},
            "engine": {"bytes": 0}}
    s = MetricsSampler(lambda: tree, interval_s=0.01, window_s=60.0)
    s.sample_once()
    time.sleep(0.05)
    tree["obs"]["request"]["write"]["count"] = 10
    tree["engine"]["bytes"] = 1 << 20
    s.sample_once()
    assert s.delta("obs/request/write/count") == 10
    assert s.rate("obs/request/write/count") > 0
    assert s.rate("missing/key") is None
    pts = s.series("engine/bytes")
    assert [v for _, v in pts] == [0, 1 << 20]
    snap = s.snapshot()
    assert snap["samples"] == 2
    assert snap["writes_per_s"] > 0
    assert snap["hashed_bytes_per_s"] > 0


def test_sampler_ring_is_bounded_and_window_clips():
    tree = {"n": 0}
    s = MetricsSampler(lambda: tree, interval_s=0.01, capacity=4,
                       window_s=0.02)
    for i in range(10):
        tree["n"] = i
        s.sample_once()
    assert len(s.samples) == 4
    assert s.latest_flat() == {"n": 9}
    # window clips to entries near the latest sample: all 4 ring entries
    # landed within microseconds, so the delta spans only the kept ring
    assert s.delta("n") == 9 - 6
    tail = s.tail(2)
    assert len(tail) == 2 and tail[-1]["metrics"] == {"n": 9}


def test_sampler_snapshot_fn_errors_counted_not_raised():
    calls = {"n": 0}

    def boom():
        calls["n"] += 1
        raise RuntimeError("stats tree exploded")

    s = MetricsSampler(boom, interval_s=0.01)
    assert s.sample_once() is None
    assert s.errors == 1 and calls["n"] == 1 and not s.samples


def test_sampler_listeners_fire_per_tick():
    hits = []
    s = MetricsSampler(lambda: {"x": 1}, interval_s=0.01)
    s.add_listener(lambda: hits.append(1))
    s.sample_once()
    s.sample_once()
    assert len(hits) == 2


def test_sampler_tail_prefix_filter():
    s = MetricsSampler(lambda: {"a": {"x": 1}, "b": {"y": 2}},
                       interval_s=0.01)
    s.sample_once()
    tail = s.tail(4, prefixes=["a/"])
    assert tail[0]["metrics"] == {"a/x": 1}


# ----------------------------------------------------------------------
# health rules (synthetic trees drive a real sampler)
# ----------------------------------------------------------------------
def _engine_for(tree):
    s = MetricsSampler(lambda: tree, interval_s=0.01, window_s=60.0)
    return s, HealthEngine(s, HealthConfig(stall_after_s=0.5))


def test_watchdog_fires_on_unparked_stale_heartbeat():
    tree = {"wal": {"heartbeats": {"flusher":
            {"age_s": 3.0, "parked": 0, "beats": 5}}}}
    s, eng = _engine_for(tree)
    s.sample_once()
    rep = eng.evaluate()
    assert rep["status"] == "critical" and not rep["healthy"]
    names = [v["name"] for v in rep["verdicts"]]
    assert names == ["wal_flusher_stalled"]


def test_watchdog_skips_parked_and_fresh_heartbeats():
    tree = {"wal": {"heartbeats": {
                "flusher": {"age_s": 99.0, "parked": 1, "beats": 5}}},
            "heartbeats": {
                "scheduler": {"age_s": 0.01, "parked": 0, "beats": 9}}}
    s, eng = _engine_for(tree)
    s.sample_once()
    rep = eng.evaluate()
    assert rep["status"] == "ok" and rep["verdicts"] == []


def test_watchdog_verdict_names_nested_components():
    tree = {"tenants": {"t0": {"heartbeats": {
        "store0": {"age_s": 7.0, "parked": 0, "beats": 1}}}},
        "heartbeats": {
            "completer x": {"age_s": 7.0, "parked": 0, "beats": 1}}}
    s, eng = _engine_for(tree)
    s.sample_once()
    names = sorted(v["name"] for v in eng.evaluate()["verdicts"])
    assert names == ["gateway_completer_x_stalled", "t0_store0_stalled"]


def test_straggler_names_slow_device_and_needs_active_peers():
    def tree_at(launches):
        return {"engine": {"per_device": {
            0: {"slowdown": 9.0, "launches": launches[0]},
            1: {"slowdown": 1.0, "launches": launches[1]},
            2: {"slowdown": 1.1, "launches": launches[2]},
        }}}

    tree = tree_at([0, 0, 0])
    s, eng = _engine_for(tree)
    s.sample_once()
    tree.update(tree_at([5, 5, 5]))
    s.sample_once()
    rep = eng.evaluate()
    v = [v for v in rep["verdicts"] if v["rule"] == "straggler"]
    assert len(v) == 1 and v[0]["name"] == "device_straggler"
    assert v[0]["device"] == 0 and rep["status"] == "critical"

    # same slowdowns, but only device 0 active: no peers to compare
    # against, so the rule stays silent (single-lane traffic is not a
    # mesh-relative judgement)
    tree2 = tree_at([0, 0, 0])
    s2, eng2 = _engine_for(tree2)
    s2.sample_once()
    tree2.update(tree_at([5, 0, 0]))
    s2.sample_once()
    assert eng2.evaluate()["verdicts"] == []


def test_straggler_silent_on_drained_mesh():
    tree = {"engine": {"per_device": {
        0: {"slowdown": 9.0, "launches": 100},
        1: {"slowdown": 1.0, "launches": 100}}}}
    s, eng = _engine_for(tree)
    s.sample_once()
    s.sample_once()                 # no launch delta across the window
    assert eng.evaluate()["verdicts"] == []


def test_backlog_growth_warns_on_growing_lane():
    tree = {"queue_depths": {"fg": 2, "batch": 2}}
    s, eng = _engine_for(tree)
    s.sample_once()
    tree["queue_depths"]["fg"] = 80
    s.sample_once()
    rep = eng.evaluate()
    assert rep["status"] == "warn"
    v = rep["verdicts"][0]
    assert v["name"] == "backlog_growth" and v["lane"] == "fg"


def test_backlog_static_depth_is_not_growth():
    tree = {"queue_depths": {"fg": 80}}
    s, eng = _engine_for(tree)
    s.sample_once()
    s.sample_once()
    assert eng.evaluate()["verdicts"] == []


def test_slo_burn_fires_on_windowed_violations():
    slo_s = 0.5
    bad_idx = (int(slo_s * 1e9) - 1).bit_length() + 1   # >= SLO bucket
    ok_idx = max(1, bad_idx - 6)

    def tree_at(ok, bad):
        return {"obs": {"qos": {"interactive": {
            "buckets": {ok_idx: ok, bad_idx: bad}}}}}

    tree = tree_at(0, 0)
    s = MetricsSampler(lambda: tree, interval_s=0.01, window_s=60.0)
    eng = HealthEngine(s, HealthConfig(
        slo_p99_s={"interactive": slo_s}, slo_budget=0.01,
        burn_warn=1.0, burn_critical=10.0, slo_min_count=8))
    s.sample_once()
    tree.update(tree_at(20, 0))
    s.sample_once()
    assert eng.evaluate()["verdicts"] == []     # all inside the SLO
    tree.update(tree_at(30, 10))                # 10/20 windowed violate
    s.sample_once()
    rep = eng.evaluate()
    v = rep["verdicts"][0]
    assert v["name"] == "slo_burn_interactive"
    assert v["status"] == "critical" and v["value"] >= 10.0


def test_health_report_shape_and_status_ranking():
    tree = {"wal": {"heartbeats": {"flusher":
            {"age_s": 3.0, "parked": 0, "beats": 1}}},
            "queue_depths": {"fg": 2}}
    s, eng = _engine_for(tree)
    s.sample_once()
    tree["queue_depths"]["fg"] = 90
    s.sample_once()
    rep = eng.evaluate()
    # critical outranks warn; verdicts sort critical-first
    assert rep["status"] == "critical"
    assert [v["status"] for v in rep["verdicts"]] == ["critical", "warn"]
    json.dumps(rep)
    assert eng.snapshot() == rep    # snapshot returns the last report


# ----------------------------------------------------------------------
# exporter satellites: non-finite floats, # TYPE lines, truncation
# ----------------------------------------------------------------------
def test_prometheus_text_nonfinite_and_type_lines():
    tree = {"a": {"inf": math.inf, "ninf": -math.inf, "nan": math.nan},
            "engine": {"launches": 3}}
    text = prometheus_text(tree, namespace="repro")
    lines = text.splitlines()
    by_name = {ln.split()[0]: ln for ln in lines if not ln.startswith("#")}
    assert by_name["repro_a_inf"].split()[1] == "+Inf"
    assert by_name["repro_a_ninf"].split()[1] == "-Inf"
    assert by_name["repro_a_nan"].split()[1] == "NaN"
    # every sample line is preceded by its # TYPE metadata line
    for name, ln in by_name.items():
        idx = lines.index(ln)
        assert lines[idx - 1] == f"# TYPE {name} " + (
            "counter" if name == "repro_engine_launches" else "gauge")


def test_truncate_tree_prunes_deepest_first_and_converges():
    tree = {"shallow": 1,
            "tenants": {f"t{i}": {"deep": {"x": i, "y": "z" * 50}}
                        for i in range(40)}}
    full = len(json.dumps(tree))
    pruned, dropped = truncate_tree(tree, full // 8)
    assert dropped > 0
    assert len(json.dumps(pruned)) <= full // 8
    assert pruned["shallow"] == 1               # shallow keys survive
    assert pruned["stats_truncated"] == dropped
    # original tree untouched (deep copy)
    assert tree["tenants"]["t0"]["deep"]["x"] == 0
    # tiny budgets still converge instead of looping forever
    tiny, _ = truncate_tree(tree, 1)
    json.dumps(tiny)


def test_stats_op_truncates_against_max_frame_bytes(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = _gateway(mgr, eng, max_frame_bytes=8 << 10)
    try:
        # enough tenants that the full tree cannot fit the frame cap
        clients = [GatewayClient(gw, f"trunc{i}") for i in range(8)]
        data = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        for i, c in enumerate(clients):
            c.write(f"/t/{i}", data)
        assert len(json.dumps(gw.snapshot_stats())) > (8 << 10) - 256
        snap = clients[0].stats()   # decodes => the frame fit the cap
        assert snap["stats_truncated"] >= 1
        assert gw.stats["stats_truncated"] >= 1
        # shallow scalar counters survive the pruning
        assert "frames" in snap
        for c in clients:
            c.close()
    finally:
        gw.close()
        eng.shutdown()


# ----------------------------------------------------------------------
# HTTP scrape endpoint
# ----------------------------------------------------------------------
def test_http_server_routes_and_codes():
    health = {"status": "ok", "verdicts": []}
    srv = HealthHTTPServer(
        stats_fn=lambda: {"engine": {"launches": 2}},
        health_fn=lambda: dict(health),
        slowlog_fn=lambda: [{"rid": 1, "wall_s": 9.9}])
    try:
        code, body = _http_get(srv.port, "/metrics")
        assert code == 200
        assert b"# TYPE repro_engine_launches counter" in body
        assert b"repro_engine_launches 2" in body

        code, body = _http_get(srv.port, "/health")
        assert code == 200 and json.loads(body)["status"] == "ok"

        health["status"] = "critical"
        code, body = _http_get(srv.port, "/health")
        assert code == 503 and json.loads(body)["status"] == "critical"

        code, body = _http_get(srv.port, "/slowlog")
        assert code == 200
        assert json.loads(body)["slow_requests"][0]["rid"] == 1

        code, _ = _http_get(srv.port, "/nope")
        assert code == 404
    finally:
        srv.close()
        srv.close()                 # idempotent


def test_http_server_handler_errors_are_500():
    def boom():
        raise RuntimeError("stats exploded")

    srv = HealthHTTPServer(stats_fn=boom, health_fn=boom)
    try:
        code, _ = _http_get(srv.port, "/metrics")
        assert code == 500
    finally:
        srv.close()


# ----------------------------------------------------------------------
# gateway integration: timeseries/health blocks + scrape endpoint
# ----------------------------------------------------------------------
def test_gateway_health_plane_blocks_and_scrape(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU(coalesce_window_s=0.01)
    gw = _gateway(mgr, eng, health=True, metrics_port=0,
                  sample_interval_s=0.05, sample_window_s=2.0)
    try:
        assert gw.sampler.running and gw.http.port > 0
        client = GatewayClient(gw, "hmon")
        for i in range(4):
            client.write_retrying(
                f"/h/{i}",
                rng.integers(0, 256, 3 * 4096, np.uint8).tobytes())
        assert _poll(lambda: gw.sampler.delta("obs/request/write/count"),
                     timeout_s=5.0)
        snap = gw.snapshot_stats()
        assert snap["timeseries"]["samples"] >= 2
        assert snap["timeseries"]["writes_per_s"] > 0
        assert snap["health"]["status"] in ("ok", "warn")
        # wire verb and HTTP route serve the same report shape
        assert client.health()["status"] in ("ok", "warn")
        code, body = _http_get(gw.http.port, "/health")
        assert code == 200 and "verdicts" in json.loads(body)
        code, body = _http_get(gw.http.port, "/metrics")
        assert code == 200 and b"# TYPE" in body
        client.close()
    finally:
        gw.close()
        eng.shutdown()
    assert not gw.sampler.running   # close() stops the plane
    with pytest.raises(OSError):
        _http_get(gw.http.port, "/health")


# ----------------------------------------------------------------------
# fault injector stall action
# ----------------------------------------------------------------------
def test_faultinject_stall_blocks_until_cleared():
    inj = FaultInjector(stall_max_s=30.0)
    inj.stall("site.x")
    released = []

    def victim():
        inj.fire("site.x")
        released.append(time.monotonic())

    import threading
    t = threading.Thread(target=victim, daemon=True)
    t0 = time.monotonic()
    t.start()
    time.sleep(0.2)
    assert not released             # still wedged
    inj.clear_stall("site.x")
    t.join(timeout=5.0)
    assert released and released[0] - t0 >= 0.2
    inj.fire("site.x")              # cleared arms don't re-trigger


def test_faultinject_reset_releases_stalls():
    inj = FaultInjector(stall_max_s=30.0)
    inj.stall("site.y")
    import threading
    t = threading.Thread(target=lambda: inj.fire("site.y"), daemon=True)
    t.start()
    time.sleep(0.1)
    inj.reset()
    t.join(timeout=5.0)
    assert not t.is_alive()


# ----------------------------------------------------------------------
# perf-regression gate (benchmarks/compare.py)
# ----------------------------------------------------------------------
def _summary(rows, counters=None):
    return {"rows": [{"name": n, "us_per_call": us} for n, us in rows],
            "counters": counters or {}}


def test_compare_passes_identical_and_within_band():
    base = _summary([("gateway/latency_p99/2c", 1000.0),
                     ("recovery/fsync_p95", 500.0),
                     ("fig4/throughput", 100.0)],
                    {"mesh.digest_ok.ok": 1.0})
    ok, problems = compare(base, base)
    assert ok and not problems
    fresh = _summary([("gateway/latency_p99/2c", 5000.0),   # within x7
                      ("recovery/fsync_p95", 600.0),
                      ("fig4/throughput", 1e9)],            # not latency
                     {"mesh.digest_ok.ok": 1.0})
    ok, problems = compare(base, fresh)
    assert ok, problems


def test_compare_fails_on_latency_regression():
    base = _summary([("gateway/latency_p99/2c", 1000.0)])
    fresh = _summary([("gateway/latency_p99/2c", 100000.0)])  # x100
    ok, problems = compare(base, fresh)
    assert not ok
    assert any("LATENCY REGR" in p and "latency_p99" in p
               for p in problems)


def test_compare_fails_on_missing_row_and_ok_flag():
    base = _summary([("gateway/latency_p99/2c", 1000.0)],
                    {"mesh.digest_ok.ok": 1.0, "scrub.clean.ok": 1.0})
    fresh = _summary([], {"mesh.digest_ok.ok": 0.0})
    ok, problems = compare(base, fresh)
    assert not ok
    labels = "\n".join(problems)
    assert "MISSING ROW" in labels
    assert "COUNTER DIFF" in labels and "MISSING CTR" in labels


def test_compare_tolerance_band_is_tunable():
    base = _summary([("x/latency_p99", 100.0)])
    fresh = _summary([("x/latency_p99", 1000.0)])
    ok, _ = compare(base, fresh, tol=0.5, floor_us=10.0)
    assert not ok
    ok, _ = compare(base, fresh, tol=20.0, floor_us=10.0)
    assert ok


# ----------------------------------------------------------------------
# acceptance drills
# ----------------------------------------------------------------------
def test_e2e_wal_stall_flips_health_and_recovers(tmp_path, rng):
    """The health drill from the issue: stall the WAL flusher via fault
    injection -> /health goes 503 with a ``wal_flusher_stalled``
    verdict within two sampling intervals of the stall being observable
    (writes keep committing via sync leader-election the whole time);
    clearing the stall returns 200/ok."""
    eng = CrystalTPU(coalesce_window_s=0.01)
    gw = StorageGateway(engine=eng, config=GatewayConfig(
        sai=_sai_cfg(), data_dir=str(tmp_path),
        health=True, metrics_port=0,
        sample_interval_s=0.05, sample_window_s=2.0,
        health_config=HealthConfig(stall_after_s=0.4)))
    inj = FaultInjector(stall_max_s=60.0)
    try:
        client = GatewayClient(gw, "drill")
        for i in range(3):
            client.write_retrying(
                f"/d/{i}",
                rng.integers(0, 256, 2 * 4096, np.uint8).tobytes())
        assert _poll(lambda: client.health()["status"] == "ok",
                     timeout_s=5.0)

        gw.manager.wal.fault = inj
        inj.stall("wal.flusher")

        def stalled():
            rep = client.health()
            return rep if any(v["name"] == "wal_flusher_stalled"
                              for v in rep["verdicts"]) else None
        # flusher idle-ticks every <=0.1s, heartbeat trips at 0.4s, and
        # the verdict must land within 2 sampling intervals after that
        rep = _poll(stalled, timeout_s=0.1 + 0.4 + 2 * 0.05 + 2.0)
        assert rep is not None, "watchdog never fired"
        assert rep["status"] == "critical" and not rep["healthy"]
        code, body = _http_get(gw.http.port, "/health")
        assert code == 503
        assert any(v["name"] == "wal_flusher_stalled"
                   for v in json.loads(body)["verdicts"])
        # degraded, not down: writes still commit around the dead
        # flusher (sync leader-election)
        client.write_retrying(
            "/d/during",
            rng.integers(0, 256, 4096, np.uint8).tobytes())

        inj.clear_stall("wal.flusher")
        assert _poll(lambda: client.health()["status"] == "ok",
                     timeout_s=10.0), "health never recovered"
        code, _ = _http_get(gw.http.port, "/health")
        assert code == 200
        client.close()
    finally:
        inj.clear_stall()
        gw.close()
        eng.shutdown()


def test_e2e_device_straggler_named_and_clears(rng):
    """Injected per-device latency skew (launch hook sleeping on device
    0 of a 3-way mesh) must produce a ``device_straggler`` verdict
    naming device 0, which clears once the skew and traffic stop."""
    mgr, _ = make_store(4)
    eng = CrystalTPU(devices=[jax.devices()[0]] * 3,
                     coalesce_window_s=0.002)
    eng._launch_hook = (lambda idx, batch:
                        time.sleep(0.04) if idx == 0 else None)
    gw = _gateway(mgr, eng, health=True,
                  sample_interval_s=0.05, sample_window_s=2.0,
                  health_config=HealthConfig(stall_after_s=10.0))
    try:
        client = GatewayClient(gw, "mesh")
        data = np.ones((1, 4096), np.uint8)

        def straggler():
            # concurrent single-row bursts spread across the mesh; the
            # hooked device's observed/estimated ratio drifts up while
            # its peers' stays ~1.  Under host load (or a jit-compile
            # transient) a peer can briefly spike and get flagged too,
            # so wait for the verdict naming the injected device
            # specifically — only its skew is persistent.
            jobs = [eng.submit("direct", data, {}) for _ in range(9)]
            for j in jobs:
                j.wait()
            rep = client.health()
            hits = [v for v in rep["verdicts"]
                    if v["rule"] == "straggler" and v["device"] == 0]
            return hits[0] if hits else None

        verdict = _poll(straggler, timeout_s=30.0, interval_s=0.0)
        assert verdict is not None, "straggler never detected"
        assert verdict["device"] == 0
        assert verdict["name"] == "device_straggler"
        assert verdict["status"] == "critical"

        # remove the skew and stop traffic: the windowed launch deltas
        # drain, so the rule goes silent deterministically
        eng._launch_hook = None
        assert _poll(
            lambda: not any(v["rule"] == "straggler"
                            for v in client.health()["verdicts"]),
            timeout_s=10.0), "straggler verdict never cleared"
        client.close()
    finally:
        gw.close()
        eng.shutdown()


def test_paused_runtime_and_idle_threads_stay_healthy(tmp_path, rng):
    """Satellite 4, the false-positive control: a cleanly paused
    runtime (scrub loops parked), an idle engine, an inline-fsync WAL
    (``flush_interval_s=0`` -> no flusher thread at all), and drained
    SAI pipelines must all report healthy — parked heartbeats are
    dormancy, not stalls, no matter how old."""
    from repro.core.castore import open_durable_store
    mgr, _, _ = open_durable_store(str(tmp_path), n_nodes=4,
                                   flush_interval_s=0.0)
    eng = CrystalTPU(coalesce_window_s=0.01)
    gw = StorageGateway(mgr, engine=eng, config=GatewayConfig(
        sai=_sai_cfg(), scrub=True,
        health=True, sample_interval_s=0.05, sample_window_s=2.0,
        health_config=HealthConfig(stall_after_s=0.3)))
    try:
        client = GatewayClient(gw, "quiet")
        for i in range(2):
            client.write_retrying(
                f"/q/{i}",
                rng.integers(0, 256, 2 * 4096, np.uint8).tobytes())
        gw.runtime.pause()
        # idle for several multiples of stall_after_s: every blocked
        # thread (scheduler, completers, SAI stages, scrub loops, the
        # absent flusher) must be parked, not "stalled"
        time.sleep(1.2)
        rep = client.health()
        assert rep["status"] == "ok", rep["verdicts"]
        flat = gw.sampler.latest_flat()
        parked = [k for k in flat
                  if "/heartbeats/" in k and k.endswith("/parked")]
        assert parked, "no heartbeats visible in the sampled tree"
        # the WAL flusher heartbeat exists and is parked (inline mode)
        assert flat.get("wal/heartbeats/flusher/parked") == 1
        gw.runtime.resume()
        client.write_retrying(
            "/q/after",
            rng.integers(0, 256, 4096, np.uint8).tobytes())
        # a fresh pad-shape JIT compile can hold threads busy (unparked,
        # not beating) past the tight test threshold right after resume
        # — health must settle back to ok once the work drains
        assert _poll(lambda: client.health()["status"] == "ok",
                     timeout_s=10.0), client.health()["verdicts"]
        client.close()
    finally:
        gw.close()
        eng.shutdown()
