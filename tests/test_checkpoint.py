"""Content-addressable checkpointing: roundtrip, dedup-across-steps (the
paper's checkpoint workload), async save, and supervised restart."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import SAI, SAIConfig, CrystalTPU, make_store
from repro.data import make_pipeline
from repro.models.model import build_model
from repro.optim import make_optimizer, make_schedule
from repro.train.checkpoint import CACheckpointer
from repro.train.fault import TrainSupervisor
from repro.train.trainstep import make_train_step


def _ckpt(ca="cdc-gear"):
    mgr, _ = make_store(3, replication=2)
    sai = SAI(mgr, SAIConfig(ca=ca, avg_chunk=16 << 10, min_chunk=4 << 10,
                             max_chunk=64 << 10, hasher="cpu"))
    return CACheckpointer(sai), mgr


def test_roundtrip(rng):
    ckpt, _ = _ckpt()
    params = {"a": np.arange(1000, dtype=np.float32).reshape(10, 100),
              "b": {"c": np.ones((3, 3), np.float32)}}
    ckpt.save(7, params)
    step, state, _ = ckpt.restore()
    assert step == 7
    np.testing.assert_array_equal(state["params"]["a"], params["a"])
    np.testing.assert_array_equal(state["params"]["b"]["c"],
                                  params["b"]["c"])


def test_dedup_across_steps(rng):
    """Successive checkpoints dedup on their UNCHANGED regions (frozen /
    slow-moving tensors).  Note (documented in DESIGN.md): a dense
    optimizer step perturbs every element, so byte-level dedup of a fully
    updated fp32 tensor is ~0 — the paper's 76-90% checkpoint similarity
    comes from unchanged pages; the ML analogue is frozen layers,
    unchanged tensors, and repeated/restarted saves."""
    ckpt, mgr = _ckpt()
    big = rng.standard_normal(300_000).astype(np.float32)
    r1 = ckpt.save(0, {"w": big})
    # contiguous 5% region changes (e.g. unfrozen head on a frozen trunk)
    big2 = big.copy()
    big2[:big.size // 20] += 0.1
    r2 = ckpt.save(1, {"w": big2})
    assert r1["dedup_ratio"] < 0.05          # first save: all new
    assert r2["dedup_ratio"] > 0.7, r2       # incremental save: mostly dup
    # identical re-save (restart duplicate): 100% dedup
    r3 = ckpt.save(2, {"w": big2})
    assert r3["new_bytes"] == 0
    # all restorable
    _, s0, _ = ckpt.restore(version=0)
    _, s1, _ = ckpt.restore(version=1)
    np.testing.assert_array_equal(s0["params"]["w"], big)
    np.testing.assert_array_equal(s1["params"]["w"], big2)


def test_async_save(rng):
    ckpt, _ = _ckpt()
    params = {"w": rng.standard_normal(10_000).astype(np.float32)}
    t = ckpt.async_save(3, params)
    ckpt.wait()
    step, state, _ = ckpt.restore()
    assert step == 3
    np.testing.assert_array_equal(state["params"]["w"], params["w"])


def test_supervisor_restart_recovers_training():
    """Inject a failure; the supervisor restores from the checkpoint and
    the run completes with decreasing loss."""
    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", make_schedule("cosine", 1e-3, 40))
    opt_state = opt.init(params)
    pipeline = make_pipeline(cfg, 64, 4)
    step_fn = jax.jit(make_train_step(model, opt))
    ckpt, _ = _ckpt()
    sup = TrainSupervisor(step_fn, pipeline, ckpt, ckpt_every=5,
                          async_ckpt=False, fail_at_steps={12: 1})
    params, opt_state = sup.run(params, opt_state, 0, 20)
    assert sup.restarts == 1
    steps = [r["step"] for r in sup.log]
    # failure at 12 -> restore to checkpoint at 10 -> steps 10/11 re-run
    assert steps.count(10) == 2 and steps.count(11) == 2
    assert steps.count(12) == 1 and steps[-1] == 19
    losses = [r["loss"] for r in sup.log]
    assert losses[-1] < losses[0]


def test_elastic_reshard_same_stream():
    from repro.train.fault import elastic_reshard
    cfg = get_smoke_config("llama3-8b")
    p4 = make_pipeline(cfg, 64, 8, num_shards=1)
    b_full = p4.batch(5)["tokens"]
    p2 = elastic_reshard(p4, 2)
    b0 = p2.batch(5)["tokens"]
    assert b0.shape[0] == 4
    np.testing.assert_array_equal(b_full[:4], b0)
