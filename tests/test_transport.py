"""TCP socket transport + tenant auth for the gateway (ISSUE 5).

Acceptance coverage: a GatewayServer on localhost TCP serves concurrent
GatewayClient connections from separate threads with full
open -> write -> read -> stat -> close round-trips; forged/expired/
replayed open tokens are rejected with ST_ERROR; the engine shows
cross-connection coalescing (launches < jobs) for a multi-client burst
over the socket; and the connection lifecycle holds up — half-close
still drains responses, abrupt disconnects resolve in-flight futures
with ST_ERROR instead of hanging, and hostile length prefixes are
refused before any allocation.
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.core import CrystalTPU, SAIConfig, make_store
from repro.serve import storage_service as svc
from repro.serve.auth import (AuthError, TokenAuthenticator, mint_token,
                              parse_token)
from repro.serve.storage_client import GatewayClient, RetryLater
from repro.serve.storage_service import GatewayConfig, StorageGateway
from repro.serve.transport import (FrameError, GatewayServer,
                                   SocketChannel, parse_address,
                                   recv_frame, send_frame)

SECRETS = {"acme": b"acme-secret", "globex": b"globex-secret",
           "t0": b"s0", "t1": b"s1", "t2": b"s2", "t3": b"s3"}


def _sai_cfg(**kw):
    kw.setdefault("hasher", "tpu")
    return SAIConfig(ca="fixed", block_size=4096, avg_chunk=4096,
                     min_chunk=1024, max_chunk=16384, **kw)


def _served(mgr, engine, auth=True, **kw):
    cfg = dict(sai=_sai_cfg())
    if auth:
        cfg["auth"] = TokenAuthenticator(SECRETS)
    cfg.update(kw)
    gw = StorageGateway(mgr, engine=engine, config=GatewayConfig(**cfg))
    return gw, GatewayServer(gw)


# ----------------------------------------------------------------------
# stream framing primitives
# ----------------------------------------------------------------------
def test_stream_framing_roundtrip_and_hostile_prefix():
    a, b = socket.socketpair()
    try:
        for payload in (b"", b"x", b"y" * 70_000):
            send_frame(a, payload, max_frame_bytes=1 << 20)
            assert recv_frame(b, max_frame_bytes=1 << 20) == payload
        # oversized send refused locally
        with pytest.raises(FrameError):
            send_frame(a, b"z" * 2048, max_frame_bytes=1024)
        # hostile length prefix refused BEFORE allocating
        a.sendall(struct.pack("!I", 1 << 31))
        with pytest.raises(FrameError):
            recv_frame(b, max_frame_bytes=1 << 20)
        # EOF mid-frame
        a.sendall(struct.pack("!I", 10) + b"abc")
        a.close()
        with pytest.raises(FrameError):
            recv_frame(b, max_frame_bytes=1 << 20)
    finally:
        for s in (a, b):
            try:
                s.close()
            except OSError:
                pass


def test_parse_address_forms():
    assert parse_address("127.0.0.1:8080") == ("127.0.0.1", 8080)
    assert parse_address("localhost:80") == ("localhost", 80)
    assert parse_address(("h", 1)) == ("h", 1)
    assert parse_address("[::1]:8080") == ("::1", 8080)
    assert parse_address("[fe80::1]:80") == ("fe80::1", 80)
    for bad in ("::1:8080",       # ambiguous unbracketed IPv6
                "nohost", ":80", "h:", "h:not-a-port", "[::1]"):
        with pytest.raises(ValueError):
            parse_address(bad)


def _ipv6_loopback_ok():
    if not socket.has_ipv6:
        return False
    try:
        s = socket.socket(socket.AF_INET6, socket.SOCK_STREAM)
        s.bind(("::1", 0))
        s.close()
        return True
    except OSError:
        return False


@pytest.mark.skipif(not _ipv6_loopback_ok(),
                    reason="no IPv6 loopback on this host")
def test_server_serves_ipv6_loopback(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = StorageGateway(mgr, engine=eng,
                        config=GatewayConfig(sai=_sai_cfg()))
    server = GatewayServer(gw, host="::1")
    try:
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        client = GatewayClient(f"[::1]:{server.address[1]}", "six")
        client.write("/v6", blob)
        assert client.read("/v6") == blob
        client.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_recv_frame_clean_eof_is_none():
    a, b = socket.socketpair()
    send_frame(a, b"last", max_frame_bytes=1024)
    a.close()
    assert recv_frame(b, max_frame_bytes=1024) == b"last"
    assert recv_frame(b, max_frame_bytes=1024) is None
    b.close()


# ----------------------------------------------------------------------
# acceptance: concurrent clients over localhost TCP
# ----------------------------------------------------------------------
def test_socket_concurrent_clients_full_roundtrips(rng):
    """>= 2 concurrent GatewayClient connections from separate threads,
    each doing open -> write -> read -> stat -> close over TCP, and the
    multi-connection burst coalesces on the shared engine
    (launches < jobs)."""
    mgr, _ = make_store(4)
    eng = CrystalTPU(coalesce_window_s=0.2)
    gw, server = _served(mgr, eng)
    errors = []
    n_clients, n_files = 4, 3
    blobs = {(i, j): rng.integers(0, 256, 4 * 4096,
                                  dtype=np.uint8).tobytes()
             for i in range(n_clients) for j in range(n_files)}
    start = threading.Barrier(n_clients)

    def lifecycle(i):
        try:
            client = GatewayClient(server, f"t{i}",
                                   secret=SECRETS[f"t{i}"])
            start.wait(timeout=30)
            pending = [(j, client.submit_write(f"/t{i}/{j}",
                                               blobs[i, j]))
                       for j in range(n_files)]
            for j, p in pending:
                assert p.result(120)["new_blocks"] == 4
            for j in range(n_files):
                assert client.read(f"/t{i}/{j}") == blobs[i, j]
                st = client.stat(f"/t{i}/{j}")
                assert st["total_len"] == len(blobs[i, j])
            client.close()
        except BaseException as e:      # surface thread failures
            errors.append((i, repr(e)))

    try:
        s0 = eng.snapshot_stats()
        threads = [threading.Thread(target=lifecycle, args=(i,),
                                    daemon=True)
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        assert not errors, errors
        s1 = eng.snapshot_stats()
        jobs = s1["jobs"] - s0["jobs"]
        launches = s1["launches"] - s0["launches"]
        assert jobs >= n_clients * n_files
        assert launches < jobs, (launches, jobs)
        stats = gw.snapshot_stats()
        assert stats["launches"] < stats["jobs"]
        assert len(stats["tenants"]) == n_clients
        assert server.snapshot_stats()["connections"] == n_clients
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_socket_client_by_address_and_string(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng, auth=False)
    try:
        host, port = server.address
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        c1 = GatewayClient((host, port), "a")
        c2 = GatewayClient(f"{host}:{port}", "b")
        c1.write("/a", blob)
        assert c2.read("/a") == blob
        assert c2.delete("/a") == 1
        c1.close()
        c2.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


# ----------------------------------------------------------------------
# tenant auth
# ----------------------------------------------------------------------
def test_auth_token_roundtrip_and_parse():
    tok = mint_token("acme", b"k", ttl_s=10.0, now=1000.0,
                     nonce=b"n" * 16)
    tenant, expiry, nonce, _sig, _body = parse_token(tok)
    assert (tenant, expiry, nonce) == ("acme", 1010.0, b"n" * 16)
    for cut in range(len(tok)):
        with pytest.raises(AuthError):
            auth = TokenAuthenticator({"acme": b"k"})
            auth.verify(tok[:cut], now=1000.0)


def test_auth_rejects_forged_expired_replayed_and_mismatched(rng):
    gate = TokenAuthenticator(SECRETS)
    now = time.time()
    assert gate.verify(mint_token("acme", SECRETS["acme"]),
                       claimed="acme") == "acme"
    with pytest.raises(AuthError):                       # forged
        gate.verify(mint_token("acme", b"wrong-secret"))
    with pytest.raises(AuthError):                       # unknown tenant
        gate.verify(mint_token("nobody", b"k"))
    with pytest.raises(AuthError):                       # expired
        gate.verify(mint_token("acme", SECRETS["acme"], ttl_s=5.0,
                               now=now - 100.0))
    with pytest.raises(AuthError):                       # missing
        gate.verify(b"")
    with pytest.raises(AuthError):                       # wrong claimant
        gate.verify(mint_token("acme", SECRETS["acme"]),
                    claimed="globex")
    tok = mint_token("globex", SECRETS["globex"])
    assert gate.verify(tok) == "globex"
    with pytest.raises(AuthError):                       # replayed
        gate.verify(tok)


def test_gateway_rejects_bad_open_tokens_over_socket(rng):
    """Forged, expired, replayed, and missing tokens are answered with
    ST_ERROR over TCP; a valid token opens and the session works; the
    rejected opens never create tenants."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng)
    try:
        with pytest.raises(AuthError):                   # forged
            GatewayClient(server, "acme", secret=b"not-the-secret")
        with pytest.raises(AuthError):                   # expired
            GatewayClient(server, "acme", token=mint_token(
                "acme", SECRETS["acme"], ttl_s=-1.0))
        with pytest.raises(AuthError):                   # missing
            GatewayClient(server, "acme")
        with pytest.raises(AuthError):                   # stolen token
            GatewayClient(server, "globex", token=mint_token(
                "acme", SECRETS["acme"]))
        assert gw.snapshot_stats()["tenants"] == {}
        ok = GatewayClient(server, "acme", secret=SECRETS["acme"])
        tok = mint_token("globex", SECRETS["globex"])
        also = GatewayClient(server, "globex", token=tok)
        with pytest.raises(AuthError):                   # replayed
            GatewayClient(server, "globex", token=tok)
        blob = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
        ok.write("/f", blob)
        assert also.read("/f") == blob
        ok.close()
        also.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_session_ids_are_connection_scoped(rng):
    """A session opened (and authenticated) on one connection is
    worthless on every other: a raw TCP client naming the victim's
    session id gets UnknownSession for reads, writes, deletes, AND
    close — it can neither touch the victim's data, bill traffic to
    its tenant, nor kill its session."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng)                 # auth enforced
    try:
        victim = GatewayClient(server, "acme", secret=SECRETS["acme"])
        blob = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
        victim.write("/secret", blob)
        sid = victim._session
        probe = socket.create_connection(server.address, timeout=10)
        attempts = [
            (svc.OP_READ, dict(path="/secret", version=-1, verify=True)),
            (svc.OP_WRITE, dict(path="/evil", data=b"x" * 64)),
            (svc.OP_DELETE, dict(path="/secret")),
            (svc.OP_STAT, dict(path="/secret")),
            (svc.OP_CLOSE, {}),
        ]
        # the forger never authenticated, yet probes the victim's sid
        # and a spread of guesses around it
        for rid, (op, fields) in enumerate(attempts, start=1):
            send_frame(probe, svc.encode_request(op, sid, rid, **fields))
            status, _op, _rid, f = svc.decode_response(recv_frame(probe))
            assert status == svc.ST_ERROR
            assert f["errtype"] == "UnknownSession"
        for guess in (0, 1, 2, sid + 1):
            send_frame(probe, svc.encode_request(
                svc.OP_STAT, guess, 99, path="/secret"))
            status, _op, _rid, f = svc.decode_response(recv_frame(probe))
            assert status == svc.ST_ERROR
            assert f["errtype"] == "UnknownSession"
        probe.close()
        # the hijack attempts neither closed the victim's session nor
        # touched its data
        assert victim.read("/secret") == blob
        assert victim.stat("/secret")["total_len"] == len(blob)
        victim.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_disconnect_drops_connection_sessions(rng):
    """A connection's sessions are removed from the gateway table when
    the connection goes away (graceful or abrupt) — ids don't pile up
    or stay live after the socket that authenticated them is gone."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng, auth=False)
    try:
        sock = socket.create_connection(server.address, timeout=10)
        send_frame(sock, svc.encode_request(
            svc.OP_OPEN, 0, 1, tenant="gone", qos="interactive",
            weight=1.0))
        status, _op, _rid, f = svc.decode_response(recv_frame(sock))
        assert status == svc.ST_OK
        assert gw.snapshot_stats()["sessions"] == 1
        sock.close()                    # vanish without OP_CLOSE
        deadline = time.time() + 30
        while gw.snapshot_stats()["sessions"] and time.time() < deadline:
            time.sleep(0.01)
        assert gw.snapshot_stats()["sessions"] == 0
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_pipelined_client_that_never_drains_is_bounded(rng):
    """The per-connection reply queue is bounded: a client that
    pipelines far more requests than max_pipeline without reading a
    single response stalls the reader (TCP backpressure) instead of
    growing server memory; once it finally drains, every reply
    arrives."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = StorageGateway(mgr, engine=eng,
                        config=GatewayConfig(sai=_sai_cfg()))
    server = GatewayServer(gw, max_pipeline=2)
    try:
        seed = GatewayClient(gw, "seed")
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        seed.write("/pre", blob)
        seed.close()
        sock = socket.create_connection(server.address, timeout=10)
        send_frame(sock, svc.encode_request(
            svc.OP_OPEN, 0, 1, tenant="flood", qos="interactive",
            weight=1.0))
        _status, _op, _rid, f = svc.decode_response(recv_frame(sock))
        sid = f["session"]
        n = 24                          # >> max_pipeline
        for rid in range(2, 2 + n):
            send_frame(sock, svc.encode_request(svc.OP_STAT, sid, rid,
                                                path="/pre"))
        time.sleep(0.2)                 # let replies pile up server-side
        rids = set()
        for _ in range(n):
            status, _op, rid, _f = svc.decode_response(recv_frame(sock))
            assert status == svc.ST_OK
            rids.add(rid)
        assert rids == set(range(2, 2 + n))
        sock.close()
        with pytest.raises(ValueError):
            GatewayServer(gw, max_pipeline=0)
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_server_wildcard_bind_roundtrip(rng):
    """host='' (the bind-all idiom) still constructs and serves."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = StorageGateway(mgr, engine=eng,
                        config=GatewayConfig(sai=_sai_cfg()))
    server = GatewayServer(gw, host="")
    try:
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        client = GatewayClient(("127.0.0.1", server.address[1]), "any")
        client.write("/w", blob)
        assert client.read("/w") == blob
        client.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


class _StuckGateway:
    """handle_frame returns futures that never resolve — forces the
    connection writer's reply_timeout_s abort path."""

    def handle_frame(self, frame, owner=None):
        return svc.ReplyFuture()

    def drop_sessions(self, owner):
        return 0


def test_writer_timeout_abort_unwedges_blocked_reader():
    """When a gateway reply never resolves, the writer's timeout abort
    must drain the bounded writeq so the reader (blocked in put())
    exits and the connection tears down — not wedge the thread and
    pin max_pipeline replies forever."""
    server = GatewayServer(_StuckGateway(), max_frame_bytes=1 << 20,
                           reply_timeout_s=0.3, max_pipeline=2)
    try:
        sock = socket.create_connection(server.address, timeout=10)
        for rid in range(1, 9):         # >> max_pipeline: reader blocks
            send_frame(sock, svc.encode_request(svc.OP_STAT, 1, rid,
                                                path="/x"))
        deadline = time.time() + 30
        while server.snapshot_stats()["open_connections"] \
                and time.time() < deadline:
            time.sleep(0.01)
        assert server.snapshot_stats()["open_connections"] == 0
        sock.close()
    finally:
        server.close(timeout_s=10)


def test_close_reclaims_connection_wedged_on_nondraining_client(rng):
    """A client that pipelines big reads and stops draining leaves the
    writer stuck in sendall (reply frames >> socket buffers) and the
    reader stuck in the bounded writeq — server.close() must abort the
    socket, reclaim both threads, and drop the session anyway."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = StorageGateway(mgr, engine=eng, config=GatewayConfig(
        sai=_sai_cfg(hasher="cpu")))
    server = GatewayServer(gw, max_pipeline=2)
    try:
        blob = rng.integers(0, 256, 4 << 20, dtype=np.uint8).tobytes()
        seed = GatewayClient(gw, "seed")
        seed.write("/big", blob)
        seed.close()
        sock = socket.create_connection(server.address, timeout=10)
        send_frame(sock, svc.encode_request(
            svc.OP_OPEN, 0, 1, tenant="wedge", qos="interactive",
            weight=1.0))
        _status, _op, _rid, f = svc.decode_response(recv_frame(sock))
        sid = f["session"]
        for rid in range(2, 8):        # 4 MiB replies, never drained
            send_frame(sock, svc.encode_request(
                svc.OP_READ, sid, rid, path="/big", version=-1,
                verify=True))
        time.sleep(1.0)                # let the writer wedge in sendall
        server.close(timeout_s=2.0)    # must abort, not hang forever
        assert server.snapshot_stats()["open_connections"] == 0
        assert gw.snapshot_stats()["sessions"] == 0
        sock.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_auth_rejects_nonfinite_expiry():
    """A hand-packed token with NaN/inf expiry must be rejected: NaN
    slips past `expiry <= now` and a NaN entry at the expiry-heap root
    would stall replay-cache pruning for every tenant (inf pins its
    entry forever)."""
    import hashlib
    import hmac as hmac_mod

    from repro.serve import auth as auth_mod

    gate = TokenAuthenticator({"acme": b"k"})
    for expiry in (float("nan"), float("inf")):
        body = auth_mod._signed_body(b"acme", expiry, b"e" * 16)
        tok = body + hmac_mod.new(b"k", body,
                                  hashlib.sha256).digest()
        with pytest.raises(AuthError):
            gate.verify(tok, now=1000.0)
    assert not gate._seen and not gate._expiries    # nothing cached


def test_auth_nonce_cache_prunes_and_hides_tenant_existence():
    """The replay cache forgets expired nonces (heap-amortized prune),
    and the unknown-tenant rejection neither names the probed tenant
    nor differs from a bad-signature rejection."""
    gate = TokenAuthenticator({"acme": b"k"})
    tok = mint_token("acme", b"k", ttl_s=5.0, now=1000.0,
                     nonce=b"n" * 16)
    assert gate.verify(tok, now=1001.0) == "acme"
    with pytest.raises(AuthError):              # replay inside window
        gate.verify(tok, now=1002.0)
    # same nonce in a FRESH token long after expiry: the stale cache
    # entry was pruned, so this is accepted (and the cache stays at
    # one live entry, not one per open ever made)
    tok2 = mint_token("acme", b"k", ttl_s=5.0, now=2000.0,
                      nonce=b"n" * 16)
    assert gate.verify(tok2, now=2001.0) == "acme"
    assert len(gate._seen) == 1
    assert len(gate._expiries) == 1
    with pytest.raises(AuthError) as unknown:
        gate.verify(mint_token("nobody", b"x"), now=1000.0)
    assert "nobody" not in str(unknown.value)
    with pytest.raises(AuthError) as forged:
        gate.verify(mint_token("acme", b"wrong"), now=1000.0)
    assert str(forged.value) == str(unknown.value)


def test_inprocess_gateway_with_auth_and_without(rng):
    """Auth is transport-independent: an auth-enforcing gateway demands
    tokens from in-process channels too, and an auth=None gateway keeps
    the PR 4 trusted behavior."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw = StorageGateway(mgr, engine=eng, config=GatewayConfig(
        sai=_sai_cfg(), auth=TokenAuthenticator(SECRETS)))
    try:
        with pytest.raises(AuthError):
            GatewayClient(gw, "acme")
        client = GatewayClient(gw, "acme", secret=SECRETS["acme"])
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        client.write("/f", blob)
        assert client.read("/f") == blob
        client.close()
    finally:
        gw.close()
        eng.shutdown()


# ----------------------------------------------------------------------
# connection lifecycle
# ----------------------------------------------------------------------
def test_abrupt_server_disconnect_resolves_inflight_futures():
    """A server that vanishes mid-request must resolve the channel's
    in-flight ReplyFutures with ST_ERROR (ConnectionError) — waiters
    get an exception, not a hang."""
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    accepted = []

    def fake_server():
        sock, _ = lsock.accept()
        accepted.append(sock)
        recv_frame(sock)                   # swallow one request ...
        sock.close()                       # ... then drop the line

    th = threading.Thread(target=fake_server, daemon=True)
    th.start()
    chan = SocketChannel(lsock.getsockname()[:2])
    try:
        frame = svc.encode_request(svc.OP_STAT, 5, 77, path="/x")
        fut = chan.request(frame)
        status, op, rid, fields = svc.decode_response(fut.result(30))
        assert (status, op, rid) == (svc.ST_ERROR, svc.OP_STAT, 77)
        assert fields["errtype"] == "ConnectionError"
        # the channel is dead: later requests fail fast, not hang
        fut2 = chan.request(svc.encode_request(svc.OP_STAT, 5, 78,
                                               path="/y"))
        status2, _, _, f2 = svc.decode_response(fut2.result(30))
        assert status2 == svc.ST_ERROR
        assert f2["errtype"] == "ConnectionError"
    finally:
        th.join(timeout=10)
        chan.close()
        lsock.close()


def test_half_close_still_drains_responses(rng):
    """A raw client that sends its requests then half-closes its write
    side (EOF at the server reader) still receives every response
    before the server closes the connection."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng, auth=False)
    try:
        blob = rng.integers(0, 256, 2 * 4096, dtype=np.uint8).tobytes()
        seed = GatewayClient(gw, "seed")   # stat target exists already
        seed.write("/pre", blob)           # (stat is served inline, so
        seed.close()                       # it must not race the write)
        sock = socket.create_connection(server.address, timeout=10)
        open_frame = svc.encode_request(svc.OP_OPEN, 0, 1, tenant="hc",
                                        qos="interactive", weight=1.0)
        send_frame(sock, open_frame)
        _status, _op, _rid, f = svc.decode_response(recv_frame(sock))
        sid = f["session"]
        send_frame(sock, svc.encode_request(svc.OP_WRITE, sid, 2,
                                            path="/hc", data=blob))
        send_frame(sock, svc.encode_request(svc.OP_STAT, sid, 3,
                                            path="/pre"))
        sock.shutdown(socket.SHUT_WR)      # half-close: no more requests
        rids = set()
        while True:
            frame = recv_frame(sock)
            if frame is None:
                break
            status, _op, rid, _f = svc.decode_response(frame)
            assert status == svc.ST_OK
            rids.add(rid)
        assert rids == {2, 3}              # both replies drained
        sock.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_hostile_length_prefix_kills_connection_not_server(rng):
    """A connection announcing an over-cap frame is dropped (no
    allocation, frame_errors counted); the server keeps serving new
    connections."""
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng, auth=False)
    try:
        sock = socket.create_connection(server.address, timeout=10)
        sock.sendall(struct.pack("!I", (64 << 20) + 1))
        deadline = time.time() + 30
        while server.snapshot_stats()["frame_errors"] == 0 \
                and time.time() < deadline:
            time.sleep(0.01)
        assert server.snapshot_stats()["frame_errors"] >= 1
        try:
            assert sock.recv(1) == b""     # server closed on us
        except OSError:
            pass                           # RST is also "closed on us"
        sock.close()
        blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        client = GatewayClient(server, "fine")   # still serving
        client.write("/ok", blob)
        assert client.read("/ok") == blob
        client.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_channel_refuses_oversized_send():
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng, auth=False)
    try:
        chan = SocketChannel(server.address, max_frame_bytes=1024)
        big = svc.encode_request(svc.OP_WRITE, 1, 9, path="/big",
                                 data=b"x" * 4096)
        status, _op, rid, f = svc.decode_response(
            chan.request(big).result(30))
        assert (status, rid) == (svc.ST_ERROR, 9)
        assert f["errtype"] == "ConnectionError"
        chan.close()
    finally:
        server.close()
        gw.close()
        eng.shutdown()


def test_server_close_is_graceful_and_idempotent(rng):
    mgr, _ = make_store(4)
    eng = CrystalTPU()
    gw, server = _served(mgr, eng, auth=False)
    client = GatewayClient(server, "t")
    blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    client.write("/f", blob)
    assert client.read("/f") == blob
    server.close()
    server.close()                          # no-op
    assert server.snapshot_stats()["open_connections"] == 0
    # the gateway outlives its listener: in-process clients still work
    inproc = GatewayClient(gw, "t2")
    inproc.write("/g", blob)
    assert inproc.read("/g") == blob
    gw.close()
    eng.shutdown()
