"""Train-step numerics: blocked CE == naive CE; microbatching == full."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.optim import make_optimizer, make_schedule
from repro.train.trainstep import (blocked_cross_entropy, make_loss_fn,
                                   make_train_step)


def test_blocked_ce_matches_naive(rng):
    B, S, d, V = 2, 1024, 16, 50
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.ones((B, S), jnp.float32)
    tot, cnt = blocked_cross_entropy(x, head, labels, mask, chunk=256)
    logits = (x @ head).astype(jnp.float32)
    naive = -jax.nn.log_softmax(logits)[
        jnp.arange(B)[:, None], jnp.arange(S)[None, :], labels]
    np.testing.assert_allclose(float(tot / cnt), float(naive.mean()),
                               rtol=1e-5)


def test_blocked_ce_grads_match(rng):
    B, S, d, V = 2, 512, 8, 40
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.ones((B, S), jnp.float32)

    def blocked(h):
        t, c = blocked_cross_entropy(x, h, labels, mask, chunk=128)
        return t / c

    def naive(h):
        logits = (x @ h).astype(jnp.float32)
        return -jax.nn.log_softmax(logits)[
            jnp.arange(B)[:, None], jnp.arange(S)[None, :], labels].mean()

    g1 = jax.grad(blocked)(head)
    g2 = jax.grad(naive)(head)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_microbatch_equivalence():
    """grad-accumulated step == single-batch step (loss + param delta)."""
    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    rngk = jax.random.PRNGKey(0)
    params = model.init(rngk)
    opt = make_optimizer("adamw", make_schedule("cosine", 1e-3, 100))
    batch = {"tokens": jax.random.randint(rngk, (4, 64), 0,
                                          cfg.vocab_size)}
    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s2 = jax.jit(make_train_step(model, opt, microbatches=2))
    p1, _, m1 = s1(params, opt.init(params), batch,
                   jnp.asarray(0, jnp.int32))
    p2, _, m2 = s2(params, opt.init(params), batch,
                   jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_vlm_loss_alignment():
    """Frontend-embed positions predict the first text token."""
    cfg = get_smoke_config("internvl2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss_fn = make_loss_fn(model)
    B, S = 2, 32
    F = cfg.frontend_embeds
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S - F),
                                     0, cfg.vocab_size),
        "embeds": jax.random.normal(jax.random.PRNGKey(2),
                                    (B, F, cfg.d_model)),
    }
    loss, metrics = jax.jit(loss_fn)(params, batch)
    assert jnp.isfinite(loss)
