"""CA store + SAI system invariants."""
import numpy as np
import pytest
from _hypcompat import given, settings, strategies as st

from repro.core import SAI, SAIConfig, NodeFailure, make_store


def _sai(ca="fixed", hasher="cpu", replication=1, **kw):
    mgr, nodes = make_store(4, replication=replication)
    cfg = SAIConfig(ca=ca, hasher=hasher, block_size=4096, avg_chunk=4096,
                    min_chunk=1024, max_chunk=16384, **kw)
    return SAI(mgr, cfg), mgr, nodes


@settings(max_examples=10, deadline=None)
@given(st.binary(min_size=1, max_size=50_000))
def test_write_read_identity(data):
    sai, _, _ = _sai()
    sai.write("/f", data)
    assert sai.read("/f") == data


def test_dedup_idempotence(rng):
    """Writing the same file twice stores zero new bytes (paper's
    'similar' workload upper bound)."""
    sai, mgr, _ = _sai()
    data = rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes()
    st1 = sai.write("/f", data)
    before = mgr.stats()["stored_bytes"]
    st2 = sai.write("/f", data)
    after = mgr.stats()["stored_bytes"]
    assert st2.new_bytes == 0 and st2.similarity == 1.0
    assert before == after


def test_cross_file_dedup(rng):
    sai, mgr, _ = _sai()
    data = rng.integers(0, 256, 50_000, dtype=np.uint8).tobytes()
    sai.write("/a", data)
    st = sai.write("/b", data)
    assert st.new_bytes == 0


def test_versioning(rng):
    sai, _, _ = _sai()
    v0 = rng.integers(0, 256, 20_000, dtype=np.uint8).tobytes()
    v1 = v0[:10_000] + b"new data" + v0[10_000:]
    sai.write("/f", v0)
    sai.write("/f", v1)
    assert sai.read("/f", version=0) == v0
    assert sai.read("/f", version=1) == v1


def test_replication_survives_node_failure(rng):
    sai, mgr, nodes = _sai(replication=2)
    data = rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    mgr.handle_node_failure(1)
    assert sai.read("/f") == data
    # a second failure after re-replication still survives
    mgr.handle_node_failure(2)
    assert sai.read("/f") == data


def test_unreplicated_failure_detected(rng):
    sai, mgr, nodes = _sai(replication=1)
    data = rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    for n in nodes:
        n.fail()
    with pytest.raises(NodeFailure):
        sai.read("/f")


def test_corruption_detected(rng):
    sai, mgr, _ = _sai()
    data = rng.integers(0, 256, 30_000, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    digest = next(iter(mgr.block_registry))
    for nid in mgr.block_registry[digest]:
        blk = mgr.nodes[nid].blocks[digest]
        mgr.nodes[nid].blocks[digest] = bytes([blk[0] ^ 1]) + blk[1:]
    with pytest.raises(IOError):
        sai.read("/f")


def test_non_ca_mode(rng):
    sai, mgr, _ = _sai(ca="none")
    data = rng.integers(0, 256, 30_000, dtype=np.uint8).tobytes()
    st1 = sai.write("/f", data)
    st2 = sai.write("/f", data)          # no dedup in non-CA mode
    assert st1.new_bytes == st2.new_bytes == len(data)
    assert sai.read("/f") == data


def test_gc_unreferenced(rng):
    sai, mgr, _ = _sai()
    d1 = rng.integers(0, 256, 20_000, dtype=np.uint8).tobytes()
    sai.write("/f", d1)
    mgr.files.clear()                     # drop all block-maps
    removed = mgr.gc_unreferenced()
    assert removed > 0
    assert mgr.stats()["stored_bytes"] == 0


def test_tpu_and_cpu_hashers_agree(rng):
    """Same digests (and therefore dedup) from the kernel and hashlib."""
    data = rng.integers(0, 256, 40_000, dtype=np.uint8).tobytes()
    sai_t, mgr_t, _ = _sai(ca="fixed", hasher="tpu")
    sai_c, mgr_c, _ = _sai(ca="fixed", hasher="cpu")
    sai_t.write("/f", data)
    sai_c.write("/f", data)
    assert set(mgr_t.block_registry) == set(mgr_c.block_registry)


def test_get_read_plan_consistent_with_lookups(rng):
    """get_read_plan returns the same block-map and locations as the
    per-block lookup path, in one lock acquisition."""
    sai, mgr, _ = _sai()
    data = rng.integers(0, 256, 30_000, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    fv, locmap = mgr.get_read_plan("/f")
    assert fv is mgr.get_blockmap("/f")
    for b in fv.blocks:
        assert locmap[b.digest] == mgr.lookup_block(b.digest)
    none_fv, none_map = mgr.get_read_plan("/missing")
    assert none_fv is None and none_map == {}


def test_read_survives_stale_plan_after_failover(rng):
    """A block re-replicated after the read plan snapshot is still
    fetched via the fresh-lookup fallback."""
    sai, mgr, nodes = _sai(replication=2)
    data = rng.integers(0, 256, 30_000, dtype=np.uint8).tobytes()
    sai.write("/f", data)
    fv, locmap = mgr.get_read_plan("/f")
    mgr.handle_node_failure(0)           # moves blocks, registry changes
    assert sai._fetch_blocks(fv.blocks, locmap)  # stale map still works
    assert sai.read("/f") == data
