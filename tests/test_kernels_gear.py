"""Gear rolling-hash kernel vs ref oracle + chunking-equivalence with the
sequential FastCDC recurrence."""
import jax.numpy as jnp
import numpy as np
from _hypcompat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.core.sai import _cpu_gear


def test_gear_kernel_vs_ref(rng):
    L = 5000
    buf = rng.integers(0, 256, L, dtype=np.uint8)
    got = ops.gear_hash(buf.tobytes())
    want = np.asarray(ref.gear_ref(jnp.asarray(buf)))
    # positions < window differ (zero-history convention); beyond, exact
    np.testing.assert_array_equal(got[32:], want[32:])


def test_gear_kernel_vs_sequential_recurrence(rng):
    """The convolution form == the FastCDC h=(h<<1)+g recurrence."""
    L = 1000
    buf = rng.integers(0, 256, L, dtype=np.uint8)
    seq = _cpu_gear(buf.tobytes(), vectorized=False)
    vec = _cpu_gear(buf.tobytes(), vectorized=True)
    par = ops.gear_hash(buf.tobytes())
    np.testing.assert_array_equal(vec[32:], seq[32:])
    np.testing.assert_array_equal(par[32:], seq[32:])


def test_gear_window_property(rng):
    """h at position p depends only on bytes (p-31 .. p)."""
    L = 600
    a = rng.integers(0, 256, L, dtype=np.uint8)
    b = a.copy()
    b[:L - 64] = rng.integers(0, 256, L - 64, dtype=np.uint8)
    ha = ops.gear_hash(a.tobytes())
    hb = ops.gear_hash(b.tobytes())
    np.testing.assert_array_equal(ha[L - 32:], hb[L - 32:])


@settings(max_examples=10, deadline=None)
@given(st.binary(min_size=64, max_size=2048))
def test_gear_hypothesis_matches_ref(data):
    got = ops.gear_hash(data)
    want = np.asarray(ref.gear_ref(jnp.asarray(
        np.frombuffer(data, np.uint8))))
    np.testing.assert_array_equal(got[32:], want[32:])
