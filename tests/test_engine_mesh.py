"""Engine-mesh behaviours (ISSUE 6): whale-job sharding, load-aware
dispatch, adaptive fusion, manager crash recovery, per-device stats.

Most tests run in-process with the single host device duplicated
(``devices=[dev]*4`` gives four managers/queues over one physical
device — the scheduling logic is identical); one subprocess test forces
real multi-device scheduling with
``--xla_force_host_platform_device_count=4`` (SNIPPETS snippet 1).
"""
import hashlib
import os
import subprocess
import sys
import textwrap
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.crystal import CrystalTPU
from repro.kernels import ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh(n=4, **kw):
    dev = jax.devices()[0]
    return CrystalTPU(devices=[dev] * n, **kw)


def _md5_rows(rows):
    return np.stack([np.frombuffer(hashlib.md5(r.tobytes()).digest(),
                                   np.uint8) for r in rows])


# ---------------------------------------------------------------------
# sharding: digests must be byte-identical to the unsharded reference
# ---------------------------------------------------------------------

def test_sharded_direct_digest_equality():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 256, (16, 8192), np.uint8)
    eng = _mesh(4, shard_min_bytes=32 << 10)
    try:
        got = eng.submit("direct", rows, {}).wait()
        assert np.array_equal(got, _md5_rows(rows))
        st = eng.snapshot_stats()
        assert st["sharded_jobs"] == 1
        assert st["shards"] >= 2
        busy = [d for d in st["per_device"].values() if d["jobs"]]
        assert len(busy) >= 2, st["per_device"]
    finally:
        eng.shutdown()


def test_sharded_stream_digest_equality():
    rng = np.random.default_rng(1)
    sbuf = rng.integers(0, 256, (64 << 10) + 17, np.uint8)
    gbuf = rng.integers(0, 256, (160 << 10) + 5, np.uint8)
    eng = _mesh(4, shard_min_bytes=16 << 10)
    try:
        sj = eng.submit("sliding", sbuf, {"window": 48, "stride": 4})
        gj = eng.submit("gear", gbuf, {})
        assert np.array_equal(
            sj.wait(), ops.sliding_window_hash(sbuf.tobytes(), 48, 4))
        assert np.array_equal(gj.wait(),
                              ops.gear_hash(gbuf.tobytes()))
        assert eng.snapshot_stats()["sharded_jobs"] == 2
    finally:
        eng.shutdown()


def test_small_jobs_do_not_shard():
    eng = _mesh(2, shard_min_bytes=1 << 20)
    try:
        rows = np.zeros((4, 1024), np.uint8)
        assert np.array_equal(eng.submit("direct", rows, {}).wait(),
                              _md5_rows(rows))
        assert eng.snapshot_stats()["sharded_jobs"] == 0
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------
# load-aware dispatch: a slow device receives less work
# ---------------------------------------------------------------------

def test_load_aware_dispatch_skews_away_from_slow_device():
    eng = _mesh(4, coalesce=False)
    eng._launch_hook = lambda idx, batch: (time.sleep(0.05)
                                           if idx == 0 else None)
    total = 30
    try:
        jobs = []
        for _ in range(total):
            jobs.append(eng.submit(
                "direct", np.ones((1, 4096), np.uint8), {}))
            time.sleep(0.01)       # pace so backlog signals can develop
        for j in jobs:
            j.wait()
        per = eng.snapshot_stats()["per_device"]
        assert sum(d["jobs"] for d in per.values()) == total
        assert per[0]["jobs"] < total / 3, {
            i: d["jobs"] for i, d in per.items()}
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------
# adaptive fusion: caps move in the direction the measurements demand
# ---------------------------------------------------------------------

def test_adaptive_caps_grow_under_launch_overhead():
    """Tiny same-size jobs + injected fixed launch latency = overhead-
    dominated regime: the policy should open the fusion caps."""
    eng = _mesh(1, adaptive_fusion=True, max_fused_rows=4,
                max_fused_bytes=64 << 10)
    eng._launch_hook = lambda idx, batch: time.sleep(0.008)
    try:
        for _ in range(12):
            eng.submit("direct", np.ones((1, 4096), np.uint8),
                       {}).wait()
        assert eng.max_fused_bytes > 64 << 10
        assert eng.max_fused_rows > 4
        pol = eng.snapshot_stats()["policy"]
        assert pol["adaptive"] == 1
        assert pol["max_fused_bytes"] == eng.max_fused_bytes
    finally:
        eng.shutdown()


def test_adaptive_caps_shrink_under_latency_target():
    """Varied job sizes + injected per-byte latency teach the cost model
    a real slope; the target launch latency then bounds the byte cap
    below the static guess."""
    eng = _mesh(1, adaptive_fusion=True, max_fused_rows=64,
                max_fused_bytes=1 << 20, target_launch_s=0.1)
    eng._launch_hook = lambda idx, batch: time.sleep(
        3e-6 * sum(j.padded_bytes for j in batch))
    try:
        for _ in range(8):
            for kb in (16, 32, 64):
                eng.submit("direct",
                           np.ones((1, kb << 10), np.uint8), {}).wait()
        assert eng.max_fused_bytes < 1 << 20, eng.max_fused_bytes
    finally:
        eng.shutdown()


def test_static_mode_caps_never_move():
    eng = _mesh(1, max_fused_rows=8, max_fused_bytes=1 << 20)
    try:
        for _ in range(6):
            eng.submit("direct", np.ones((1, 4096), np.uint8),
                       {}).wait()
        assert eng.max_fused_rows == 8
        assert eng.max_fused_bytes == 1 << 20
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------
# manager crash resilience
# ---------------------------------------------------------------------

def test_manager_crash_fails_batch_and_requeues_rest():
    eng = _mesh(2, coalesce=False)
    fired = threading.Event()

    def fault(idx, batch):
        if idx == 0 and not fired.is_set():
            fired.set()
            raise RuntimeError("injected manager crash")

    eng._fault_hook = fault
    data = np.ones((1, 4096), np.uint8)
    ref = _md5_rows(data)
    try:
        jobs = [eng.submit("direct", data, {}) for _ in range(12)]
        failures, successes = 0, 0
        for j in jobs:
            try:
                assert np.array_equal(j.wait(), ref)
                successes += 1
            except RuntimeError as e:
                assert "injected manager crash" in str(e)
                failures += 1
        assert fired.is_set()
        assert failures >= 1
        assert successes == 12 - failures
        st = eng.snapshot_stats()
        assert st["manager_restarts"] == 1
        assert sum(d["manager_restarts"]
                   for d in st["per_device"].values()) == 1
        # the restarted manager still serves its queue
        assert np.array_equal(eng.submit("direct", data, {}).wait(), ref)
        assert eng.queue_depth() == 0
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------
# octave classes: tiny and huge stream jobs must never share a launch
# ---------------------------------------------------------------------

def test_tiny_and_huge_stream_jobs_never_fuse():
    rng = np.random.default_rng(2)
    tiny = rng.integers(0, 256, 2048, np.uint8)
    huge = rng.integers(0, 256, 256 << 10, np.uint8)
    eng = _mesh(1, coalesce_window_s=0.25)
    try:
        assert (eng.policy.octave_class(tiny.size)
                != eng.policy.octave_class(huge.size))
        tj = eng.submit("gear", tiny, {})
        hj = eng.submit("gear", huge, {})
        assert np.array_equal(tj.wait(), ops.gear_hash(tiny.tobytes()))
        assert np.array_equal(hj.wait(), ops.gear_hash(huge.tobytes()))
        st = eng.snapshot_stats()
        assert st["jobs"] == 2
        assert st["launches"] == 2      # a fused pair would show 1
    finally:
        eng.shutdown()


def test_octave_class_is_true_power_of_two_octave():
    eng = _mesh(1)
    try:
        oc = eng.policy.octave_class
        assert oc(4096) == 13
        assert oc(8192) == 14           # adjacent octaves distinct
        assert oc(4096) != oc(8191 + 1)
        assert oc(6000) == oc(4097)     # same octave fuses
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------
# per-device stats + queue depth API
# ---------------------------------------------------------------------

def test_per_device_stats_and_queue_depth():
    eng = _mesh(2)
    try:
        data = np.ones((2, 4096), np.uint8)
        for _ in range(4):
            eng.submit("direct", data, {}).wait()
        st = eng.snapshot_stats()
        assert set(st["per_device"]) == {0, 1}
        for row in st["per_device"].values():
            for key in ("jobs", "launches", "bytes", "ewma_launch_s",
                        "ewma_bucket_s", "queue_depth", "queued_bytes",
                        "slowdown", "manager_restarts"):
                assert key in row, key
        assert sum(d["jobs"] for d in st["per_device"].values()) == 4
        assert "policy" in st and "cost_model" in st
        assert eng.queue_depth() == 0
        assert eng.queue_depth("fg", device=0) == 0
        assert eng.queue_depth(device=1) == 0
        with pytest.raises(IndexError):
            eng.queue_depth(device=7)
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------
# real multi-device scheduling (forced host devices, subprocess)
# ---------------------------------------------------------------------

def test_forced_multi_device_sharding_subprocess():
    code = textwrap.dedent("""
        import hashlib
        import jax, numpy as np
        from repro.core.crystal import CrystalTPU
        devs = jax.devices()
        assert len(devs) == 4, devs
        rng = np.random.default_rng(3)
        rows = rng.integers(0, 256, (16, 8192), np.uint8)
        ref = np.stack([np.frombuffer(
            hashlib.md5(r.tobytes()).digest(), np.uint8) for r in rows])
        eng = CrystalTPU(devices=list(devs), shard_min_bytes=32 << 10)
        got = eng.submit("direct", rows, {}).wait()
        assert np.array_equal(got, ref)
        st = eng.snapshot_stats()
        eng.shutdown()
        assert st["sharded_jobs"] == 1, st
        busy = [i for i, d in st["per_device"].items() if d["jobs"]]
        assert len(busy) >= 2, st["per_device"]
        print("MESH_OK", st["shards"], busy)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_OK" in out.stdout
