"""Perf-regression gate: fresh BENCH_smoke.json vs committed baseline.

``make bench-compare`` (and the CI step behind it) runs::

    python benchmarks/compare.py BENCH_baseline.json BENCH_smoke.json

Row policy (the per-row tolerance bands):

* every baseline row must exist in the fresh run (a silently vanished
  benchmark is itself a regression);
* latency-like rows (``/latency_p*``, ``/fsync_p*``, ``health/`` tick
  timings excluded) are **higher-is-worse**: the fresh ``us_per_call``
  must stay under ``baseline * (1 + tol) + floor_us``.  The band is
  deliberately generous (defaults: tol x6 + 25 ms floor, overridable
  via ``BENCH_COMPARE_TOL`` / ``BENCH_COMPARE_FLOOR_US``) because the
  committed baseline and the CI runner are different machines — the
  gate exists to catch order-of-magnitude regressions, not scheduler
  jitter;
* correctness counters (``counters`` keys ending in ``.ok``) must match
  **exactly** — an ok-flag is a boolean claim, not a measurement.

Exit status 1 prints every offending row; 0 prints the pass summary.
To refresh the baseline intentionally, run ``make bench-smoke`` and
copy ``BENCH_smoke.json`` over ``BENCH_baseline.json`` in the same PR
that changes the performance.
"""
from __future__ import annotations

import json
import os
import re
import sys
from typing import Dict, List, Tuple

LATENCY_ROW = re.compile(r"/latency_p\d+|/fsync_p\d+")

DEFAULT_TOL = 6.0          # fresh may be up to (1 + tol) x baseline
DEFAULT_FLOOR_US = 25000.0  # plus this absolute slack (cross-machine)


def _rows(summary: Dict) -> Dict[str, float]:
    return {r["name"]: float(r["us_per_call"])
            for r in summary.get("rows", [])}


def compare(baseline: Dict, fresh: Dict,
            tol: float = DEFAULT_TOL,
            floor_us: float = DEFAULT_FLOOR_US) -> Tuple[bool, List[str]]:
    """-> (ok, problems).  Pure so tests can feed synthetic JSON."""
    problems: List[str] = []
    base_rows, fresh_rows = _rows(baseline), _rows(fresh)

    for name, base_us in sorted(base_rows.items()):
        if name not in fresh_rows:
            problems.append(f"MISSING ROW   {name} (baseline "
                            f"{base_us:.1f} us, absent from fresh run)")
            continue
        if not LATENCY_ROW.search(name):
            continue
        limit = base_us * (1.0 + tol) + floor_us
        got = fresh_rows[name]
        if got > limit:
            problems.append(
                f"LATENCY REGR  {name}: {got:.1f} us > limit "
                f"{limit:.1f} us (baseline {base_us:.1f} us, "
                f"tol x{1.0 + tol:g} + {floor_us:.0f} us floor)")

    base_ctr = baseline.get("counters", {})
    fresh_ctr = fresh.get("counters", {})
    for key, want in sorted(base_ctr.items()):
        if not key.endswith(".ok"):
            continue
        got = fresh_ctr.get(key)
        if got is None:
            problems.append(f"MISSING CTR   {key} (baseline {want})")
        elif float(got) != float(want):
            problems.append(f"COUNTER DIFF  {key}: {got} != "
                            f"baseline {want}")
    return not problems, problems


def main(argv: List[str]) -> int:
    if len(argv) != 3:
        print(f"usage: {argv[0]} BASELINE.json FRESH.json",
              file=sys.stderr)
        return 2
    with open(argv[1], "r", encoding="utf-8") as fh:
        baseline = json.load(fh)
    with open(argv[2], "r", encoding="utf-8") as fh:
        fresh = json.load(fh)
    tol = float(os.environ.get("BENCH_COMPARE_TOL", DEFAULT_TOL))
    floor_us = float(os.environ.get("BENCH_COMPARE_FLOOR_US",
                                    DEFAULT_FLOOR_US))
    ok, problems = compare(baseline, fresh, tol=tol, floor_us=floor_us)
    if not ok:
        print(f"bench-compare: {len(problems)} regression(s) vs "
              f"{argv[1]}:")
        for p in problems:
            print(f"  {p}")
        return 1
    n_lat = sum(1 for n in _rows(baseline) if LATENCY_ROW.search(n))
    n_ok = sum(1 for k in baseline.get("counters", {})
               if k.endswith(".ok"))
    print(f"bench-compare: OK ({len(_rows(baseline))} baseline rows "
          f"present, {n_lat} latency rows within x{1.0 + tol:g}"
          f"+{floor_us:.0f}us band, {n_ok} ok-flags exact)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
