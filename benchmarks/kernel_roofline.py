"""Kernel-level roofline for the hashing kernels (the paper-technique
§Perf hillclimb's measurement harness).

VPU-op counts are MEASURED from the compiled HLO via the repo's analyzer
(XLA's 'flops' metric ignores most integer ops); the v5e projection is
peak-int-ops / measured-ops-per-byte vs the HBM streaming bound."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import V5E_HBM_BW, V5E_INT_OPS, synth_data
from repro.roofline.hlo_analysis import analyze_hlo


def run() -> list:
    rows: list = []
    size = 512 << 10   # 128 x 4KB segments = full lane tile
    buf = np.frombuffer(synth_data(size), np.uint8)
    words = jnp.asarray(buf.view("<u4"))

    from repro.kernels.ops import (_direct_hash_words,
                                   _gear_hash_words_batch,
                                   _sliding_hash_words_batch)
    segs = jnp.asarray(np.ascontiguousarray(buf.reshape(-1, 4096)).view(
        "<u4"))
    lens = jnp.full((segs.shape[0],), segs.shape[1], jnp.int32)

    batch = words[None]                # B=1 row of the fused entry points
    cases = [
        ("sliding_md5_stride1", _sliding_hash_words_batch.lower(
            batch, w_words=12, phases=(0, 1, 2, 3))),
        ("sliding_md5_stride4", _sliding_hash_words_batch.lower(
            batch, w_words=12, phases=(0,))),
        ("gear_v1", _gear_hash_words_batch.lower(batch, version=1)),
        ("gear_v2_doubling", _gear_hash_words_batch.lower(batch,
                                                          version=2)),
        ("gear_v3_hybrid", _gear_hash_words_batch.lower(batch,
                                                        version=3)),
        ("direct_md5_4k", _direct_hash_words.lower(segs, lens)),
    ]
    for name, lowered in cases:
        an = analyze_hlo(lowered.compile().as_text())
        opb = an["int_ops"] / size
        t_comp = opb / V5E_INT_OPS                 # s/byte compute
        t_mem = 1.0 / V5E_HBM_BW                   # s/byte stream
        bound = "vpu" if t_comp > t_mem else "hbm"
        thr = 1.0 / max(t_comp, t_mem)
        rows.append((f"kernel_roofline/{name}", 1e6 * size * max(t_comp,
                                                                 t_mem),
                     f"opsPerByte={opb:.1f}_v5e={thr/1e6:.0f}MBps_"
                     f"bound={bound}"))
    return rows
