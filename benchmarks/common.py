"""Shared benchmark utilities.

Measured numbers on this container are CPU-hosted (Pallas interpret mode
executes kernel bodies via XLA:CPU); each bench also derives the TPU-v5e
projection from the kernel's static op counts where meaningful.  The CSV
contract is ``name,us_per_call,derived`` (derived = bench-specific:
speedup, throughput MB/s, similarity %, ...).
"""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

import numpy as np

Row = Tuple[str, float, str]

# BENCH_SMOKE=1 shrinks every module's problem sizes so the whole harness
# runs in CI on every PR (make bench-smoke) — same code paths, tiny data.
SMOKE = os.environ.get("BENCH_SMOKE", "0") not in ("", "0")


def scaled(full, tiny):
    """Pick the full-size or smoke-size variant of a bench parameter."""
    return tiny if SMOKE else full


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def mbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e6


def synth_data(n: int, seed: int = 0) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, n, dtype=np.uint8).tobytes()


def checkpoint_series(n_images: int, image_bytes: int,
                      change_frac: float = 0.15, seed: int = 0):
    """Synthetic BLCR-like checkpoint images: each successive image
    rewrites a contiguous region in place AND applies an insert/delete
    pair (heap growth shifts content — what makes fixed-block dedup fail
    in the paper: 21-23% fixed vs 76-90% CDC similarity)."""
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 256, image_bytes, dtype=np.uint8)
    out = [img.tobytes()]
    for i in range(1, n_images):
        buf = bytearray(img.tobytes())
        span = int(image_bytes * change_frac)
        start = int(rng.integers(0, len(buf) - span))
        buf[start:start + span] = rng.integers(
            0, 256, span, dtype=np.uint8).tobytes()
        # insert/delete pair: shifts everything between the two points
        k = int(rng.integers(1, 4096))
        ins = int(rng.integers(0, len(buf)))
        buf[ins:ins] = rng.integers(0, 256, k, dtype=np.uint8).tobytes()
        del_at = int(rng.integers(0, len(buf) - k))
        del buf[del_at:del_at + k]
        img = np.frombuffer(bytes(buf), dtype=np.uint8)
        out.append(bytes(buf))
    return out


# TPU v5e model for projections (same constants as §Roofline)
V5E_PEAK_BF16 = 197e12
V5E_HBM_BW = 819e9
# VPU integer throughput: 8x128 lanes * 2 ops/cycle? conservatively
# 1 int32 op/lane/cycle at 940 MHz x 4 MXU-adjacent VPUs ~ 3.9e12 ops/s.
V5E_INT_OPS = 3.9e12

# uint32 ALU ops per byte of input — MEASURED from compiled kernel HLO
# by the repo's analyzer (benchmarks/kernel_roofline.py); napkin values
# in comments
OPS_PER_BYTE = {
    "sliding_md5": 635.3,            # stride 1 (napkin 640)
    "direct_md5": 60.9,              # napkin ~12; padding-select machinery
    "gear": 85.0,                    # napkin ~73
}


def project_v5e_throughput(kind: str) -> float:
    """Projected bytes/s on one v5e chip for a VPU-bound hashing kernel."""
    return V5E_INT_OPS / OPS_PER_BYTE[kind]
