"""Engine-mesh ablation (ISSUE 6): 1 vs N devices on a whale job,
static vs adaptive fusion on a small-job burst, per-device dispatch
stats.

The measurements run in a child process launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (SNIPPETS
snippet-1 technique) so multi-device scheduling is exercised on
CPU-only hosts; the parent process keeps its single default device and
only parses the child's JSON rows.

Digest checks: the child verifies every mode — single-device whale,
sharded whale, sharded sliding/gear streams, both fusion bursts —
byte-for-byte against the hashlib / ops CPU reference and reports
``digest_ok``; ``run()`` asserts it, so a sharding or fusion bug fails
the bench run.  The 1-vs-N ``speedup`` is reported as a measured
counter, not asserted: forced host devices share the machine's cores,
so on a single-core container the shards serialize (speedup ~1x or
below); multi-core hosts are where the sharded row should beat the
single-device row.  The adaptive-vs-static contract IS asserted:
at equal submitted ``jobs``, the adaptive-fusion round must need no
more ``launches`` than the static-cap round.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import scaled

N_DEVICES = 4
WHALE_ROWS = scaled(192, 32)          # whale direct job: rows x ROW_KB
WHALE_ROW_KB = scaled(64, 8)
SLIDE_KB = scaled(384, 96)            # sharded stream buffers
GEAR_KB = scaled(768, 192)
BURST_JOBS = scaled(48, 16)           # fusion-ablation burst
BURST_CHUNK_KB = scaled(8, 4)
STATIC_CAP_ROWS = 4                   # deliberately small static guess
STATIC_CAP_BYTES = 1 << 20


def _child() -> None:
    import hashlib
    import time

    import jax
    import numpy as np

    from benchmarks.common import mbps, timeit
    from repro.core.crystal import CrystalTPU
    from repro.kernels import ops

    devs = jax.devices()
    rng = np.random.default_rng(7)
    rows_arr = rng.integers(0, 256, (WHALE_ROWS, WHALE_ROW_KB << 10),
                            np.uint8)
    total = rows_arr.size
    ref = np.stack([np.frombuffer(hashlib.md5(rows_arr[i].tobytes())
                                  .digest(), np.uint8)
                    for i in range(WHALE_ROWS)])
    digest_ok = True
    rows: list = []

    def whale(devices, shard_min):
        nonlocal digest_ok
        eng = CrystalTPU(devices=devices, shard_min_bytes=shard_min)
        got = eng.submit("direct", rows_arr, {}).wait()   # warm + check
        digest_ok &= bool(np.array_equal(got, ref))
        sec = timeit(lambda: eng.submit("direct", rows_arr, {}).wait(),
                     repeats=3, warmup=0)
        stats = eng.snapshot_stats()
        eng.shutdown()
        return sec, stats

    sec1, _ = whale([devs[0]], 1 << 62)
    # shard threshold sized so the whale splits one shard per device
    padded = WHALE_ROWS * (1 << (rows_arr.shape[1] - 1).bit_length())
    secN, statsN = whale(list(devs), max(padded // len(devs), 1))
    speedup = sec1 / max(secN, 1e-12)
    rows.append(("mesh/whale_1dev", sec1 * 1e6,
                 f"mbps={mbps(total, sec1):.1f}"))
    rows.append((f"mesh/whale_{len(devs)}dev_sharded", secN * 1e6,
                 f"mbps={mbps(total, secN):.1f}_speedup={speedup:.2f}_"
                 f"sharded_jobs={statsN['sharded_jobs']}_"
                 f"shards={statsN['shards']}"))
    for i, ds in sorted(statsN["per_device"].items()):
        rows.append((f"mesh/device_{i}", ds["ewma_launch_s"] * 1e6,
                     f"jobs={ds['jobs']}_launches={ds['launches']}_"
                     f"bytes={ds['bytes']}_"
                     f"queue_depth={ds['queue_depth']}_"
                     f"restarts={ds['manager_restarts']}"))

    # sharded streams: digests must equal the unsharded ops oracle
    eng = CrystalTPU(devices=list(devs), shard_min_bytes=32 << 10)
    sbuf = rng.integers(0, 256, (SLIDE_KB << 10) + 17, dtype=np.uint8)
    gbuf = rng.integers(0, 256, (GEAR_KB << 10) + 5, dtype=np.uint8)
    t0 = time.perf_counter()
    sj = eng.submit("sliding", sbuf, {"window": 48, "stride": 4})
    gj = eng.submit("gear", gbuf, {})
    s_got, g_got = sj.wait(), gj.wait()
    stream_s = time.perf_counter() - t0
    digest_ok &= bool(np.array_equal(
        s_got, ops.sliding_window_hash(sbuf.tobytes(), 48, 4)))
    digest_ok &= bool(np.array_equal(g_got,
                                     ops.gear_hash(gbuf.tobytes())))
    st = eng.snapshot_stats()
    eng.shutdown()
    rows.append(("mesh/stream_shard", stream_s * 1e6,
                 f"ok={int(digest_ok)}_sharded_jobs={st['sharded_jobs']}"
                 f"_shards={st['shards']}"))

    # static vs adaptive fusion: identical two-round burst, round-2
    # launch counts compared at equal job counts
    chunk = rng.integers(0, 256, BURST_CHUNK_KB << 10, dtype=np.uint8)
    want = np.frombuffer(hashlib.md5(chunk.tobytes()).digest(), np.uint8)

    def burst(adaptive):
        nonlocal digest_ok
        eng = CrystalTPU(devices=[devs[0]],
                         max_fused_rows=STATIC_CAP_ROWS,
                         max_fused_bytes=STATIC_CAP_BYTES,
                         coalesce_window_s=0.05,
                         adaptive_fusion=adaptive)
        deltas = []
        for _ in range(2):               # round 1 warms model + caps
            before = eng.snapshot_stats()
            t0 = time.perf_counter()
            jobs = [eng.submit("direct", chunk, {})
                    for _ in range(BURST_JOBS)]
            for j in jobs:
                digest_ok &= bool(np.array_equal(j.wait()[0], want))
            sec = time.perf_counter() - t0
            after = eng.snapshot_stats()
            deltas.append((after["jobs"] - before["jobs"],
                           after["launches"] - before["launches"], sec))
        policy = eng.snapshot_stats()["policy"]
        eng.shutdown()
        return deltas[-1], policy

    (jobs_s, launches_s, sec_s), _ = burst(False)
    (jobs_a, launches_a, sec_a), pol = burst(True)
    rows.append(("mesh/fusion_static", sec_s * 1e6,
                 f"jobs={jobs_s}_launches={launches_s}"))
    rows.append(("mesh/fusion_adaptive", sec_a * 1e6,
                 f"jobs={jobs_a}_launches={launches_a}_"
                 f"cap_rows={pol['max_fused_rows']}_"
                 f"cap_bytes={pol['max_fused_bytes']}"))
    rows.append(("mesh/digest_ok", 0.0, f"ok={int(digest_ok)}"))
    print(json.dumps({
        "n_devices": len(devs), "digest_ok": digest_ok, "rows": rows,
        "fusion": {"jobs_static": jobs_s, "jobs_adaptive": jobs_a,
                   "launches_static": launches_s,
                   "launches_adaptive": launches_a},
    }))


def run() -> list:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                        f"{N_DEVICES}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        env=env, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError("engine_mesh child failed:\n"
                           + proc.stderr[-4000:])
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = [tuple(r) for r in payload["rows"]]
    assert payload["n_devices"] == N_DEVICES, payload["n_devices"]
    assert payload["digest_ok"], \
        "sharded/fused digests diverged from the CPU reference"
    fus = payload["fusion"]
    assert fus["jobs_static"] == fus["jobs_adaptive"], fus
    assert fus["launches_adaptive"] <= fus["launches_static"], fus
    assert any(n.startswith("mesh/device_") for n, _, _ in rows)
    return rows


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        for r in run():
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
