"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run fig5 fig11``.  Pipeline-stage rows
(``.../stage_*`` from ``WriteStats.stage_s``) and engine
launch/coalesce counter rows (``.../engine_*``) ride along with their
figure's throughput rows so fused-launch regressions are visible in the
perf trajectory.  ``BENCH_SMOKE=1`` (the ``make bench-smoke`` CI target)
shrinks every module's sizes so the whole harness runs on each PR.

``BENCH_JSON=<path>`` additionally writes a machine-readable summary:
every CSV row, per-module pass/fail, and a flat ``counters`` map parsed
from the ``k=v`` pairs embedded in the derived column (engine/gateway
launch, coalesce, rejection counters ...) — the artifact CI uploads so
the perf trajectory is trackable PR-over-PR.
"""
from __future__ import annotations

import json
import os
import re
import sys
import traceback

MODULES = [
    "benchmarks.fig4_stages",
    "benchmarks.fig5_sliding",
    "benchmarks.fig6_direct",
    "benchmarks.fig7_10_workloads",
    "benchmarks.fig11_checkpoint",
    "benchmarks.read_path",
    "benchmarks.scrub_interference",
    "benchmarks.recovery",
    "benchmarks.gateway_saturation",
    "benchmarks.engine_mesh",
    "benchmarks.fig12_17_competing",
    "benchmarks.sec4_2_cpu_vs_accel",
    "benchmarks.kernel_roofline",
]

# k=v pairs are '_'-separated in derived strings and keys are
# lower_snake_case; anchoring at the separator keeps unit suffixes of
# the previous value (``0.5MBps_completed=4``) out of the key
_KV = re.compile(r"(?:^|_)([a-z]\w*)=(-?[0-9]+(?:\.[0-9]+)?)")


def _write_json(path: str, rows, modules) -> None:
    counters = {}
    for name, us, derived in rows:
        for key, val in _KV.findall(str(derived)):
            counters[f"{name}.{key}"] = float(val)
    # latency-distribution rows (gateway request p50/p95/p99, WAL fsync
    # percentiles) folded into their own block so dashboards don't have
    # to regex the row names back apart
    obs = {name: us for name, us, _ in rows
           if "/latency_p" in name or "/fsync_p" in name}
    summary = {
        "schema": 1,
        "smoke": os.environ.get("BENCH_SMOKE", "0") not in ("", "0"),
        "modules": modules,
        "rows": [{"name": n, "us_per_call": u, "derived": d}
                 for n, u, d in rows],
        "counters": counters,
        "obs": obs,
    }
    with open(path, "w") as fh:
        json.dump(summary, fh, indent=1, sort_keys=True)
        fh.write("\n")


def main() -> None:
    want = sys.argv[1:]
    print("name,us_per_call,derived")
    failed = 0
    all_rows = []
    modules = {}
    for modname in MODULES:
        short = modname.split(".")[-1]
        if want and not any(w in short for w in want):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
                all_rows.append((name, us, derived))
            modules[short] = "ok"
        except Exception:
            failed += 1
            modules[short] = "error"
            print(f"{short},ERROR,see_stderr", flush=True)
            traceback.print_exc()
    json_path = os.environ.get("BENCH_JSON")
    if json_path:
        _write_json(json_path, all_rows, modules)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
