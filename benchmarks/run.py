"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run fig5 fig11``.  Pipeline-stage rows
(``.../stage_*`` from ``WriteStats.stage_s``) and engine
launch/coalesce counter rows (``.../engine_*``) ride along with their
figure's throughput rows so fused-launch regressions are visible in the
perf trajectory.  ``BENCH_SMOKE=1`` (the ``make bench-smoke`` CI target)
shrinks every module's sizes so the whole harness runs on each PR.
"""
from __future__ import annotations

import sys
import traceback

MODULES = [
    "benchmarks.fig4_stages",
    "benchmarks.fig5_sliding",
    "benchmarks.fig6_direct",
    "benchmarks.fig7_10_workloads",
    "benchmarks.fig11_checkpoint",
    "benchmarks.read_path",
    "benchmarks.scrub_interference",
    "benchmarks.fig12_17_competing",
    "benchmarks.sec4_2_cpu_vs_accel",
    "benchmarks.kernel_roofline",
]


def main() -> None:
    want = sys.argv[1:]
    print("name,us_per_call,derived")
    failed = 0
    for modname in MODULES:
        short = modname.split(".")[-1]
        if want and not any(w in short for w in want):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failed += 1
            print(f"{short},ERROR,see_stderr", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
