"""Durability tax + cold-recovery speed (ISSUE 7 acceptance rows).

Two questions the WAL + persistent block store must answer with
numbers:

1. What does durability cost the write path?  The same pipelined
   ``write_async`` burst runs against an in-memory store
   (``durable=0``) and a WAL-backed persistent one (``durable=1``,
   every write blocking on its group-committed fsync).  The acceptance
   bar is ``ratio <= 2`` at bench-smoke sizes — group commit amortizing
   many writers' records into few fsyncs is what keeps it there.

2. How fast is cold recovery?  A store is built with snapshotting
   disabled so a >=1k-record tail accumulates, "killed" (WAL crashed so
   close-time compaction can't shrink the tail), and reopened cold —
   segment scans, tail replay, claim/pin reconciliation, refcount
   verification.  The bar is < 1 second for the 1k-record tail.

Both bars are asserted here (``ok=1`` in the derived column) so CI's
bench-smoke step fails loudly on regression.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import mbps, scaled
from repro.core import SAI, SAIConfig, make_store
from repro.core.castore import open_durable_store

N_FILES = scaled(32, 16)
FILE_KB = scaled(256, 128)
BLOCK_KB = scaled(64, 32)
REPEATS = 5                       # best-of: container noise rejection

REPLAY_WRITES = 180               # 6 WAL records each -> >=1k-record tail
REPLAY_FILE_B = 1100


def _cfg(**kw):
    kw.setdefault("block_size", BLOCK_KB << 10)
    return SAIConfig(ca="fixed", hasher="cpu", **kw)


def _burst(sai: SAI, datas, tag: str) -> float:
    t0 = time.perf_counter()
    futs = [sai.write_async(f"/{tag}/{i}", d) for i, d in enumerate(datas)]
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(7)
    burst = [rng.integers(0, 256, FILE_KB << 10, dtype=np.uint8).tobytes()
             for _ in range(N_FILES)]
    total = sum(len(d) for d in burst)

    # -- durability tax on the write path --------------------------------
    mgr0, _ = make_store(4, replication=2)
    sai0 = SAI(mgr0, _cfg())
    _burst(sai0, burst, tag="warm")
    t_mem = min(_burst(sai0, burst, tag=f"burst{r}")
                for r in range(REPEATS))
    sai0.close()
    rows.append((f"recovery/write_durable0/{N_FILES}x{FILE_KB}KB",
                 t_mem / N_FILES * 1e6,
                 f"{mbps(total, t_mem):.1f}MBps_durable=0"))

    data_dir = tempfile.mkdtemp(prefix="bench-recovery-")
    try:
        mgr1, _ = make_store(4, replication=2, data_dir=data_dir)
        sai1 = SAI(mgr1, _cfg())
        _burst(sai1, burst, tag="warm")
        t_dur = min(_burst(sai1, burst, tag=f"burst{r}")
                    for r in range(REPEATS))
        sai1.close()
        wal_stats = mgr1.wal.snapshot_stats()
        mgr1.close()
    finally:
        shutil.rmtree(data_dir, ignore_errors=True)
    # group-commit fsync distribution (observability plane): the WAL's
    # log-bucketed fsync histogram, as p50/p95/p99
    fsum = wal_stats["fsync_hist"]
    for p in (50, 95, 99):
        p_s = fsum[f"p{p}_s"]
        rows.append((f"recovery/fsync_p{p}", p_s * 1e6,
                     f"p{p}_ms={p_s * 1e3:.3f}_count={fsum['count']}"))
    ratio = t_dur / max(t_mem, 1e-9)
    ok = int(ratio <= 2.0)
    rows.append((f"recovery/write_durable1/{N_FILES}x{FILE_KB}KB",
                 t_dur / N_FILES * 1e6,
                 f"{mbps(total, t_dur):.1f}MBps_durable=1_"
                 f"ratio={ratio:.2f}_ok={ok}"))
    assert ok, f"durable write {ratio:.2f}x in-memory (bar: 2x)"

    # -- cold recovery of a >=1k-record WAL tail -------------------------
    data_dir = tempfile.mkdtemp(prefix="bench-recovery-cold-")
    try:
        mgr, _, _ = open_durable_store(data_dir, n_nodes=3, replication=2,
                                       snapshot_every=10 ** 9)
        sai = SAI(mgr, _cfg(durable_sync=False, block_size=1024))
        for i in range(REPLAY_WRITES):
            sai.write(f"/f{i}", rng.integers(
                0, 256, REPLAY_FILE_B, dtype=np.uint8).tobytes())
        mgr.wait_durable()
        n_records = mgr.wal.last_seq
        mgr.wal.crash()           # SIGKILL-style: no close-time snapshot
        mgr.close()

        t0 = time.perf_counter()
        mgr2, _, rep = open_durable_store(data_dir, n_nodes=3,
                                          replication=2)
        wall = time.perf_counter() - t0
        mgr2.close()
    finally:
        shutil.rmtree(data_dir, ignore_errors=True)
    ok = int(wall < 1.0 and rep.refcount_drift == 0
             and rep.replayed >= 1000)
    rows.append((f"recovery/cold_replay/{n_records}rec", wall * 1e6,
                 f"replayed={rep.replayed}_wall_ms={wall * 1e3:.1f}_"
                 f"drift={rep.refcount_drift}_ok={ok}"))
    assert ok, (f"cold recovery: {wall:.3f}s for {rep.replayed} records "
                f"(bar: <1s for >=1k), drift={rep.refcount_drift}")
    return rows
