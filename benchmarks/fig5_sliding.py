"""Figures 5: sliding-window hashing — CrystalTPU optimization ablation
across block sizes, vs the single-core CPU baseline (hashlib MD5 per
window, the paper's baseline), for a stream of jobs."""
from __future__ import annotations

import hashlib
import time

import numpy as np

from benchmarks.common import Row, mbps, project_v5e_throughput, synth_data
from repro.core import CrystalTPU

STREAM = 4          # jobs per stream (paper uses 10; trimmed for CPU host)
WINDOW, STRIDE = 48, 4


def _cpu_single_core(data: bytes) -> float:
    view = memoryview(data)
    n = (len(data) - WINDOW) // STRIDE + 1
    t0 = time.perf_counter()
    for i in range(0, n, 1):
        hashlib.md5(view[i * STRIDE:i * STRIDE + WINDOW]).digest()
    return time.perf_counter() - t0


def _stream(reuse: bool, overlap: bool, data: np.ndarray) -> float:
    c = CrystalTPU(buffer_reuse=reuse, overlap=overlap, n_slots=4)
    try:
        c.submit("sliding", data, {"window": WINDOW, "stride": STRIDE}
                 ).wait()                       # compile warmup
        t0 = time.perf_counter()
        jobs = c.map_stream("sliding", [data] * STREAM,
                            {"window": WINDOW, "stride": STRIDE})
        for j in jobs:
            j.wait()
        return (time.perf_counter() - t0) / STREAM
    finally:
        c.shutdown()


def run() -> list:
    rows: list = []
    for size in (64 << 10, 512 << 10):
        raw = synth_data(size)
        data = np.frombuffer(raw, np.uint8)
        t_cpu = _cpu_single_core(raw)
        rows.append((f"fig5/cpu_1core/{size>>10}KB", t_cpu * 1e6,
                     f"{mbps(size, t_cpu):.1f}MBps"))
        variants = [("no_opt", False, False), ("buffer_reuse", True, False),
                    ("overlap", False, True), ("reuse+overlap", True, True)]
        for name, r, o in variants:
            t = _stream(r, o, data)
            rows.append((f"fig5/{name}/{size>>10}KB", t * 1e6,
                         f"speedup_vs_cpu={t_cpu/t:.2f}x"))
        # stride s hashes 1/s of the offsets -> ops/byte divides by s
        proj = project_v5e_throughput("sliding_md5") * STRIDE
        rows.append((f"fig5/v5e_projected/{size>>10}KB",
                     size / proj * 1e6,
                     f"{proj/1e6:.0f}MBps_speedup={proj/ (size/t_cpu):.0f}x"))
    return rows
