"""Figures 7-10: integrated-system write throughput.

'different' workload (all files unique) and 'similar' workload (same file
written back-to-back), for fixed-block and content-based-chunking
configurations, across non-CA / CA-CPU / CA-TPU / CA-Infinite.  The
CA-Infinite oracle (paper §4.4) bounds what infinite hashing compute
could buy."""
from __future__ import annotations

import time

from benchmarks.common import mbps, synth_data
from repro.core import SAI, SAIConfig, make_store

N_FILES = 6
FILE_MB = 2


def _sai(ca, hasher):
    mgr, _ = make_store(4, replication=1)
    cfg = SAIConfig(ca=ca, hasher=hasher, block_size=256 << 10,
                    avg_chunk=256 << 10, min_chunk=64 << 10,
                    max_chunk=1 << 20, stride=4)
    return SAI(mgr, cfg)


def _write_stream(sai, files) -> float:
    t0 = time.perf_counter()
    hash_s = 0.0
    for i, f in enumerate(files):
        st = sai.write(f"/bench/{i}", f)
        if sai.cfg.hasher == "infinite":
            hash_s += st.stage_s.get("hash", 0) + st.stage_s.get("chunk", 0)
    return time.perf_counter() - t0 - hash_s


def run() -> list:
    rows: list = []
    size = FILE_MB << 20
    different = [synth_data(size, seed=i) for i in range(N_FILES)]
    similar = [synth_data(size, seed=99)] * N_FILES

    configs = [("nonCA", "none", "cpu"),
               ("fixed_CPU", "fixed", "cpu"),
               ("fixed_TPU", "fixed", "tpu"),
               ("fixed_Inf", "fixed", "infinite"),
               ("cdc_CPU", "cdc-gear", "cpu"),
               ("cdc_TPU", "cdc-gear", "tpu"),
               ("cdc_Inf", "cdc-gear", "infinite")]
    for wname, files in (("different", different), ("similar", similar)):
        for cname, ca, hasher in configs:
            if wname == "different" and cname == "cdc_CPU":
                pass  # keep: exposes the paper's CPU chunking bottleneck
            sai = _sai(ca, hasher)
            t = _write_stream(sai, files)
            thr = mbps(size * N_FILES, t)
            fig = {"different": "fig7_8", "similar": "fig9_10"}[wname]
            rows.append((f"{fig}/{wname}/{cname}",
                         t / N_FILES * 1e6, f"{thr:.1f}MBps"))
    return rows
