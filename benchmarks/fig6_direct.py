"""Figure 6: direct hashing (fixed-size blocks) — ablation + CPU baseline."""
from __future__ import annotations

import hashlib
import time

import numpy as np

from benchmarks.common import mbps, project_v5e_throughput, synth_data
from repro.core import CrystalTPU

STREAM = 4
SEG = 4096


def run() -> list:
    rows: list = []
    for size in (1 << 20, 4 << 20):
        raw = synth_data(size)
        data = np.frombuffer(raw, np.uint8)
        t0 = time.perf_counter()
        for i in range(0, size, SEG):
            hashlib.md5(raw[i:i + SEG]).digest()
        t_cpu = time.perf_counter() - t0
        rows.append((f"fig6/cpu_1core/{size>>20}MB", t_cpu * 1e6,
                     f"{mbps(size, t_cpu):.1f}MBps"))
        for name, r, o in [("no_opt", False, False),
                           ("reuse+overlap", True, True)]:
            c = CrystalTPU(buffer_reuse=r, overlap=o, n_slots=4)
            try:
                c.submit("direct", data, {"seg_bytes": SEG}).wait()
                t0 = time.perf_counter()
                jobs = c.map_stream("direct", [data] * STREAM,
                                    {"seg_bytes": SEG})
                for j in jobs:
                    j.wait()
                t = (time.perf_counter() - t0) / STREAM
            finally:
                c.shutdown()
            rows.append((f"fig6/{name}/{size>>20}MB", t * 1e6,
                         f"speedup_vs_cpu={t_cpu/t:.2f}x"))
        proj = project_v5e_throughput("direct_md5")
        rows.append((f"fig6/v5e_projected/{size>>20}MB", size / proj * 1e6,
                     f"{proj/1e6:.0f}MBps"))
    return rows
