"""§4.2 'Add a CPU or a GPU?': content-based chunking throughput when the
host is extended with a second CPU (multithreaded hashlib — this 1-core
container caps at 1 thread; the scaling factor is reported analytically)
vs an accelerator (projected v5e kernel throughput).  The paper's answer:
the accelerator wins 15x for sliding-window hashing; here the static
op-count projection reproduces the shape."""
from __future__ import annotations

import hashlib
import time

from benchmarks.common import (OPS_PER_BYTE, mbps, project_v5e_throughput,
                               synth_data)

SIZE = 256 << 10
WINDOW, STRIDE = 48, 4


def run() -> list:
    rows: list = []
    raw = synth_data(SIZE)
    view = memoryview(raw)
    n = (SIZE - WINDOW) // STRIDE + 1
    t0 = time.perf_counter()
    for i in range(n):
        hashlib.md5(view[i * STRIDE:i * STRIDE + WINDOW]).digest()
    t1 = time.perf_counter() - t0
    thr1 = mbps(SIZE, t1)
    rows.append(("sec4_2/cpu_1core_sliding", t1 * 1e6, f"{thr1:.1f}MBps"))
    # dual-socket 8-core scaling (paper's config): ~8x ideal
    rows.append(("sec4_2/cpu_dual_socket_est", t1 / 8 * 1e6,
                 f"{thr1*8:.1f}MBps_est_8threads"))
    proj = project_v5e_throughput("sliding_md5") * STRIDE
    rows.append(("sec4_2/v5e_sliding_projected", SIZE / proj * 1e6,
                 f"{proj/1e6:.0f}MBps_={proj/1e6/(thr1*8):.1f}x_dualCPU"))
    proj_g = project_v5e_throughput("gear")
    rows.append(("sec4_2/v5e_gear_projected", SIZE / proj_g * 1e6,
                 f"{proj_g/1e6:.0f}MBps_beyond_paper_cdc"))
    return rows
