"""Figures 12-17: impact on competing applications.

A compute-bound competitor (prime search) and an IO-bound competitor
(file write/read loop) run concurrently with the storage write stream;
we report the competitor slowdown vs an unloaded host and the storage
throughput under contention.  (Single-core container: contention is
maximal — the paper's 8-core client shows smaller slowdowns; trends, not
magnitudes, transfer.)"""
from __future__ import annotations

import os
import tempfile
import threading
import time

from benchmarks.common import mbps, synth_data
from repro.core import SAI, SAIConfig, make_store

FILE_MB = 1
N_FILES = 3


def _prime_work(stop, count):
    n = 0
    x = 10_000_019
    while not stop.is_set():
        is_p = all(x % d for d in range(3, 2000, 2))
        x += 2
        n += 1
    count.append(n)


def _io_work(stop, count):
    n = 0
    buf = synth_data(256 << 10, seed=5)
    with tempfile.NamedTemporaryFile(delete=True) as f:
        while not stop.is_set():
            f.seek(0)
            f.write(buf)
            f.flush()
            os.fsync(f.fileno())
            f.seek(0)
            f.read()
            n += 1
    count.append(n)


def _competitor_rate(worker, seconds=2.0) -> float:
    stop, count = threading.Event(), []
    t = threading.Thread(target=worker, args=(stop, count))
    t.start()
    time.sleep(seconds)
    stop.set()
    t.join()
    return count[0] / seconds


def run() -> list:
    rows: list = []
    files = [synth_data(FILE_MB << 20, seed=i) for i in range(N_FILES)]

    for comp_name, worker in (("compute", _prime_work), ("io", _io_work)):
        base_rate = _competitor_rate(worker)
        for cname, ca, hasher in (("nonCA", "none", "cpu"),
                                  ("CA_CPU", "fixed", "cpu"),
                                  ("CA_TPU", "fixed", "tpu")):
            mgr, _ = make_store(4)
            sai = SAI(mgr, SAIConfig(ca=ca, hasher=hasher,
                                     block_size=256 << 10))
            stop, count = threading.Event(), []
            t = threading.Thread(target=worker, args=(stop, count))
            t.start()
            t0 = time.perf_counter()
            for i, f in enumerate(files):
                sai.write(f"/c/{i}", f)
            dt = time.perf_counter() - t0
            elapsed = time.perf_counter() - t0
            stop.set()
            t.join()
            rate = count[0] / max(elapsed, dt, 1e-9)
            slowdown = 100 * (base_rate - rate) / base_rate
            rows.append(
                (f"fig12_17/{comp_name}/{cname}", dt / N_FILES * 1e6,
                 f"store={mbps(FILE_MB<<20, dt/N_FILES):.1f}MBps_"
                 f"competitor_slowdown={slowdown:.0f}%"))
    return rows
