"""Gateway saturation: aggregate throughput and per-tenant fairness as
the number of concurrent client sessions scales (the serving-front-end
version of the paper's competing-applications evaluation, §V).

Each client opens its own gateway session (distinct tenant, equal
weight) and pushes a burst of framed writes, then reads one file back
verified.  All tenants' hash traffic funnels through ONE shared engine,
so the run reports the cross-client coalescing signature —
``engine launches < client requests`` — alongside per-tenant throughput
rows (``gateway/tenant_*``; the CI smoke asserts these are emitted),
per-device engine-mesh rows (``gateway/engine_device*`` — jobs,
launches, bytes, EWMA launch latency per device), and a fairness row
(min/max tenant throughput ratio; 1.0 = perfectly fair).
Admission rejections ride along: a saturated run backpressures instead
of queueing without bound.

The socket-mode section (``gateway/socket_*`` rows; the CI smoke
asserts them too) repeats the burst over a real localhost TCP
``GatewayServer`` with tenant auth enforced — every client dials its
own connection and opens with an HMAC-signed token — and reports the
same signature: ``launches < jobs`` across connections proves the
coalescing survives the wire (ISSUE 5: the step from in-process demo
to servable system).
"""
from __future__ import annotations

import http.client
import json
import threading
import time

import numpy as np

from benchmarks.common import mbps, scaled
from repro.core import CrystalTPU, SAIConfig, make_store
from repro.obs import dump_slow_log
from repro.serve.auth import TokenAuthenticator
from repro.serve.storage_client import GatewayClient
from repro.serve.storage_service import GatewayConfig, StorageGateway
from repro.serve.transport import GatewayServer

CLIENT_COUNTS = scaled([2, 4, 8], [4])
FILES_PER_CLIENT = scaled(8, 3)
FILE_KB = scaled(512, 32)
BLOCK_KB = scaled(128, 8)
SOCKET_CLIENTS = scaled(4, 4)


def _client_burst(client: GatewayClient, datas, done, errors):
    # daemon-thread failures must surface as rows-missing diagnostics,
    # not vanish: collect and let the caller assert the list is empty
    try:
        t0 = time.perf_counter()
        for i, d in enumerate(datas):
            client.write_retrying(f"/{client.tenant}/{i}", d)
        got = client.read(f"/{client.tenant}/0")
        assert got == datas[0]
        done[client.tenant] = time.perf_counter() - t0
    except BaseException as e:
        errors.append(f"{client.tenant}: {e!r}")


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(13)
    slow_entries: list = []
    for n_clients in CLIENT_COUNTS:
        mgr, _ = make_store(4)
        engine = CrystalTPU(coalesce_window_s=0.02)
        gw = StorageGateway(mgr, engine=engine, config=GatewayConfig(
            sai=SAIConfig(ca="fixed", hasher="tpu",
                          block_size=BLOCK_KB << 10)))
        clients = [GatewayClient(gw, f"t{i}") for i in range(n_clients)]
        per_client = [
            [rng.integers(0, 256, FILE_KB << 10,
                          dtype=np.uint8).tobytes()
             for _ in range(FILES_PER_CLIENT)]
            for _ in range(n_clients)]
        done: dict = {}
        errors: list = []
        t0 = time.perf_counter()
        threads = [threading.Thread(target=_client_burst,
                                    args=(c, d, done, errors),
                                    daemon=True)
                   for c, d in zip(clients, per_client)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)
        elapsed = time.perf_counter() - t0
        stats = gw.snapshot_stats()
        eng_stats = engine.snapshot_stats()
        slow_entries.extend(gw.tracer.slow_entries())
        gw.close()
        engine.shutdown()
        assert not errors, errors

        client_bytes = FILES_PER_CLIENT * (FILE_KB << 10)
        rates = {}
        for name, t in sorted(done.items()):
            rates[name] = mbps(client_bytes, t)
            rows.append((
                f"gateway/tenant_{name}/{n_clients}c",
                t / FILES_PER_CLIENT * 1e6,
                f"{rates[name]:.1f}MBps_completed="
                f"{stats['tenants'][name]['completed']}_rejected="
                f"{stats['tenants'][name]['rejected']}"))
        total = client_bytes * n_clients
        rows.append((f"gateway/aggregate/{n_clients}c",
                     elapsed / max(n_clients * FILES_PER_CLIENT, 1) * 1e6,
                     f"{mbps(total, elapsed):.1f}MBps"))
        requests = n_clients * (FILES_PER_CLIENT + 1)   # writes + 1 read
        rows.append((f"gateway/engine/{n_clients}c",
                     float(stats["jobs"]),
                     f"launches={stats['launches']}_requests={requests}_"
                     f"rejections={stats['admission_rejections']}"))
        for i, ds in sorted(eng_stats["per_device"].items()):
            rows.append((
                f"gateway/engine_device{i}/{n_clients}c",
                ds["ewma_launch_s"] * 1e6,
                f"jobs={ds['jobs']}_launches={ds['launches']}_"
                f"bytes={ds['bytes']}_queue_depth={ds['queue_depth']}"))
        # request-latency distribution rows (observability plane): the
        # gateway's log-bucketed write histogram, as p50/p95/p99
        wsum = stats["obs"]["request"]["write"]
        for p in (50, 95, 99):
            p_s = wsum[f"p{p}_s"]
            rows.append((
                f"gateway/latency_p{p}/{n_clients}c", p_s * 1e6,
                f"p{p}_ms={p_s * 1e3:.3f}_count={wsum['count']}"))
        if rates:
            fair = min(rates.values()) / max(max(rates.values()), 1e-9)
            rows.append((f"gateway/fairness/{n_clients}c", fair * 1e6,
                         f"min_over_max={fair:.2f}"))
    rows.extend(_socket_mode(rng, SOCKET_CLIENTS, slow_entries))
    rows.extend(_health_mode(rng))
    # requests that crossed the gateway's slow threshold, as a span-tree
    # dump CI uploads when non-empty
    if dump_slow_log(slow_entries, "obs-slowlog.json"):
        rows.append(("gateway/slow_requests", float(len(slow_entries)),
                     f"dumped={len(slow_entries)}"))
    # the smoke CI contract: per-tenant + socket + percentile + health
    # rows MUST be present
    assert any(name.startswith("gateway/tenant_") for name, _, _ in rows)
    assert any(name.startswith("gateway/socket_") for name, _, _ in rows)
    assert any(name.startswith("gateway/latency_p99") for name, _, _ in rows)
    assert any(name.startswith("health/") for name, _, _ in rows)
    return rows


def _http_get(port: int, path: str):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=10)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def _health_mode(rng) -> list:
    """Continuous-health-plane section: the same write burst with the
    MetricsSampler + HealthEngine + HTTP scrape endpoint live.  Emits
    ``health/`` rows (status, sampler ring size, windowed write rate,
    scrape sizes) and dumps ``obs-health.json`` (final verdicts + the
    sampler ring tail) for the CI artifact."""
    rows: list = []
    mgr, _ = make_store(4)
    engine = CrystalTPU(coalesce_window_s=0.02)
    gw = StorageGateway(mgr, engine=engine, config=GatewayConfig(
        sai=SAIConfig(ca="fixed", hasher="tpu",
                      block_size=BLOCK_KB << 10),
        health=True, metrics_port=0, sample_interval_s=0.05,
        sample_window_s=2.0))
    client = GatewayClient(gw, "hmon")
    datas = [rng.integers(0, 256, FILE_KB << 10,
                          dtype=np.uint8).tobytes()
             for _ in range(FILES_PER_CLIENT)]
    t0 = time.perf_counter()
    for i, d in enumerate(datas):
        client.write_retrying(f"/hmon/{i}", d)
    elapsed = time.perf_counter() - t0
    # let the sampler take a couple of post-burst ticks so windowed
    # rates and verdicts cover the traffic
    time.sleep(0.2)
    report = client.health()
    ts = gw.snapshot_stats().get("timeseries", {})
    code_h, body_h = _http_get(gw.http.port, "/health")
    code_m, body_m = _http_get(gw.http.port, "/metrics")
    tail = gw.sampler.tail(32, prefixes=[
        "heartbeats/", "wal/heartbeats/", "engine/per_device/",
        "queue_depths/", "obs/request/", "frames", "dispatched"])
    gw.close()
    engine.shutdown()
    assert code_h == 200, (code_h, body_h)
    assert report["status"] in ("ok", "warn"), report
    assert b"# TYPE" in body_m and b"repro_" in body_m

    with open("obs-health.json", "w", encoding="utf-8") as fh:
        json.dump({"report": report, "ring_tail": tail}, fh,
                  indent=1, sort_keys=True)
        fh.write("\n")

    healthy = int(report["status"] == "ok")
    rows.append(("health/status",
                 elapsed / max(FILES_PER_CLIENT, 1) * 1e6,
                 f"ok={healthy}_verdicts={len(report['verdicts'])}_"
                 f"evals={report['evals']}"))
    rows.append(("health/sampler", float(report["samples"]),
                 f"samples={report['samples']}_"
                 f"writes_per_s={ts.get('writes_per_s', 0.0):.2f}"))
    rows.append(("health/scrape", float(len(body_m)),
                 f"metrics_bytes={len(body_m)}_health_bytes="
                 f"{len(body_h)}_http_code={code_h}"))
    return rows


def _socket_mode(rng, n_clients: int, slow_entries: list) -> list:
    """The same burst over localhost TCP with tenant auth: every client
    opens its own GatewayServer connection with a signed token, and the
    engine's ``launches < jobs`` across those connections is the
    cross-connection coalescing signature over a real wire."""
    rows: list = []
    secrets = {f"s{i}": f"secret-{i}".encode() for i in range(n_clients)}
    mgr, _ = make_store(4)
    engine = CrystalTPU(coalesce_window_s=0.02)
    gw = StorageGateway(mgr, engine=engine, config=GatewayConfig(
        sai=SAIConfig(ca="fixed", hasher="tpu",
                      block_size=BLOCK_KB << 10),
        auth=TokenAuthenticator(secrets)))
    server = GatewayServer(gw)
    clients = [GatewayClient(server, f"s{i}", secret=secrets[f"s{i}"])
               for i in range(n_clients)]
    per_client = [
        [rng.integers(0, 256, FILE_KB << 10, dtype=np.uint8).tobytes()
         for _ in range(FILES_PER_CLIENT)]
        for _ in range(n_clients)]
    done: dict = {}
    errors: list = []
    t0 = time.perf_counter()
    threads = [threading.Thread(target=_client_burst,
                                args=(c, d, done, errors), daemon=True)
               for c, d in zip(clients, per_client)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600)
    elapsed = time.perf_counter() - t0
    for c in clients:
        c.close()
    stats = gw.snapshot_stats()
    conn = server.snapshot_stats()
    slow_entries.extend(gw.tracer.slow_entries())
    server.close()
    gw.close()
    engine.shutdown()
    assert not errors, errors

    client_bytes = FILES_PER_CLIENT * (FILE_KB << 10)
    for name, t in sorted(done.items()):
        rows.append((
            f"gateway/socket_tenant_{name}/{n_clients}c",
            t / FILES_PER_CLIENT * 1e6,
            f"{mbps(client_bytes, t):.1f}MBps_completed="
            f"{stats['tenants'][name]['completed']}"))
    total = client_bytes * n_clients
    rows.append((f"gateway/socket_aggregate/{n_clients}c",
                 elapsed / max(n_clients * FILES_PER_CLIENT, 1) * 1e6,
                 f"{mbps(total, elapsed):.1f}MBps_connections="
                 f"{conn['connections']}"))
    rows.append((f"gateway/socket_engine/{n_clients}c",
                 float(stats["jobs"]),
                 f"launches={stats['launches']}_jobs={stats['jobs']}_"
                 f"coalesced={int(stats['launches'] < stats['jobs'])}"))
    return rows
