"""Gateway saturation: aggregate throughput and per-tenant fairness as
the number of concurrent client sessions scales (the serving-front-end
version of the paper's competing-applications evaluation, §V).

Each client opens its own gateway session (distinct tenant, equal
weight) and pushes a burst of framed writes, then reads one file back
verified.  All tenants' hash traffic funnels through ONE shared engine,
so the run reports the cross-client coalescing signature —
``engine launches < client requests`` — alongside per-tenant throughput
rows (``gateway/tenant_*``; the CI smoke asserts these are emitted) and
a fairness row (min/max tenant throughput ratio; 1.0 = perfectly fair).
Admission rejections ride along: a saturated run backpressures instead
of queueing without bound.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import mbps, scaled
from repro.core import CrystalTPU, SAIConfig, make_store
from repro.serve.storage_client import GatewayClient
from repro.serve.storage_service import GatewayConfig, StorageGateway

CLIENT_COUNTS = scaled([2, 4, 8], [4])
FILES_PER_CLIENT = scaled(8, 3)
FILE_KB = scaled(512, 32)
BLOCK_KB = scaled(128, 8)


def _client_burst(client: GatewayClient, datas, done):
    t0 = time.perf_counter()
    for i, d in enumerate(datas):
        client.write_retrying(f"/{client.tenant}/{i}", d)
    got = client.read(f"/{client.tenant}/0")
    assert got == datas[0]
    done[client.tenant] = time.perf_counter() - t0


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(13)
    for n_clients in CLIENT_COUNTS:
        mgr, _ = make_store(4)
        engine = CrystalTPU(coalesce_window_s=0.02)
        gw = StorageGateway(mgr, engine=engine, config=GatewayConfig(
            sai=SAIConfig(ca="fixed", hasher="tpu",
                          block_size=BLOCK_KB << 10)))
        clients = [GatewayClient(gw, f"t{i}") for i in range(n_clients)]
        per_client = [
            [rng.integers(0, 256, FILE_KB << 10,
                          dtype=np.uint8).tobytes()
             for _ in range(FILES_PER_CLIENT)]
            for _ in range(n_clients)]
        done: dict = {}
        t0 = time.perf_counter()
        threads = [threading.Thread(target=_client_burst,
                                    args=(c, d, done), daemon=True)
                   for c, d in zip(clients, per_client)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)
        elapsed = time.perf_counter() - t0
        stats = gw.snapshot_stats()
        gw.close()
        engine.shutdown()

        client_bytes = FILES_PER_CLIENT * (FILE_KB << 10)
        rates = {}
        for name, t in sorted(done.items()):
            rates[name] = mbps(client_bytes, t)
            rows.append((
                f"gateway/tenant_{name}/{n_clients}c",
                t / FILES_PER_CLIENT * 1e6,
                f"{rates[name]:.1f}MBps_completed="
                f"{stats['tenants'][name]['completed']}_rejected="
                f"{stats['tenants'][name]['rejected']}"))
        total = client_bytes * n_clients
        rows.append((f"gateway/aggregate/{n_clients}c",
                     elapsed / max(n_clients * FILES_PER_CLIENT, 1) * 1e6,
                     f"{mbps(total, elapsed):.1f}MBps"))
        requests = n_clients * (FILES_PER_CLIENT + 1)   # writes + 1 read
        rows.append((f"gateway/engine/{n_clients}c",
                     float(stats["jobs"]),
                     f"launches={stats['launches']}_requests={requests}_"
                     f"rejections={stats['admission_rejections']}"))
        if rates:
            fair = min(rates.values()) / max(max(rates.values()), 1e-9)
            rows.append((f"gateway/fairness/{n_clients}c", fair * 1e6,
                         f"min_over_max={fair:.2f}"))
    # the smoke CI contract: per-tenant throughput rows MUST be present
    assert any(name.startswith("gateway/tenant_") for name, _, _ in rows)
    return rows
