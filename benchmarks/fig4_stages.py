"""Figure 4: per-stage time breakdown of sliding-window hashing WITHOUT
CrystalTPU optimizations (alloc/copy-in dominates the paper's GPU runs at
80-96%; we measure the same staged pipeline on this host)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, synth_data
from repro.core import CrystalTPU


def run() -> list:
    rows: list = []
    for size in (256 << 10, 1 << 20):
        c = CrystalTPU(buffer_reuse=False, overlap=False, n_slots=2)
        try:
            data = np.frombuffer(synth_data(size), np.uint8)
            # warmup (compile)
            c.submit("sliding", data, {"window": 48, "stride": 4}).wait()
            job = c.submit("sliding", data, {"window": 48, "stride": 4})
            job.wait()
            t = job.timings
            total = sum(t.values())
            for stage in ("in", "kernel", "out"):
                pct = 100 * t[stage] / total
                rows.append((f"fig4/stage_{stage}/{size>>10}KB",
                             t[stage] * 1e6, f"{pct:.1f}%_of_total"))
        finally:
            c.shutdown()
    return rows
