"""Figure 4: per-stage time breakdown of sliding-window hashing WITHOUT
CrystalTPU optimizations (alloc/copy-in dominates the paper's GPU runs at
80-96%; we measure the same staged pipeline on this host), plus the
engine's request-coalescing ablations: a burst of small direct-hash
requests — and a burst of same-config sliding stream jobs (CDC chunking
burst) — dispatched per-request vs fused into batched launches."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, scaled, synth_data
from repro.core import CrystalTPU

BURST = scaled(16, 8)
BURST_SEG = scaled(16 << 10, 4 << 10)
STREAM_BURST = scaled(8, 4)
STREAM_LEN = scaled(64 << 10, 8 << 10)


def run() -> list:
    rows: list = []
    for size in scaled((256 << 10, 1 << 20), (64 << 10,)):
        c = CrystalTPU(buffer_reuse=False, overlap=False, n_slots=2)
        try:
            data = np.frombuffer(synth_data(size), np.uint8)
            # warmup (compile)
            c.submit("sliding", data, {"window": 48, "stride": 4}).wait()
            job = c.submit("sliding", data, {"window": 48, "stride": 4})
            job.wait()
            t = job.timings
            total = sum(t.values())
            for stage in ("in", "kernel", "out"):
                pct = 100 * t[stage] / total
                rows.append((f"fig4/stage_{stage}/{size>>10}KB",
                             t[stage] * 1e6, f"{pct:.1f}%_of_total"))
        finally:
            c.shutdown()

    # coalescing ablation: same burst of BURST small direct requests,
    # per-request launches vs fused batch launches
    bufs = [np.frombuffer(synth_data(BURST_SEG, seed=i), np.uint8)
            for i in range(BURST)]
    for coalesce in (False, True):
        c = CrystalTPU(coalesce=coalesce, coalesce_window_s=0.02)
        try:
            # warm both the per-request and the fused batch shapes
            for j in c.map_stream("direct", bufs, {"seg_bytes": 4096}):
                j.wait()
            s0 = c.snapshot_stats()
            t0 = time.perf_counter()
            jobs = c.map_stream("direct", bufs, {"seg_bytes": 4096})
            for j in jobs:
                j.wait()
            t = time.perf_counter() - t0
            s1 = c.snapshot_stats()
            launches = s1["launches"] - s0["launches"]
            njobs = s1["jobs"] - s0["jobs"]
            label = "fused" if coalesce else "per_request"
            rows.append((f"fig4/coalesce_{label}", t / BURST * 1e6,
                         f"launches={launches}_jobs={njobs}"))
        finally:
            c.shutdown()

    # stream-coalescing ablation: a CDC chunking burst of same-config
    # sliding jobs, per-request launches vs one fused [B, L] launch
    sbufs = [np.frombuffer(synth_data(STREAM_LEN, seed=100 + i), np.uint8)
             for i in range(STREAM_BURST)]
    meta = {"window": 48, "stride": 4}
    for coalesce in (False, True):
        c = CrystalTPU(coalesce=coalesce, coalesce_window_s=0.02)
        try:
            for j in c.map_stream("sliding", sbufs, meta):    # warm shapes
                j.wait()
            s0 = c.snapshot_stats()
            t0 = time.perf_counter()
            jobs = c.map_stream("sliding", sbufs, meta)
            for j in jobs:
                j.wait()
            t = time.perf_counter() - t0
            s1 = c.snapshot_stats()
            launches = s1["launches"] - s0["launches"]
            njobs = s1["jobs"] - s0["jobs"]
            label = "fused" if coalesce else "per_request"
            rows.append((f"fig4/stream_coalesce_{label}",
                         t / STREAM_BURST * 1e6,
                         f"launches={launches}_jobs={njobs}"))
        finally:
            c.shutdown()
    return rows
