"""Scrub-vs-foreground interference (the paper's Figures 12-17 question
asked of the node runtime itself): how much does continuous background
integrity scrubbing slow the foreground write path when both share one
offload engine?

Two runs over the same store shape: a pipelined ``write_async`` burst
with no runtime (baseline), then the same burst while a
:class:`ClusterRuntime` continuously scrubs a pre-populated resident
data set.  Scrub hashing rides the engine's low-priority ``scrub`` lane
and paces its bursts, so the foreground latency ratio should stay small
(the acceptance bar is < 2x).  The ``scrub_*`` rows expose the engine's
scrub-lane coalescing counters — ``scrub_launches < scrub_jobs`` is the
fused-background-burst signature — and the runtime's sweep counters.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import mbps, scaled
from repro.core import (ClusterRuntime, CrystalTPU, NodeRuntimeConfig,
                        SAI, SAIConfig, make_store)

N_FILES = scaled(6, 3)            # foreground write burst
FILE_KB = scaled(1024, 32)
BLOCK_KB = scaled(128, 8)
RESIDENT_FILES = scaled(8, 4)     # pre-populated blocks the scrubber sweeps
RESIDENT_KB = scaled(512, 32)


def _timed_burst(sai: SAI, datas, tag: str) -> float:
    t0 = time.perf_counter()
    futs = [sai.write_async(f"/{tag}/{i}", d)
            for i, d in enumerate(datas)]
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(11)
    resident = [rng.integers(0, 256, RESIDENT_KB << 10,
                             dtype=np.uint8).tobytes()
                for _ in range(RESIDENT_FILES)]
    warmup = [rng.integers(0, 256, FILE_KB << 10, dtype=np.uint8).tobytes()
              for _ in range(N_FILES)]
    burst = [rng.integers(0, 256, FILE_KB << 10, dtype=np.uint8).tobytes()
             for _ in range(N_FILES)]
    total = sum(len(d) for d in burst)
    times = {}

    for mode in ("baseline", "with_scrub", "durable"):
        # "durable" reruns the baseline burst against a WAL-backed
        # persistent store (ISSUE 7): same engine/write path, plus
        # group-committed metadata fsyncs and block-segment flushes
        data_dir = tempfile.mkdtemp(prefix="bench-scrub-durable-") \
            if mode == "durable" else None
        if data_dir is not None:
            mgr, _ = make_store(4, replication=2, data_dir=data_dir)
        else:
            mgr, _ = make_store(4, replication=2)
        engine = CrystalTPU(coalesce_window_s=0.02)
        sai = SAI(mgr, SAIConfig(ca="fixed", hasher="tpu",
                                 block_size=BLOCK_KB << 10),
                  crystal=engine)
        for i, d in enumerate(resident):
            sai.write(f"/resident/{i}", d)
        runtime = None
        if mode == "with_scrub":
            # rate-limited scrubbing (the point of the run): small
            # bursts with pacing, so an in-flight scrub launch never
            # holds the single engine device long enough to stall a
            # queued foreground job past the 2x acceptance bar
            runtime = ClusterRuntime(
                mgr, engine=engine,
                config=NodeRuntimeConfig(scrub_batch_blocks=4,
                                         scrub_interval_s=0.05,
                                         scrub_cycle_idle_s=0.25))
            runtime.start()
            time.sleep(0.2)                   # scrubbing underway
        # untimed warmup burst: compiles the fused batch shapes —
        # including the mixed scrub+foreground batches that only exist
        # while the runtime scrubs — so the timed region measures
        # steady-state interference, not one-time jit retraces
        _timed_burst(sai, warmup, tag="warmup")
        t = _timed_burst(sai, burst, tag="burst")
        times[mode] = t
        durable = int(mode == "durable")
        derived = f"{mbps(total, t):.1f}MBps_durable={durable}"
        if mode != "baseline":
            ratio = t / max(times["baseline"], 1e-9)
            derived += f"_slowdown={ratio:.2f}x"
        if runtime is not None:
            runtime.stop()
            s = runtime.snapshot_stats()
            rows.append((f"scrub/engine/scrub_jobs/{RESIDENT_FILES}res",
                         float(s["scrub_jobs"]),
                         f"scrub_launches={s['scrub_launches']}_"
                         f"scrub_coalesced={s['scrub_coalesced']}"))
            rows.append(("scrub/runtime/scrubbed_blocks",
                         float(s["scrubbed_blocks"]),
                         f"corrupt_found={s['corrupt_found']}_"
                         f"repaired={s['repaired_copies']}_"
                         f"backoffs={s['scrub_backoffs']}"))
        rows.append((f"scrub/foreground_write_{mode}/"
                     f"{N_FILES}x{FILE_KB}KB",
                     t / N_FILES * 1e6, derived))
        sai.close()
        if data_dir is not None:
            mgr.close()
            shutil.rmtree(data_dir, ignore_errors=True)
        engine.shutdown()
    return rows
