"""Read-path verification: the paper's "traditional system that uses
hashing to preserve data integrity", served three ways —

  * per-block host hashing (hasher='cpu': the CPU baseline),
  * one fused engine hash request per read (hasher='tpu', sync ``read``),
  * the pipelined ``read_async`` burst, where verify of read i overlaps
    fetch of read i+1 and the per-read verify requests coalesce into
    batched kernel launches.

The derived column reports read throughput plus the engine's fused
launch count vs submitted verify requests for the accelerated rows.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mbps, scaled
from repro.core import CrystalTPU, SAI, SAIConfig, make_store

N_FILES = scaled(8, 4)
FILE_KB = scaled(512, 32)
BLOCK_KB = scaled(64, 8)


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(7)
    datas = [rng.integers(0, 256, FILE_KB << 10, dtype=np.uint8).tobytes()
             for _ in range(N_FILES)]
    total = sum(len(d) for d in datas)

    for mode in ("cpu", "tpu_sync", "tpu_async"):
        hasher = "cpu" if mode == "cpu" else "tpu"
        mgr, _ = make_store(4)
        engine = CrystalTPU(coalesce_window_s=0.02) if hasher == "tpu" \
            else None
        sai = SAI(mgr, SAIConfig(ca="fixed", hasher=hasher,
                                 block_size=BLOCK_KB << 10),
                  crystal=engine)
        for i, d in enumerate(datas):
            sai.write(f"/read/f{i}", d)
        # warm the verify-batch shapes, then measure a clean burst
        sai.read("/read/f0")
        s0 = engine.snapshot_stats() if engine else None
        t0 = time.perf_counter()
        if mode == "tpu_async":
            futs = [sai.read_async(f"/read/f{i}")
                    for i in range(N_FILES)]
            got = [f.result() for f in futs]
        else:
            got = [sai.read(f"/read/f{i}") for i in range(N_FILES)]
        t = time.perf_counter() - t0
        assert got == datas
        derived = f"{mbps(total, t):.1f}MBps"
        if engine is not None:
            s1 = engine.snapshot_stats()
            derived += (f"_launches={s1['launches'] - s0['launches']}"
                        f"/jobs={s1['jobs'] - s0['jobs']}")
        sai.close()
        if engine is not None:
            engine.shutdown()
        rows.append((f"read/verified_{mode}/{N_FILES}x{FILE_KB}KB",
                     t / N_FILES * 1e6, derived))
    return rows
