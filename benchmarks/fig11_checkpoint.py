"""Figure 11: the checkpoint workload — successive checkpoint images
written back-to-back while varying the block size; reports write
throughput and detected similarity for fixed vs content-based chunking.
(The paper: fixed detects 21-23%, CDC detects 76-90% on BLCR images.)

The tpu rows run through a shared CrystalTPU offload engine and the
async write pipeline, so the derived column also reports the engine's
fused launch count vs submitted hash requests (coalescing at work)."""
from __future__ import annotations

import time

from benchmarks.common import checkpoint_series, mbps, scaled
from repro.core import CrystalTPU, SAI, SAIConfig, make_store

N_IMAGES = scaled(4, 3)
IMAGE_MB = scaled(2, 0.25)


def run() -> list:
    rows: list = []
    images = checkpoint_series(N_IMAGES, int(IMAGE_MB * (1 << 20)),
                               change_frac=0.15)
    size_total = sum(len(i) for i in images)
    for block in scaled((16 << 10, 64 << 10), (16 << 10,)):
        for ca in ("fixed", "cdc-gear"):
            for hasher in ("cpu", "tpu"):
                mgr, _ = make_store(4)
                cfg = SAIConfig(ca=ca, hasher=hasher, block_size=block,
                                avg_chunk=block, min_chunk=block // 4,
                                max_chunk=block * 4, stride=4)
                engine = CrystalTPU() if hasher == "tpu" else None
                sai = SAI(mgr, cfg, crystal=engine)
                t0 = time.perf_counter()
                sims = []
                stage_s = {}
                futs = [sai.write_async("/ckpt/image", img)
                        for img in images]
                for i, fut in enumerate(futs):
                    st = fut.result()
                    if i:
                        sims.append(st.similarity)
                    for stage, sec in st.stage_s.items():
                        stage_s[stage] = stage_s.get(stage, 0.0) + sec
                t = time.perf_counter() - t0
                sai.close()
                sim = 100 * sum(sims) / len(sims)
                label = "fixed" if ca == "fixed" else "CB"
                name = f"fig11/{label}_{hasher}/{block>>10}KB"
                derived = f"{mbps(size_total, t):.1f}MBps_sim={sim:.0f}%"
                if engine is not None:
                    s = engine.snapshot_stats()
                    derived += (f"_launches={s['launches']}"
                                f"/jobs={s['jobs']}")
                    # engine launch/coalesce counters as their own CSV
                    # rows so fused-launch regressions show up in the
                    # perf trajectory directly
                    for key in ("launches", "jobs", "coalesced",
                                "max_fused"):
                        rows.append((f"{name}/engine_{key}", 0.0,
                                     str(s[key])))
                    engine.shutdown()
                rows.append((name, t / N_IMAGES * 1e6, derived))
                # per-stage pipeline time (WriteStats.stage_s, summed
                # over the image burst)
                for stage, sec in sorted(stage_s.items()):
                    rows.append((f"{name}/stage_{stage}",
                                 sec / N_IMAGES * 1e6,
                                 f"{100 * sec / max(t, 1e-12):.1f}%_of_wall"))
    return rows
