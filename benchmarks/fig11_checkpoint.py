"""Figure 11: the checkpoint workload — successive checkpoint images
written back-to-back while varying the block size; reports write
throughput and detected similarity for fixed vs content-based chunking.
(The paper: fixed detects 21-23%, CDC detects 76-90% on BLCR images.)

The tpu rows run through a shared CrystalTPU offload engine and the
async write pipeline, so the derived column also reports the engine's
fused launch count vs submitted hash requests (coalescing at work)."""
from __future__ import annotations

import time

from benchmarks.common import checkpoint_series, mbps
from repro.core import CrystalTPU, SAI, SAIConfig, make_store

N_IMAGES = 4
IMAGE_MB = 2


def run() -> list:
    rows: list = []
    images = checkpoint_series(N_IMAGES, IMAGE_MB << 20, change_frac=0.15)
    size_total = sum(len(i) for i in images)
    for block in (16 << 10, 64 << 10):
        for ca in ("fixed", "cdc-gear"):
            for hasher in ("cpu", "tpu"):
                mgr, _ = make_store(4)
                cfg = SAIConfig(ca=ca, hasher=hasher, block_size=block,
                                avg_chunk=block, min_chunk=block // 4,
                                max_chunk=block * 4, stride=4)
                engine = CrystalTPU() if hasher == "tpu" else None
                sai = SAI(mgr, cfg, crystal=engine)
                t0 = time.perf_counter()
                sims = []
                futs = [sai.write_async("/ckpt/image", img)
                        for img in images]
                for i, fut in enumerate(futs):
                    st = fut.result()
                    if i:
                        sims.append(st.similarity)
                t = time.perf_counter() - t0
                sai.close()
                sim = 100 * sum(sims) / len(sims)
                label = "fixed" if ca == "fixed" else "CB"
                derived = f"{mbps(size_total, t):.1f}MBps_sim={sim:.0f}%"
                if engine is not None:
                    s = engine.snapshot_stats()
                    derived += (f"_launches={s['launches']}"
                                f"/jobs={s['jobs']}")
                    engine.shutdown()
                rows.append((f"fig11/{label}_{hasher}/{block>>10}KB",
                             t / N_IMAGES * 1e6, derived))
    return rows
