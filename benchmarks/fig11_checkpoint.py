"""Figure 11: the checkpoint workload — successive checkpoint images
written back-to-back while varying the block size; reports write
throughput and detected similarity for fixed vs content-based chunking.
(The paper: fixed detects 21-23%, CDC detects 76-90% on BLCR images.)"""
from __future__ import annotations

import time

from benchmarks.common import checkpoint_series, mbps
from repro.core import SAI, SAIConfig, make_store

N_IMAGES = 4
IMAGE_MB = 2


def run() -> list:
    rows: list = []
    images = checkpoint_series(N_IMAGES, IMAGE_MB << 20, change_frac=0.15)
    size_total = sum(len(i) for i in images)
    for block in (16 << 10, 64 << 10):
        for ca in ("fixed", "cdc-gear"):
            for hasher in ("cpu", "tpu"):
                mgr, _ = make_store(4)
                cfg = SAIConfig(ca=ca, hasher=hasher, block_size=block,
                                avg_chunk=block, min_chunk=block // 4,
                                max_chunk=block * 4, stride=4)
                sai = SAI(mgr, cfg)
                t0 = time.perf_counter()
                sims = []
                for i, img in enumerate(images):
                    st = sai.write("/ckpt/image", img)
                    if i:
                        sims.append(st.similarity)
                t = time.perf_counter() - t0
                sim = 100 * sum(sims) / len(sims)
                label = "fixed" if ca == "fixed" else "CB"
                rows.append(
                    (f"fig11/{label}_{hasher}/{block>>10}KB",
                     t / N_IMAGES * 1e6,
                     f"{mbps(size_total, t):.1f}MBps_sim={sim:.0f}%"))
    return rows
