"""Architecture configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``.  The model
stack (``repro.models``) consumes only this dataclass, so adding an
architecture is a single new file in ``repro/configs``.

Shape handling: each architecture carries the four assigned input shapes
(train_4k / prefill_32k / decode_32k / long_500k).  ``decode_*`` and
``long_*`` lower ``serve_step`` (one new token against a KV cache of
``seq_len``), not ``train_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # which layers are MoE: 'all', 'every_2' (odd layers dense), ...
    layer_pattern: str = "all"
    # sharding mode for the stacked expert tensor: 'expert' shards the E dim
    # on the model axis, 'ffn' shards the expert-ffn dim (for E < mesh model).
    shard_mode: str = "expert"
    num_shared_experts: int = 0
    # GShard-style per-group expert capacity factor.  Tokens overflowing an
    # expert's capacity are dropped (residual passes through) — a known
    # train/serve asymmetry of capacity-based TPU MoE (decode never drops).
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD / state-space duality) block configuration."""
    state_dim: int = 128
    head_dim: int = 64           # P in the SSD paper
    conv_width: int = 4
    expand: int = 2              # inner dim = expand * d_model
    chunk_size: int = 256        # SSD chunked-scan block length
    ngroups: int = 1             # B/C groups (GVA in mamba2)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'


# The four assigned LM shapes (identical across the 10 archs).
TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attention-free archs
    kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # attention
    rope_theta: float = 10000.0
    swa_window: int = 0           # 0 = full attention; >0 = sliding-window
    attn_logit_softcap: float = 0.0
    # mlp
    mlp_type: str = "swiglu"      # swiglu | gelu
    # norm / embedding
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    residual_scale: float = 1.0   # MiniCPM-style depth scaling
    embed_scale: float = 1.0      # MiniCPM scale_emb
    logit_scale: float = 1.0      # MiniCPM: d_model / dim_model_base divisor
    # mixture-of-experts / state-space / hybrid
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid interleave: period and which index inside the period is attention
    # (Jamba: 1 attention per 8 layers).
    hybrid_period: int = 0        # 0 = not hybrid
    hybrid_attn_index: int = 0
    # modality frontend stub: number of prepended precomputed embeddings
    # (vlm: patch embeddings; audio: frame embeddings).  The frontend itself
    # (ViT / EnCodec) is a STUB per the assignment; input_specs() provides the
    # precomputed embeddings.
    frontend_embeds: int = 0
    # training numerics
    param_dtype: str = "float32"  # master/param dtype for training
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"      # adamw | adafactor (memory-lean for huge archs)
    lr_schedule: str = "cosine"   # cosine | wsd (MiniCPM warmup-stable-decay)
    # long_500k eligibility: sub-quadratic attention path exists
    # (SSM / hybrid / SWA archs). Pure full-attention archs skip long_500k.
    supports_long_context: bool = False
    source: str = ""              # [arXiv/hf; verification tier]

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings included once if tied)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # lm head
        for i in range(L):
            total += d                                    # pre-mixer norm
            if self._layer_is_attn(i):
                q = d * self.num_heads * hd
                kv = 2 * d * self.kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
            else:
                total += self._ssm_params()
            total += d                                    # pre-ffn norm
            total += self._ffn_params(i)
        total += d                                        # final norm
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE counts top_k experts only)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for i in range(L):
            total += 2 * d
            if self._layer_is_attn(i):
                total += d * self.num_heads * hd + 2 * d * self.kv_heads * hd \
                    + self.num_heads * hd * d
            else:
                total += self._ssm_params()
            total += self._ffn_params(i, active=True)
        total += d
        return total

    # -- helpers -----------------------------------------------------------
    def _layer_is_attn(self, i: int) -> bool:
        if self.ssm is None:
            return True
        if self.hybrid_period:                            # hybrid (Jamba)
            return (i % self.hybrid_period) == self.hybrid_attn_index
        return False                                      # pure SSM (Mamba2)

    def _layer_is_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.layer_pattern == "all":
            return True
        if self.moe.layer_pattern == "every_2":
            return (i % 2) == 1
        raise ValueError(self.moe.layer_pattern)

    def _ffn_params(self, i: int, active: bool = False) -> int:
        d = self.d_model
        if self._layer_is_moe(i):
            m = self.moe
            n_mats = 3 if self.mlp_type == "swiglu" else 2
            per_expert = n_mats * d * m.d_ff_expert
            router = d * m.num_experts
            n_e = (m.top_k if active else m.num_experts) + m.num_shared_experts
            return router + n_e * per_expert
        if self.d_ff == 0:
            return 0                                      # attention/ssm-only
        n_mats = 3 if self.mlp_type == "swiglu" else 2
        return n_mats * d * self.d_ff

    def _ssm_params(self) -> int:
        s = self.ssm
        d = self.d_model
        d_in = s.expand * d
        nheads = d_in // s.head_dim
        # in_proj: z, x, B, C, dt   (mamba2 fused projection)
        in_proj = d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
        conv = s.conv_width * (d_in + 2 * s.ngroups * s.state_dim)
        out_proj = d_in * d
        extra = 2 * nheads + d_in                         # A_log, D, gate norm
        return in_proj + conv + out_proj + extra

    def shapes(self) -> Tuple[ShapeSpec, ...]:
        out = []
        for s in ALL_SHAPES:
            if s.name == "long_500k" and not self.supports_long_context:
                continue
            out.append(s)
        return tuple(out)

    def skipped_shapes(self) -> Tuple[str, ...]:
        if self.supports_long_context:
            return ()
        return ("long_500k",)
