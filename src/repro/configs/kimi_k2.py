"""kimi-k2-1t-a32b — trillion-parameter MoE (384 experts, top-8).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per-expert) vocab=163840, MoE 384e top-8 + 1 shared expert.  Uses
Adafactor + bf16 params: AdamW fp32 state for 1.04T params would need
~12.5 TB (> the 8 TB HBM of a 512-chip v5e slice); factored state keeps
the dry-run within footprint.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    rope_theta=50000.0,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  layer_pattern="all", shard_mode="expert",
                  num_shared_experts=1),
    param_dtype="bfloat16",
    optimizer="adafactor",
    source="[arXiv:2501.kimi2; unverified]",
)
