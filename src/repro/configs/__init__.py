"""Architecture registry.

``get_config(name)`` returns the full published configuration;
``get_smoke_config(name)`` returns a reduced same-family config for CPU
smoke tests (small layers/width, few experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                PREFILL_32K, TRAIN_4K, ArchConfig, MoEConfig,
                                SSMConfig, ShapeSpec)

from repro.configs.mamba2_1p3b import CONFIG as _mamba2
from repro.configs.minicpm_2b import CONFIG as _minicpm
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.glm4_9b import CONFIG as _glm4
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.internvl2_2b import CONFIG as _internvl2
from repro.configs.jamba_1p5_large import CONFIG as _jamba
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.kimi_k2 import CONFIG as _kimi

REGISTRY: Dict[str, ArchConfig] = {
    c.name: c
    for c in (_mamba2, _minicpm, _starcoder2, _glm4, _llama3, _internvl2,
              _jamba, _musicgen, _mixtral, _kimi)
}

ARCH_NAMES = tuple(REGISTRY.keys())


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config: runs a forward/train step on CPU."""
    full = get_config(name)
    moe = full.moe
    if moe is not None:
        moe = dataclasses.replace(moe, num_experts=min(4, moe.num_experts),
                                  top_k=min(2, moe.top_k), d_ff_expert=64)
    ssm = full.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, state_dim=16, head_dim=16,
                                  chunk_size=32)
    period = full.hybrid_period
    n_layers = max(4, period) if period else 4
    return dataclasses.replace(
        full,
        num_layers=n_layers,
        d_model=64,
        num_heads=4 if full.num_heads else 0,
        kv_heads=min(max(full.kv_heads, 0), 2) if full.num_heads else 0,
        head_dim=16 if full.num_heads else 0,
        d_ff=96 if full.d_ff else 0,
        vocab_size=128,
        frontend_embeds=min(full.frontend_embeds, 8),
        moe=moe,
        ssm=ssm,
        hybrid_attn_index=min(full.hybrid_attn_index, n_layers - 1),
        residual_scale=full.residual_scale if full.residual_scale != 1.0
        else 1.0,
        param_dtype="float32",
        compute_dtype="float32",
    )


def get_shape(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


__all__ = [
    "REGISTRY", "ARCH_NAMES", "get_config", "get_smoke_config", "get_shape",
    "ArchConfig", "MoEConfig", "SSMConfig", "ShapeSpec",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K", "ALL_SHAPES",
]
