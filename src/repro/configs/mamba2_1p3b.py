"""mamba2-1.3b — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  48L d_model=2048 d_ff=0 vocab=50280,
ssm_state=128.  Pure Mamba-2 blocks: no attention, no separate FFN
(d_ff=0); each layer is a single SSD mixer.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, conv_width=4, expand=2,
                  chunk_size=256, ngroups=1),
    supports_long_context=True,   # O(1)-state decode; run long_500k
    source="[arXiv:2405.21060; unverified]",
)
