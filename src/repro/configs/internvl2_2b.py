"""internvl2-2b — InternViT + InternLM2 VLM; the ViT frontend is a STUB.

[arXiv:2404.16821; hf]  24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  Per the assignment, only the transformer BACKBONE is
modelled; ``input_specs()`` provides 256 precomputed patch embeddings
(InternVL's pixel-unshuffled 448px tile -> 256 visual tokens) which are
prepended to the text sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1000000.0,
    frontend_embeds=256,
    source="[arXiv:2404.16821; hf]",
)
