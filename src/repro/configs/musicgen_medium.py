"""musicgen-medium — decoder-only LM over EnCodec tokens; frontend is a STUB.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24 -> MHA) d_ff=6144
vocab=2048.  The EnCodec tokenizer is a stub: ``input_specs()`` provides
the audio-token stream directly (the assignment models the transformer
backbone only).  MusicGen uses a plain (non-gated) GeLU MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    mlp_type="gelu",
    source="[arXiv:2306.05284; hf]",
)
