"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096.  With 8 experts < 16-way
model axis, experts shard on their FFN dim ('ffn' mode).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1000000.0,
    swa_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336,
                  layer_pattern="all", shard_mode="ffn"),
    supports_long_context=True,   # SWA -> sub-quadratic, bounded KV
    source="[arXiv:2401.04088; hf]",
)
