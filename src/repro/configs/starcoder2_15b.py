"""starcoder2-15b — GQA + RoPE code LM.

[arXiv:2402.19173; hf]  40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152.  StarCoder2 uses a standard (non-gated) GeLU MLP (d_ff = 4x).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=100000.0,
    mlp_type="gelu",
    source="[arXiv:2402.19173; hf]",
)
