"""jamba-1.5-large-398b — hybrid Mamba + attention (1:7) with MoE.

[arXiv:2403.19887; hf]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2.  Attention every 8th layer (1:7 interleave),
MoE every second layer (odd layers), dense FFN otherwise.  Uses the
memory-lean Adafactor optimizer + bf16 params so the 398B-param training
state is representable on a 512-chip v5e footprint.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  layer_pattern="every_2", shard_mode="expert"),
    ssm=SSMConfig(state_dim=128, head_dim=64, conv_width=4, expand=2,
                  chunk_size=256, ngroups=1),
    hybrid_period=8,
    hybrid_attn_index=4,          # Jamba places attention mid-period
    param_dtype="bfloat16",
    optimizer="adafactor",
    supports_long_context=True,   # hybrid: SSM carries long context
    source="[arXiv:2403.19887; hf]",
)
