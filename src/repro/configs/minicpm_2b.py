"""minicpm-2b — llama-like dense LM trained with the WSD schedule.

[arXiv:2404.06395; hf]  40L d_model=2304 36H (GQA kv=36 -> MHA) d_ff=5760
vocab=122753.  MiniCPM uses tied embeddings, depth-scaled residuals
(scale_depth=1.4 -> residual_scale = 1.4/sqrt(L)), scale_emb=12 and
logits divided by d_model/dim_model_base (256).
"""
import math

from repro.configs.base import ArchConfig

_L = 40

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=_L,
    d_model=2304,
    num_heads=36,
    kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=10000.0,
    tie_embeddings=True,
    residual_scale=1.4 / math.sqrt(_L),
    embed_scale=12.0,
    logit_scale=1.0 / (2304 / 256),
    lr_schedule="wsd",            # the paper's Warmup-Stable-Decay schedule
    source="[arXiv:2404.06395; hf]",
)
