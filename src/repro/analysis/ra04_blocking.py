"""RA04 — blocking calls under a lock.

Nothing that can block indefinitely may sit lexically inside a
``with <lock>:`` body: ``queue.get()``/``put()``, socket sends/receives,
``time.sleep``, ``os.fsync``, or ``Future.result()``.  A thread that
blocks while holding a lock stalls every other thread contending for it —
the exact convoy PR 9's watchdogs catch at runtime, caught here at lint
time.

Lock-ish context managers are recognised by name: the final component
contains ``lock``, ``cv``, ``mu``, or ``mutex`` (``self._lock``,
``self._cv``, ``self._wlock``, ``state_lock``, ...).  Queue-ish receivers
likewise (``completion_q``, ``writeq``, ``dev.queue``), so dict
``.get(key, default)`` does not trip the rule.  ``Condition.wait`` is
fine — it releases the lock.  Nested ``def``/``lambda`` bodies are
skipped: defining a callback under a lock is not running it there.

Deliberate exceptions carry ``# ra: disable=RA04(reason)`` — e.g. the
WAL's snapshot fsync, where the lock *is* the commit-point serialiser.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator, List, Optional

from .astutil import dotted_name
from .engine import Context, Finding, SourceFile

RULE = "RA04"
DESCRIPTION = ("no queue.get/put, socket send/recv, time.sleep, os.fsync, "
               "or Future.result() inside `with <lock>:`")

_LOCK_NAME_RE = re.compile(r"(^|_)(lock|cv|mu|mutex)$|wlock|rlock")
_QUEUE_NAME_RE = re.compile(r"(^|_)(q|queue|inq|outq|writeq)$|queue")
_SOCK_NAME_RE = re.compile(r"sock|conn\b")
_SOCK_METHODS = {"send", "sendall", "sendmsg", "recv", "recv_into",
                 "recvmsg", "accept", "connect"}
_FRAME_HELPERS = {"send_frame", "recv_frame", "_recv_exact"}
_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _is_lockish(expr: ast.AST) -> bool:
    name = dotted_name(expr)
    if not name:
        return False
    return bool(_LOCK_NAME_RE.search(name.split(".")[-1].lower()))


def _blocking_reason(call: ast.Call) -> Optional[str]:
    func = call.func
    name = dotted_name(func) or ""
    if name == "time.sleep":
        return "time.sleep holds the lock while dozing"
    if name in ("os.fsync", "os.fdatasync"):
        return f"{name} is a disk-latency stall under the lock"
    if isinstance(func, ast.Name) and func.id in _FRAME_HELPERS:
        return f"{func.id}() does socket I/O under the lock"
    if not isinstance(func, ast.Attribute):
        return None
    attr = func.attr
    recv = dotted_name(func.value) or ""
    last = recv.split(".")[-1].lower()
    if attr == "result":
        return "Future.result() blocks until completion under the lock"
    if attr in ("get", "put") and _QUEUE_NAME_RE.search(last):
        block_false = any(
            kw.arg == "block" and isinstance(kw.value, ast.Constant)
            and kw.value.value is False for kw in call.keywords)
        if not block_false:
            return (f"{recv}.{attr}() can block on the queue while the "
                    f"lock is held (pass block=False or move it out)")
    if attr in _SOCK_METHODS and _SOCK_NAME_RE.search(recv.lower()):
        return f"{recv}.{attr}() is socket I/O under the lock"
    return None


def _walk(nodes: List[ast.AST], lock: Optional[str], src: SourceFile,
          out: List[Finding]) -> None:
    for node in nodes:
        if isinstance(node, _FUNC_NODES):
            body = ([node.body] if isinstance(node, ast.Lambda)
                    else list(node.body))
            _walk(body, None, src, out)  # callback body: runs later
            continue
        if isinstance(node, ast.With):
            held = lock
            for item in node.items:
                _walk([item.context_expr], lock, src, out)
                if _is_lockish(item.context_expr):
                    held = dotted_name(item.context_expr)
            _walk(node.body, held, src, out)
            continue
        if lock and isinstance(node, ast.Call):
            reason = _blocking_reason(node)
            if reason:
                out.append(Finding(
                    src.display, node.lineno, RULE,
                    f"blocking call inside `with {lock}:` — {reason}"))
        _walk(list(ast.iter_child_nodes(node)), lock, src, out)


def check(src: SourceFile, ctx: Context) -> Iterator[Finding]:
    out: List[Finding] = []
    _walk(list(src.tree.body), None, src, out)
    yield from out
