"""RA02 — raw stats mutation.

``self.stats[k] += n`` (and friends) on a ``CounterGroup`` is a lost-update
race: read-modify-write of an atomic counter outside its lock.  PR 8 fixed
every such site; this rule keeps them out.  Use ``stats.inc(k, n)`` /
``stats.max_update(k, v)`` instead.  Plain assignment ``stats[k] = v`` is
allowed — ``CounterGroup.__setitem__`` routes through the atomic
``Counter.set`` — but calling ``__setitem__``/``setdefault``/``update``
explicitly to smuggle a dict-style mutation is not.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from .astutil import dotted_name
from .engine import Context, Finding, SourceFile

RULE = "RA02"
DESCRIPTION = ("no `stats[k] += n` / `__setitem__` on a CounterGroup — "
               "use .inc()/.max_update()")

# attribute / variable names that hold CounterGroup instances in this repo
_STATS_NAMES = {"stats", "read_stats", "counters"}


def _stats_receiver(node: ast.AST) -> Optional[str]:
    """'self.stats' / 'stats' / 'eng.read_stats' if `node` looks like a
    CounterGroup reference, else None."""
    name = dotted_name(node)
    if name and name.split(".")[-1] in _STATS_NAMES:
        return name
    return None


def check(src: SourceFile, ctx: Context) -> Iterator[Finding]:
    for node in ast.walk(src.tree):
        if isinstance(node, ast.AugAssign):
            tgt = node.target
            if isinstance(tgt, ast.Subscript):
                recv = _stats_receiver(tgt.value)
                if recv:
                    yield Finding(
                        src.display, node.lineno, RULE,
                        f"`{recv}[k] {type(node.op).__name__.lower()}=` is a "
                        f"read-modify-write race on a CounterGroup — use "
                        f"`{recv}.inc(k, n)` / `.max_update(k, v)`")
        elif isinstance(node, ast.Call):
            func = node.func
            if (isinstance(func, ast.Attribute)
                    and func.attr in ("__setitem__", "setdefault", "update")):
                recv = _stats_receiver(func.value)
                if recv:
                    yield Finding(
                        src.display, node.lineno, RULE,
                        f"`{recv}.{func.attr}(...)` bypasses the atomic "
                        f"counter API — use `{recv}.inc()` / "
                        f"`.max_update()` / plain `{recv}[k] = v`")
