"""Engine for the invariant lint suite.

Shared plumbing for the RA checkers: source loading (AST + comment map via
``tokenize``), the ``# ra:`` directive grammar, waiver filtering, baseline
files, and the fixture self-test used by CI and the unit tests.

Directive grammar (all live in ``#`` comments):

    # ra: disable=RA04(reason why this site is exempt)
        Waives the named rule(s) on this line, or — when placed on a
        ``def`` line — for the whole function.  Multiple rules separate
        with commas; the parenthesised reason is required by convention
        (reviewed like code) but not enforced grammatically.

    # ra: holds self._lock
        On a ``def`` line: RA01 treats the function body as holding the
        named lock (caller-holds-lock contract, like a ``_locked`` suffix).

    # ra: decode-boundary
        On a ``def`` line: RA03 treats the function as a sanctioned decode
        boundary (its callers receive CodecError/WALError, not struct.error).

    # guarded by self._lock
        On a ``self.attr = ...`` assignment: declares the attribute guarded;
        RA01 then requires every touch to sit under ``with self._lock:``
        (or an aliased Condition constructed from it).

    # ra-selftest: RA03
        Fixture marker (tests only): asserts the analysis reports exactly
        this rule at exactly this line.
"""

from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "AnalysisResult",
    "Context",
    "Finding",
    "SourceFile",
    "all_checkers",
    "format_baseline",
    "load_baseline",
    "run_analysis",
    "selftest",
]

_RULE_RE = re.compile(r"RA\d{2}")
_DISABLE_RE = re.compile(r"ra:\s*disable=(.+)")
_HOLDS_RE = re.compile(r"ra:\s*holds\s+([A-Za-z_][\w.]*)")
_DECODE_RE = re.compile(r"ra:\s*decode-boundary")
_GUARDED_RE = re.compile(r"guarded by\s+([A-Za-z_][\w.]*)")
_SELFTEST_RE = re.compile(r"ra-selftest:\s*(RA\d{2})")


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a specific source location."""

    path: str  # display path (posix, relative to the analysis root)
    line: int
    rule: str  # "RA01" .. "RA06" ("RA00" = file failed to parse)
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"


@dataclass
class Context:
    """Cross-file state shared by all checkers in one run."""

    root: str  # directory findings display relative to; docs/ resolve near it


class SourceFile:
    """A parsed module: AST, comment map, and ``# ra:`` directives."""

    def __init__(self, path: str, display: str, text: str):
        self.path = path
        self.display = display
        self.text = text
        self.tree = ast.parse(text, filename=display)
        # line -> comment text (sans '#'); tokenize is the only stdlib way
        # to recover comments (ast drops them).
        self.comments: Dict[int, str] = {}
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                self.comments[tok.start[0]] = tok.string.lstrip("#").strip()
        self.disables: Dict[int, Set[str]] = {}
        self.holds: Dict[int, str] = {}
        self.decode_boundaries: Set[int] = set()
        self.guard_decls: Dict[int, str] = {}
        self.selftest_marks: Set[Tuple[int, str]] = set()
        for line, comment in self.comments.items():
            m = _DISABLE_RE.search(comment)
            if m:
                self.disables.setdefault(line, set()).update(
                    _RULE_RE.findall(m.group(1)))
            m = _HOLDS_RE.search(comment)
            if m:
                self.holds[line] = m.group(1)
            if _DECODE_RE.search(comment):
                self.decode_boundaries.add(line)
            m = _GUARDED_RE.search(comment)
            if m:
                self.guard_decls[line] = m.group(1)
            for rule in _SELFTEST_RE.findall(comment):
                self.selftest_marks.add((line, rule))
        # (def_line, end_line) spans for def-level waiver scoping
        self._func_spans: List[Tuple[int, int, int]] = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                end = getattr(node, "end_lineno", node.lineno) or node.lineno
                self._func_spans.append((node.lineno, end, node.lineno))

    def comment_only_line(self, line: int) -> bool:
        """True when `line` holds nothing but a comment — directives on
        such lines apply to the line below them."""
        lines = self.text.splitlines()
        return (1 <= line <= len(lines)
                and lines[line - 1].lstrip().startswith("#"))

    def is_waived(self, rule: str, line: int) -> bool:
        """True if `rule` is disabled at `line` — directly, via a
        standalone comment on the line above, or on the ``def`` line of
        any function enclosing it."""
        if rule in self.disables.get(line, ()):
            return True
        if (rule in self.disables.get(line - 1, ())
                and self.comment_only_line(line - 1)):
            return True
        for start, end, def_line in self._func_spans:
            if start <= line <= end and rule in self.disables.get(def_line, ()):
                return True
        return False

    def fn_holds(self, fn: ast.AST) -> Optional[str]:
        return self.holds.get(getattr(fn, "lineno", -1))

    def fn_is_decode_boundary(self, fn: ast.AST) -> bool:
        return getattr(fn, "lineno", -1) in self.decode_boundaries


@dataclass
class AnalysisResult:
    findings: List[Finding] = field(default_factory=list)  # non-waived
    waived: int = 0
    files: int = 0

    def non_baselined(self, baseline: Set[str]) -> List[Finding]:
        return [f for f in self.findings if f.render() not in baseline]


def all_checkers():
    """The registered checker modules, in rule order."""
    from . import (ra01_locks, ra02_stats, ra03_codec, ra04_blocking,
                   ra05_heartbeat, ra06_wiretable)
    return [ra01_locks, ra02_stats, ra03_codec, ra04_blocking,
            ra05_heartbeat, ra06_wiretable]


def _iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in ("__pycache__", ".git", ".pytest_cache"))
                for name in sorted(filenames):
                    if name.endswith(".py"):
                        yield os.path.join(dirpath, name)


def _display_path(path: str, root: str) -> str:
    try:
        rel = os.path.relpath(path, root)
    except ValueError:  # different drive (windows) — keep absolute
        rel = path
    if rel.startswith(".."):
        rel = path
    return rel.replace(os.sep, "/")


def run_analysis(paths: Sequence[str], root: Optional[str] = None,
                 checkers=None) -> AnalysisResult:
    """Run every checker over each ``.py`` file under `paths`.

    Findings come back sorted and with waivers already filtered out;
    `result.waived` counts what the ``# ra: disable`` comments suppressed.
    """
    root = os.path.abspath(root or os.getcwd())
    checkers = checkers if checkers is not None else all_checkers()
    ctx = Context(root=root)
    result = AnalysisResult()
    for path in _iter_py_files(paths):
        display = _display_path(os.path.abspath(path), root)
        try:
            with open(path, "r", encoding="utf-8") as fh:
                text = fh.read()
            src = SourceFile(path, display, text)
        except (SyntaxError, UnicodeDecodeError, tokenize.TokenError) as exc:
            lineno = getattr(exc, "lineno", None) or 1
            result.findings.append(Finding(
                display, int(lineno), "RA00",
                f"file failed to parse: {type(exc).__name__}"))
            result.files += 1
            continue
        result.files += 1
        for checker in checkers:
            for finding in checker.check(src, ctx):
                if src.is_waived(finding.rule, finding.line):
                    result.waived += 1
                else:
                    result.findings.append(finding)
    result.findings.sort()
    return result


# ---------------------------------------------------------------------------
# baseline files

_BASELINE_HEADER = (
    "# repro invariant-lint baseline — one `path:line RAxx message` per "
    "line.\n"
    "# Regenerate: PYTHONPATH=src python -m repro.analysis src/repro "
    "--write-baseline analysis-baseline.txt\n")


def format_baseline(findings: Sequence[Finding]) -> str:
    lines = sorted(f.render() for f in findings)
    body = "".join(line + "\n" for line in lines)
    return _BASELINE_HEADER + body


def load_baseline(text: str) -> Set[str]:
    out = set()
    for line in text.splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


# ---------------------------------------------------------------------------
# fixture self-test

def selftest(fixture_dir: str) -> Tuple[bool, str]:
    """Run the suite over the fixture tree and compare against the
    ``# ra-selftest: RAxx`` markers embedded in the fixtures.

    Exact-match in both directions: every marker must be reported at its
    own (file, line), and nothing unmarked may be reported.  Returns
    ``(ok, human_readable_report)``.
    """
    fixture_dir = os.path.abspath(fixture_dir)
    expected: Set[Tuple[str, int, str]] = set()
    for path in _iter_py_files([fixture_dir]):
        display = _display_path(path, fixture_dir)
        try:
            with open(path, "r", encoding="utf-8") as fh:
                src = SourceFile(path, display, fh.read())
        except (SyntaxError, tokenize.TokenError):
            continue
        for line, rule in src.selftest_marks:
            expected.add((display, line, rule))
    result = run_analysis([fixture_dir], root=fixture_dir)
    actual = {(f.path, f.line, f.rule) for f in result.findings}
    missing = sorted(expected - actual)
    surprise = sorted(actual - expected)
    lines = [f"selftest: {len(expected)} expected findings, "
             f"{len(actual)} reported, {result.files} fixture files"]
    for path, line, rule in missing:
        lines.append(f"  MISSING  {path}:{line} {rule} "
                     f"(marked in fixture, not reported)")
    for path, line, rule in surprise:
        lines.append(f"  SURPRISE {path}:{line} {rule} "
                     f"(reported, no fixture marker)")
    ok = not missing and not surprise and bool(expected)
    if not expected:
        lines.append("  ERROR: no `# ra-selftest:` markers found — "
                     "wrong fixture directory?")
    lines.append("selftest: " + ("OK" if ok else "FAILED"))
    return ok, "\n".join(lines)
