"""RA01 — lock discipline.

Attributes declared guarded (``self.attr = ...  # guarded by self._lock``)
may only be read or written inside a ``with <that lock>:`` block of the
same class.  ``threading.Condition(self._lock)`` aliases are understood:
holding the condition *is* holding the lock.

Escapes, in order of preference:

* ``with self._lock:`` around the access (the point of the rule);
* a ``_locked`` name suffix — the method's contract is "caller holds";
* ``# ra: holds self._lock`` on the ``def`` line (same contract, for
  names that can't take the suffix, e.g. condition-variable predicates);
* ``# ra: disable=RA01(reason)`` for the rare justified exception
  (pre-publication writes in ``__init__`` helpers, advisory reads).

``__init__``/``__new__`` bodies are exempt (no concurrency before the
object is published) — but callables *defined* inside them (metric-gauge
lambdas, callbacks) are not: those run later, on other threads.
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterator, List, Optional, Set

from .astutil import dotted_name, iter_class_functions
from .engine import Context, Finding, SourceFile

RULE = "RA01"
DESCRIPTION = ("guarded attributes (`# guarded by self._lock`) must only be "
               "touched under `with self._lock:`")

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)
_EXEMPT_METHODS = {"__init__", "__new__"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class _ClassInfo:
    def __init__(self) -> None:
        self.guarded: Dict[str, str] = {}  # attr -> guard expr ("self._lock")
        self.aliases: Dict[str, str] = {}  # "self._cv" -> "self._lock"

    def canon(self, lock: str) -> str:
        seen = set()
        while lock in self.aliases and lock not in seen:
            seen.add(lock)
            lock = self.aliases[lock]
        return lock


def _scan_class(cls: ast.ClassDef, src: SourceFile) -> _ClassInfo:
    info = _ClassInfo()
    for node in ast.walk(cls):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for tgt in targets:
            attr = _self_attr(tgt)
            if attr is None:
                continue
            guard = src.guard_decls.get(node.lineno)
            if not guard and src.comment_only_line(node.lineno - 1):
                guard = src.guard_decls.get(node.lineno - 1)
            if guard:
                info.guarded[attr] = guard
            # self._cv = threading.Condition(self._lock): same lock, two names
            value = node.value
            if (isinstance(value, ast.Call)
                    and (dotted_name(value.func) or "").split(".")[-1]
                    == "Condition"
                    and len(value.args) == 1):
                inner = _self_attr(value.args[0])
                if inner is not None:
                    info.aliases[f"self.{attr}"] = f"self.{inner}"
    return info


def _check_body(nodes: List[ast.AST], held: FrozenSet[str],
                info: _ClassInfo, src: SourceFile,
                out: List[Finding], in_exempt_init: bool) -> None:
    for node in nodes:
        if isinstance(node, ast.With):
            for item in node.items:
                _check_body([item.context_expr], held, info, src, out,
                            in_exempt_init)
            acquired = set()
            for item in node.items:
                name = dotted_name(item.context_expr)
                if name:
                    acquired.add(info.canon(name))
            _check_body(node.body, held | acquired, info, src, out,
                        in_exempt_init)
            continue
        if isinstance(node, _FUNC_NODES + (ast.Lambda,)):
            # nested callable: runs later, possibly on another thread,
            # with no lock held — and the __init__ exemption ends here.
            body = node.body if isinstance(node, _FUNC_NODES) else [node.body]
            _check_body(list(body), frozenset(), info, src, out, False)
            continue
        attr = _self_attr(node)
        if attr is not None and attr in info.guarded and not in_exempt_init:
            guard = info.canon(info.guarded[attr])
            if guard not in held:
                out.append(Finding(
                    src.display, node.lineno, RULE,
                    f"self.{attr} is guarded by {info.guarded[attr]} but "
                    f"accessed outside `with {info.guarded[attr]}:`"))
        _check_body(list(ast.iter_child_nodes(node)), held, info, src, out,
                    in_exempt_init)


def check(src: SourceFile, ctx: Context) -> Iterator[Finding]:
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        info = _scan_class(node, src)
        if not info.guarded:
            continue
        for fn in iter_class_functions(node):
            if fn.name.endswith("_locked"):
                continue
            held: Set[str] = set()
            holds = src.fn_holds(fn)
            if holds:
                held.add(info.canon(holds))
            exempt = fn.name in _EXEMPT_METHODS
            out: List[Finding] = []
            _check_body(list(fn.body), frozenset(held), info, src, out,
                        exempt)
            yield from out
