"""RA05 — heartbeat coverage for long-lived threads.

Every ``threading.Thread(target=f)`` whose target (transitively, within
the module) contains a ``while`` loop must call ``beat()`` or ``park()``
somewhere in that closure, or carry ``# ra: disable=RA05(reason)`` — on
the ``Thread(...)`` line or the target's ``def``.  PR 9's watchdogs can
only notice a stalled loop that *beats*; a loop with no heartbeat is
invisible to the health plane.

Resolution is in-module only: ``target=self._loop`` binds to the method
on the enclosing class, ``target=fn`` to a module-level def, and the
call graph is chased one module deep (``self._main`` calling
``self._loop`` which beats, counts).  Unresolvable targets
(``target=httpd.serve_forever``) are skipped — we can't see their body.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .astutil import dotted_name, walk_no_nested_functions
from .engine import Context, Finding, SourceFile

RULE = "RA05"
DESCRIPTION = ("Thread targets with a while loop must beat()/park() a "
               "Heartbeat (or carry an RA05 waiver)")

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)
_Key = Tuple[Optional[str], str]  # (class name or None, function name)


def _collect_functions(tree: ast.Module) -> Dict[_Key, ast.AST]:
    out: Dict[_Key, ast.AST] = {}
    for node in tree.body:
        if isinstance(node, _FUNC_NODES):
            out[(None, node.name)] = node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, _FUNC_NODES):
                    out[(node.name, sub.name)] = sub
    return out


def _callees(fn: ast.AST, cls: Optional[str],
             funcs: Dict[_Key, ast.AST]) -> Set[_Key]:
    out: Set[_Key] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if (isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Name)
                and func.value.id == "self" and cls is not None
                and (cls, func.attr) in funcs):
            out.add((cls, func.attr))
        elif isinstance(func, ast.Name) and (None, func.id) in funcs:
            out.add((None, func.id))
    return out


def _closure(start: _Key, funcs: Dict[_Key, ast.AST]) -> List[_Key]:
    seen: Set[_Key] = set()
    work = [start]
    while work:
        key = work.pop()
        if key in seen or key not in funcs:
            continue
        seen.add(key)
        work.extend(_callees(funcs[key], key[0], funcs))
    return sorted(seen, key=str)


def _has_while(fn: ast.AST) -> bool:
    return any(isinstance(n, ast.While) for n in ast.walk(fn))


def _has_beat(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("beat", "park")):
            return True
    return False


def check(src: SourceFile, ctx: Context) -> Iterator[Finding]:
    funcs = _collect_functions(src.tree)

    # walk every Thread(...) call, remembering the enclosing class
    def walk(node: ast.AST, cls: Optional[str]) -> Iterator[ast.Call]:
        for child in ast.iter_child_nodes(node):
            inner_cls = child.name if isinstance(child, ast.ClassDef) else cls
            if isinstance(child, ast.Call):
                name = dotted_name(child.func) or ""
                if name.split(".")[-1] == "Thread":
                    yield child, cls
            yield from walk(child, inner_cls)

    for call, cls in walk(src.tree, None):
        target = next((kw.value for kw in call.keywords
                       if kw.arg == "target"), None)
        if target is None:
            continue
        key: Optional[_Key] = None
        if (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self" and cls is not None):
            key = (cls, target.attr)
        elif isinstance(target, ast.Name):
            key = (None, target.id)
        if key is None or key not in funcs:
            continue  # out-of-module target: nothing to inspect
        closure = _closure(key, funcs)
        bodies = [funcs[k] for k in closure]
        if not any(_has_while(b) for b in bodies):
            continue  # one-shot worker; watchdogs don't apply
        if any(_has_beat(b) for b in bodies):
            continue
        tgt_name = (f"{key[0]}.{key[1]}" if key[0] else key[1])
        finding = Finding(
            src.display, call.lineno, RULE,
            f"thread target {tgt_name}() loops forever but never beat()s "
            f"or park()s a Heartbeat — invisible to the PR 9 watchdogs")
        # honour a waiver placed on the target's def line, not just the
        # Thread(...) call site
        def_line = funcs[key].lineno
        if RULE in src.disables.get(def_line, ()):
            continue
        yield finding
