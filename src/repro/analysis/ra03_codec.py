"""RA03 — codec safety on wire/disk bytes.

Scope: modules under ``serve/`` plus the durable-format modules
(``wal.py``, ``castore.py``, ``blockstore.py``) — everywhere bytes arrive
from a socket or disk and are therefore hostile (truncated, bit-flipped,
or adversarial).

Two checks:

* **RA03a — unpack behind a boundary.**  Every ``struct.unpack`` /
  ``Struct.unpack_from`` must sit where ``struct.error``/``IndexError``
  cannot escape raw: an explicit bounds check (a ``len(...)`` call earlier
  in the same function — the repo's ``_take*`` idiom), an enclosing
  ``try`` whose handlers catch struct/index errors and re-raise the
  domain error (``CodecError``/``WALError``/``FrameError``/``AuthError``),
  or a ``# ra: decode-boundary`` annotation on the ``def``.

* **RA03b — length checked before allocation.**  When a value produced by
  an unpack flows into a read/allocation call (``recv``, ``_recv_exact``,
  ``fh.read``, ``bytes``/``bytearray``), some comparison against a
  ``max``-named bound (``max_frame_bytes``, ``MAX_RECORD_BYTES``, ...)
  must appear earlier in the function.  A length field is attacker data;
  allocating first is a one-frame memory bomb.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from .astutil import dotted_name, iter_functions, walk_no_nested_functions
from .engine import Context, Finding, SourceFile

RULE = "RA03"
DESCRIPTION = ("struct.unpack on wire bytes needs a bounds check / "
               "decode-boundary; length fields checked vs max before "
               "allocation")

_WIRE_BASENAMES = {"wal.py", "castore.py", "blockstore.py"}
_ALLOC_CALLEES = {"recv", "recv_into", "_recv_exact", "read", "bytes",
                  "bytearray"}
_CAUGHT_OK = {"error", "Exception", "BaseException", "IndexError",
              "ValueError", "struct.error"}


def _in_scope(src: SourceFile) -> bool:
    parts = src.display.split("/")
    return "serve" in parts or parts[-1] in _WIRE_BASENAMES


def _is_unpack(call: ast.Call) -> bool:
    func = call.func
    return (isinstance(func, ast.Attribute)
            and func.attr in ("unpack", "unpack_from"))


def _handler_catches(trynode: ast.Try) -> bool:
    for handler in trynode.handlers:
        if handler.type is None:  # bare except
            return True
        types = (handler.type.elts
                 if isinstance(handler.type, ast.Tuple) else [handler.type])
        for t in types:
            name = dotted_name(t) or ""
            if name in _CAUGHT_OK or name.split(".")[-1] in _CAUGHT_OK:
                return True
    return False


def _tainted_names(fn: ast.AST) -> Set[str]:
    """Names assigned (directly or via tuple unpacking) from an unpack."""
    out: Set[str] = set()
    for node in walk_no_nested_functions(fn):
        if not isinstance(node, ast.Assign):
            continue
        value = node.value
        if not (isinstance(value, ast.Call) and _is_unpack(value)):
            continue
        for tgt in node.targets:
            elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
            for e in elts:
                if isinstance(e, ast.Name):
                    out.add(e.id)
    return out


def _is_bound_check(node: ast.Compare, tainted: Set[str]) -> bool:
    """A comparison that bounds a wire-decoded length: either against a
    ``max``-named cap, or against ``len(<buffer we already hold>)`` with a
    tainted name involved (allocation bounded by bytes in hand)."""
    has_max = False
    has_len = False
    has_taint = False
    for sub in ast.walk(node):
        name = dotted_name(sub)
        if name and "max" in name.lower():
            has_max = True
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "len"):
            has_len = True
        if isinstance(sub, ast.Name) and sub.id in tainted:
            has_taint = True
    return has_max or (has_len and has_taint)


def check(src: SourceFile, ctx: Context) -> Iterator[Finding]:
    if not _in_scope(src):
        return
    # parent-Try map for RA03a
    try_stack: List[ast.Try] = []
    for fn, _cls in iter_functions(src.tree):
        is_boundary = src.fn_is_decode_boundary(fn)
        # line of the first len(...) call in this function, if any
        len_lines = [n.lineno for n in walk_no_nested_functions(fn)
                     if isinstance(n, ast.Call)
                     and isinstance(n.func, ast.Name) and n.func.id == "len"]
        first_len = min(len_lines) if len_lines else None
        # enclosing-try info per node, via a scoped walk
        guarded_lines: Set[int] = set()
        def mark_try(node: ast.AST, inside_ok: bool) -> None:
            for child in ast.iter_child_nodes(node):
                ok = inside_ok
                if isinstance(node, ast.Try) and child in node.body:
                    ok = inside_ok or _handler_catches(node)
                if ok and hasattr(child, "lineno"):
                    guarded_lines.add(child.lineno)
                mark_try(child, ok)
        mark_try(fn, False)

        for node in walk_no_nested_functions(fn):
            if not (isinstance(node, ast.Call) and _is_unpack(node)):
                continue
            if is_boundary:
                continue
            if first_len is not None and first_len <= node.lineno:
                continue  # the `_take` idiom: bounds-checked before unpack
            if node.lineno in guarded_lines:
                continue  # inside try whose handlers absorb struct.error
            yield Finding(
                src.display, node.lineno, RULE,
                "struct unpack of wire bytes with no bounds check, no "
                "struct.error handler, and no `# ra: decode-boundary` — "
                "a truncated frame escapes as raw struct.error")

        # RA03b: tainted length -> allocation without a max-bound compare
        tainted = _tainted_names(fn)
        if not tainted:
            continue
        compare_lines = [n.lineno for n in walk_no_nested_functions(fn)
                         if isinstance(n, ast.Compare)
                         and _is_bound_check(n, tainted)]
        first_cmp = min(compare_lines) if compare_lines else None
        for node in walk_no_nested_functions(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = (node.func.attr if isinstance(node.func, ast.Attribute)
                      else node.func.id if isinstance(node.func, ast.Name)
                      else None)
            if callee not in _ALLOC_CALLEES:
                continue
            uses_taint = any(
                isinstance(sub, ast.Name) and sub.id in tainted
                for arg in node.args for sub in ast.walk(arg))
            if not uses_taint:
                continue
            if first_cmp is not None and first_cmp <= node.lineno:
                continue
            yield Finding(
                src.display, node.lineno, RULE,
                "length decoded from the wire reaches an allocation/read "
                "before any check against a max_*_bytes bound — cap it "
                "first (one hostile frame is a memory bomb)")
