"""Invariant lint suite: AST checkers for the repo's concurrency and
wire-protocol conventions.

Nine PRs of hand-enforced discipline — stats through ``CounterGroup.inc()``,
untrusted bytes through ``CodecError``/``WALError`` decode boundaries,
heartbeats on every long-lived thread, no blocking calls under locks —
are machine-checked here.  Run as::

    PYTHONPATH=src python -m repro.analysis src/repro

Rules (see docs/STATIC_ANALYSIS.md for the full table):

    RA01  lock discipline: guarded attributes only under ``with <lock>:``
    RA02  raw stats mutation: no ``stats[k] += n`` on a CounterGroup
    RA03  codec safety: struct.unpack of wire bytes behind decode boundaries
    RA04  blocking calls (sleep/fsync/queue/socket/Future.result) under locks
    RA05  heartbeat coverage: looping thread targets must beat()/park()
    RA06  wire-table drift: opcodes vs dispatch vs documented table

Stdlib-only by design (``ast`` + ``tokenize``): the lint gate must run in
any environment the tests run in, with zero extra dependencies.
"""

from .engine import (  # noqa: F401
    AnalysisResult,
    Context,
    Finding,
    SourceFile,
    all_checkers,
    format_baseline,
    load_baseline,
    run_analysis,
    selftest,
)
