"""Small AST helpers shared by the RA checkers."""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

__all__ = [
    "dotted_name",
    "iter_functions",
    "iter_class_functions",
    "walk_no_nested_functions",
]

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted_name(node: ast.AST) -> Optional[str]:
    """``self._cv`` / ``threading.Thread`` as a string, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def iter_functions(tree: ast.Module) -> Iterator[
        Tuple[ast.AST, Optional[str]]]:
    """Yield (function_node, enclosing_class_name) for every def in the
    module, including methods and nested functions."""
    def walk(node: ast.AST, cls: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child.name)
            elif isinstance(child, _FUNC_NODES):
                yield child, cls
                yield from walk(child, cls)
            else:
                yield from walk(child, cls)
    yield from walk(tree, None)


def iter_class_functions(cls: ast.ClassDef) -> Iterator[ast.AST]:
    """Direct methods of a class (no nested functions, no inner classes)."""
    for child in cls.body:
        if isinstance(child, _FUNC_NODES):
            yield child


def walk_no_nested_functions(node: ast.AST) -> Iterator[ast.AST]:
    """ast.walk that does not descend into nested def/lambda bodies —
    lexical analyses use this so code that merely *defines* a callback is
    not confused with code that runs on the current thread."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(node))
    while stack:
        cur = stack.pop()
        yield cur
        if isinstance(cur, _FUNC_NODES + (ast.Lambda,)):
            continue
        stack.extend(ast.iter_child_nodes(cur))
