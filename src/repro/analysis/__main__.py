"""CLI for the invariant lint suite.

    PYTHONPATH=src python -m repro.analysis src/repro \\
        --baseline analysis-baseline.txt --report ra-findings.txt

Exit status: 0 when every finding is baselined (or there are none),
1 when new findings exist, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import os
import sys

from .engine import (all_checkers, format_baseline, load_baseline,
                     run_analysis, selftest)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Run the RA invariant checkers over a source tree.")
    parser.add_argument("paths", nargs="*", default=[],
                        help="files or directories to scan "
                             "(default: src/repro)")
    parser.add_argument("--root", default=None,
                        help="root findings are reported relative to and "
                             "docs/ resolved against (default: cwd)")
    parser.add_argument("--baseline", default=None, metavar="FILE",
                        help="suppress findings listed in FILE")
    parser.add_argument("--write-baseline", default=None, metavar="FILE",
                        help="write current findings to FILE and exit 0")
    parser.add_argument("--report", default=None, metavar="FILE",
                        help="also write the findings report to FILE "
                             "(always written, for CI artifacts)")
    parser.add_argument("--selftest", default=None, metavar="DIR",
                        help="run the fixture self-test over DIR and exit")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for checker in all_checkers():
            print(f"{checker.RULE}  {checker.DESCRIPTION}")
        return 0

    if args.selftest:
        ok, report = selftest(args.selftest)
        print(report)
        return 0 if ok else 1

    paths = args.paths or ["src/repro"]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2
    result = run_analysis(paths, root=args.root)

    if args.write_baseline:
        with open(args.write_baseline, "w", encoding="utf-8") as fh:
            fh.write(format_baseline(result.findings))
        print(f"wrote {len(result.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = set()
    if args.baseline and os.path.exists(args.baseline):
        with open(args.baseline, "r", encoding="utf-8") as fh:
            baseline = load_baseline(fh.read())
    fresh = result.non_baselined(baseline)
    baselined = len(result.findings) - len(fresh)

    lines = [f.render() for f in fresh]
    summary = (f"{len(fresh)} finding(s) "
               f"({baselined} baselined, {result.waived} waived) "
               f"across {result.files} file(s)")
    out = "\n".join(lines + [summary]) + "\n"
    sys.stdout.write(out)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            fh.write(out)
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
