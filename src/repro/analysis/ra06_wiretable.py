"""RA06 — wire-table drift.

Any module defining the opcode constants (``(OP_OPEN, ...) = range(n)``)
is cross-checked three ways:

* **OP_NAMES**: the human-name map must cover exactly the defined
  opcodes — a new verb (``OP_STATS``, ``OP_HEALTH``) that skips the map
  breaks tracing labels silently.
* **codec + dispatch coverage**: each of ``encode_request`` /
  ``decode_request`` / ``encode_response`` / ``decode_response`` (when
  present) and the dispatch function (name containing ``handle`` or
  ``dispatch``, referencing ≥ 2 opcodes) must reference every opcode —
  a verb the decoder accepts but the dispatcher ignores is a hang, not
  an error.
* **documented table**: ``docs/WIRE_PROTOCOL.md`` (located by walking up
  from the module towards the analysis root) must carry a markdown table
  row ``| OP_X | value |`` for every opcode, with matching values, and
  no rows for opcodes the code no longer defines.

All findings are reported against the module (at the constant-definition
or offending-function line) so fixtures and waivers stay in one file.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .engine import Context, Finding, SourceFile

RULE = "RA06"
DESCRIPTION = ("opcode constants vs OP_NAMES vs codec/dispatch coverage vs "
               "the documented wire table must agree")

_CODEC_FUNCS = ("encode_request", "decode_request",
                "encode_response", "decode_response")
_DOC_NAME = os.path.join("docs", "WIRE_PROTOCOL.md")
_DOC_ROW_RE = re.compile(r"^\|\s*`?(OP_[A-Z_]+)`?\s*\|\s*(\d+)\s*\|")


def _opcode_constants(tree: ast.Module) -> Tuple[Dict[str, int], int]:
    """Parse ``(OP_A, OP_B, ...) = range(n)`` → ({name: value}, lineno)."""
    for node in tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not isinstance(tgt, ast.Tuple):
            continue
        names = [e.id for e in tgt.elts
                 if isinstance(e, ast.Name) and e.id.startswith("OP_")]
        if len(names) != len(tgt.elts) or not names:
            continue
        value = node.value
        if (isinstance(value, ast.Call) and isinstance(value.func, ast.Name)
                and value.func.id == "range"):
            return {name: i for i, name in enumerate(names)}, node.lineno
    return {}, 0


def _names_referenced(fn: ast.AST, universe: Set[str]) -> Set[str]:
    return {n.id for n in ast.walk(fn)
            if isinstance(n, ast.Name) and n.id in universe}


def _find_doc(src_path: str, root: str) -> Optional[str]:
    """Nearest docs/WIRE_PROTOCOL.md walking up from the module to root."""
    cur = os.path.dirname(os.path.abspath(src_path))
    root = os.path.abspath(root)
    for _ in range(32):
        cand = os.path.join(cur, _DOC_NAME)
        if os.path.isfile(cand):
            return cand
        if cur == root or os.path.dirname(cur) == cur:
            break
        cur = os.path.dirname(cur)
    cand = os.path.join(root, _DOC_NAME)
    return cand if os.path.isfile(cand) else None


def check(src: SourceFile, ctx: Context) -> Iterator[Finding]:
    opcodes, def_line = _opcode_constants(src.tree)
    if not opcodes:
        return
    universe = set(opcodes)

    # --- OP_NAMES map coverage -------------------------------------------
    for node in src.tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "OP_NAMES"
                and isinstance(node.value, ast.Dict)):
            keys = {k.id for k in node.value.keys
                    if isinstance(k, ast.Name) and k.id in universe}
            for missing in sorted(universe - keys):
                yield Finding(
                    src.display, node.lineno, RULE,
                    f"OP_NAMES is missing {missing} — tracing/QoS labels "
                    f"for that verb fall back to nothing")

    # --- codec + dispatch coverage ---------------------------------------
    fns: List[Tuple[str, ast.AST]] = []
    def collect(node: ast.AST):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fns.append((child.name, child))
            collect(child)
    collect(src.tree)

    for fname, fn in fns:
        wanted = fname in _CODEC_FUNCS
        if not wanted and ("handle" in fname or "dispatch" in fname):
            wanted = len(_names_referenced(fn, universe)) >= 2
        if not wanted:
            continue
        referenced = _names_referenced(fn, universe)
        for missing in sorted(universe - referenced):
            yield Finding(
                src.display, fn.lineno, RULE,
                f"{fname}() does not handle {missing} — drift between the "
                f"opcode table and the {fname} switch")

    # --- documented table -------------------------------------------------
    doc_path = _find_doc(src.path, ctx.root)
    if doc_path is None:
        yield Finding(
            src.display, def_line, RULE,
            f"no {_DOC_NAME} found for the opcode table — the wire "
            f"protocol must be documented where reviewers can diff it")
        return
    doc_rows: Dict[str, int] = {}
    with open(doc_path, "r", encoding="utf-8") as fh:
        for line in fh:
            m = _DOC_ROW_RE.match(line.strip())
            if m:
                doc_rows[m.group(1)] = int(m.group(2))
    for name, value in sorted(opcodes.items()):
        if name not in doc_rows:
            yield Finding(
                src.display, def_line, RULE,
                f"{name} (= {value}) is not documented in {_DOC_NAME}")
        elif doc_rows[name] != value:
            yield Finding(
                src.display, def_line, RULE,
                f"{name} is {value} in code but {doc_rows[name]} in "
                f"{_DOC_NAME} — the documented table has drifted")
    for name in sorted(set(doc_rows) - universe):
        yield Finding(
            src.display, def_line, RULE,
            f"{_DOC_NAME} documents {name}, which the code no longer "
            f"defines — stale table row")
