from repro.data.pipeline import SyntheticTokens, make_pipeline  # noqa: F401
