"""Deterministic sharded data pipeline.

Batches are a pure function of (seed, step, shard), so a restarted (or
elastically resharded) trainer resumes the exact token stream from its
checkpointed step — the data-side half of fault tolerance.  The token
stream is a Zipf-ish mixture with local n-gram structure so losses
decrease measurably during the example runs (pure uniform noise would
have a constant floor at ln V).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    frontend_embeds: int = 0
    d_model: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards
        # a fixed random unigram table + bigram successor table give the
        # stream learnable structure
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks ** 1.1)
        self._unigram /= self._unigram.sum()
        self._succ = rng.integers(0, V, size=(min(V, 4096),),
                                  dtype=np.int64)

    def _row(self, step: int, global_row: int):
        """One sequence, keyed by (seed, step, GLOBAL row id) — elastic
        resharding re-partitions identical rows across any shard count."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + global_row)
        S, V = self.seq_len, self.vocab_size
        toks = rng.choice(V, size=S, p=self._unigram)
        follow = rng.random(S - 1) < 0.5
        succ = self._succ[toks[:-1] % len(self._succ)]
        toks[1:] = np.where(follow, succ, toks[1:])
        emb = None
        if self.frontend_embeds:
            emb = rng.standard_normal(
                (self.frontend_embeds, self.d_model)).astype(np.float32)
        return toks, emb

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = range(self.shard * self.local_batch,
                     (self.shard + 1) * self.local_batch)
        toks, embs = [], []
        for r in rows:
            t, e = self._row(step, r)
            toks.append(t)
            if e is not None:
                embs.append(e)
        out = {"tokens": np.stack(toks).astype(np.int32)}
        if embs:
            out["embeds"] = np.stack(embs)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_pipeline(cfg, seq_len: int, global_batch: int, seed: int = 0,
                  shard: int = 0, num_shards: int = 1) -> SyntheticTokens:
    F = cfg.frontend_embeds
    return SyntheticTokens(
        vocab_size=cfg.vocab_size,
        seq_len=seq_len - F,
        global_batch=global_batch,
        seed=seed, shard=shard, num_shards=num_shards,
        frontend_embeds=F, d_model=cfg.d_model)
