"""CrystalTPU — the accelerator task-management runtime (CrystalGPU analog).

The paper's CrystalGPU layer sits between the storage system and the GPU
runtime and provides three application-agnostic optimizations:
  (1) buffer reuse   — amortize (pinned) buffer allocation across a stream
                       of hashing jobs,
  (2) transfer/compute overlap — pipeline H2D copy of job i+1 with the
                       kernel of job i,
  (3) transparent multi-device — round-robin dispatch over all devices.

TPU/JAX adaptation: JAX's runtime is asynchronous by design, so overlap is
expressed by *not* synchronizing between stage boundaries (async dispatch
pipelines transfer and compute), while the no-overlap baseline inserts
``block_until_ready`` after every stage — mirroring the paper's staged
Table-1 execution.  Buffer reuse keeps a free-list of device-resident
input buffers that are re-filled in place (donated on dispatch) instead of
allocating + copying fresh host arrays per job.  The same master/manager-
thread/queue structure as CrystalGPU is kept: an idle queue of
preallocated job slots, an outstanding queue of submitted jobs, one
manager thread per device, and completion callbacks.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.kernels import ops


@dataclass
class Job:
    kind: str                          # 'direct' | 'sliding' | 'gear'
    data: Optional[np.ndarray] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    callback: Optional[Callable] = None
    result: Any = None
    error: Optional[BaseException] = None
    done: threading.Event = field(default_factory=threading.Event)
    timings: Dict[str, float] = field(default_factory=dict)

    def wait(self):
        self.done.wait()
        if self.error is not None:
            raise self.error
        return self.result


class CrystalTPU:
    """Task-management engine for hashing offload.

    Parameters mirror the paper's ablation switches:
      buffer_reuse: keep and reuse job input buffers (idle queue)
      overlap:      async dispatch (no per-stage synchronization)
      devices:      accelerators to round-robin over (default: all)
    """

    def __init__(self, devices=None, buffer_reuse: bool = True,
                 overlap: bool = True, n_slots: int = 8,
                 interpret: bool = True):
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.buffer_reuse = buffer_reuse
        self.overlap = overlap
        self.interpret = interpret
        self.outstanding: "queue.Queue[Optional[Job]]" = queue.Queue()
        self.idle: "queue.Queue[dict]" = queue.Queue()
        for _ in range(n_slots):
            self.idle.put({})          # slot: device-buffer cache by shape
        self.running: List[Job] = []
        self._lock = threading.Lock()
        self._managers = [
            threading.Thread(target=self._manager_loop, args=(d,),
                             daemon=True, name=f"crystal-mgr-{i}")
            for i, d in enumerate(self.devices)]
        self._alive = True
        for t in self._managers:
            t.start()
        self.stats = {"jobs": 0, "bytes": 0}

    # ------------------------------------------------------------------
    def submit(self, kind: str, data: np.ndarray, meta=None,
               callback=None) -> Job:
        job = Job(kind=kind, data=np.asarray(data), meta=meta or {},
                  callback=callback)
        self.outstanding.put(job)
        return job

    def map_stream(self, kind: str, buffers, meta=None) -> List[Job]:
        """Submit a stream of jobs back-to-back (the paper's batched
        streaming workload) and return the job list."""
        return [self.submit(kind, b, meta) for b in buffers]

    def shutdown(self):
        self._alive = False
        for _ in self._managers:
            self.outstanding.put(None)
        for t in self._managers:
            t.join(timeout=5)

    # ------------------------------------------------------------------
    def _get_slot(self) -> dict:
        if self.buffer_reuse:
            return self.idle.get()
        return {}

    def _put_slot(self, slot: dict):
        if self.buffer_reuse:
            self.idle.put(slot)

    def _stage_sync(self, x):
        """Baseline (no overlap): force completion at stage boundary."""
        if not self.overlap:
            jax.block_until_ready(x)
        return x

    def _manager_loop(self, device):
        while self._alive:
            job = self.outstanding.get()
            if job is None:
                return
            slot = self._get_slot()
            t0 = time.perf_counter()
            try:
                with self._lock:
                    self.running.append(job)
                # stage 1-2: buffer (re)use + transfer in.  With reuse, a
                # persistent staging buffer per slot is refilled in place
                # (the analogue of reusing pinned host memory); without, a
                # fresh staging allocation is made per job (the paper's
                # unoptimized malloc-per-task path).
                key = (job.data.shape, str(job.data.dtype))
                if self.buffer_reuse:
                    staging = slot.get(key)
                    if staging is None:
                        staging = np.empty_like(job.data)
                        slot[key] = staging
                    np.copyto(staging, job.data)
                else:
                    staging = np.array(job.data)     # fresh alloc + copy
                buf = staging
                dev_buf = jax.device_put(buf, device)
                self._stage_sync(dev_buf)
                t1 = time.perf_counter()
                # stage 3: kernel
                result = self._run_kernel(job, dev_buf)
                self._stage_sync(result)
                t2 = time.perf_counter()
                # stage 4: transfer out (numpy conversion pulls to host)
                host = jax.tree.map(np.asarray, result)
                t3 = time.perf_counter()
                job.result = host
                job.timings = {"in": t1 - t0, "kernel": t2 - t1,
                               "out": t3 - t2}
                with self._lock:
                    self.stats["jobs"] += 1
                    self.stats["bytes"] += buf.nbytes
            except BaseException as e:              # surfaced via wait()
                job.error = e
            finally:
                with self._lock:
                    if job in self.running:
                        self.running.remove(job)
                self._put_slot(slot)
                job.done.set()
                if job.callback is not None:
                    try:
                        job.callback(job)
                    except Exception:
                        pass

    # ------------------------------------------------------------------
    def _run_kernel(self, job: Job, dev_buf):
        kind = job.kind
        meta = job.meta
        if kind == "direct":
            seg = meta.get("seg_bytes", 4096)
            data = np.asarray(dev_buf)
            n = (len(data) + seg - 1) // seg
            padded = np.zeros((n, seg), np.uint8)
            flat = data.reshape(-1)
            padded.reshape(-1)[:flat.size] = flat
            lens = np.full((n,), seg, np.int64)
            tail = flat.size - (n - 1) * seg
            lens[-1] = (tail + 3) // 4 * 4
            return ops.direct_hash(padded, lens, interpret=self.interpret)
        if kind == "sliding":
            return ops.sliding_window_hash(
                np.asarray(dev_buf), window=meta.get("window", 48),
                stride=meta.get("stride", 4), interpret=self.interpret)
        if kind == "gear":
            return ops.gear_hash(np.asarray(dev_buf),
                                 interpret=self.interpret)
        raise ValueError(f"unknown job kind {kind!r}")
