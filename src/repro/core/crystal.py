"""CrystalTPU — the generalized offload engine (CrystalGPU analog).

The paper's CrystalGPU layer sits between the storage system and the GPU
runtime and provides application-agnostic optimizations that make hashing
offload pay off:
  (1) buffer reuse   — amortize (pinned) staging-buffer allocation across
                       a stream of hashing jobs,
  (2) transfer/compute overlap — pipeline H2D copy of job i+1 with the
                       kernel of job i,
  (3) transparent multi-device — round-robin dispatch over all devices,
  (4) request coalescing — fuse many small outstanding hash requests
                       (concurrent writers, checkpoint leaves, read-path
                       verification) into ONE padded batch kernel launch,
                       so per-launch overhead is amortized over the whole
                       burst.  This covers every job kind: ``direct``
                       rows stack into one [B, W] batch, and bursts of
                       same-config ``sliding`` / ``gear`` stream jobs
                       (CDC chunking bursts: checkpoint restore, many
                       concurrent writers) stack into one padded [B, L]
                       multi-row launch via the ``ops.*_batch_device``
                       entry points.

Engine structure (same master/manager-thread/queue design as CrystalGPU):
an idle queue of preallocated job slots, an outstanding queue of submitted
jobs, one manager thread per device, and completion callbacks.  Each
manager drains the outstanding queue: it takes one job, then greedily
pulls every further queued job with the same fuse key — ``direct`` with
``direct``, ``sliding`` with identical window/stride, ``gear`` with
``gear`` — (plus stragglers within ``coalesce_window_s``) and executes
the whole batch as a single kernel launch, slicing each job's rows out
of the fused phase-matrix output.  Batch row counts and padded widths
are bucketed to powers of two to bound jit retraces across ragged
bursts.  ``stats["launches"] < stats["jobs"]`` is the signature of a
fused burst.

Data stays device-resident from ``device_put`` through the kernel: hosts
prepare word-packed staging buffers, the device buffer is handed straight
to the jit'd kernel entry points (``ops.*_device``), and only the (small)
digest/fingerprint output is pulled back to the host — the seed's
``np.asarray(dev_buf)`` host round-trip before every launch is gone.

TPU/JAX adaptation: JAX's runtime is asynchronous by design, so overlap is
expressed by *not* synchronizing between stage boundaries (async dispatch
pipelines transfer and compute), while the no-overlap baseline inserts
``block_until_ready`` after every stage — mirroring the paper's staged
Table-1 execution.

Job normal forms
----------------
  'direct'  : data = [n, w] uint8 rows (w % 4 == 0) and meta['lens'] =
              [n] byte lengths (multiples of 4, <= w); result [n, 16]
              uint8 digests.  Legacy form: data = flat uint8 buffer plus
              meta['seg_bytes'] — split into fixed segments, word-aligned
              tail.  Coalescing fuses any mix of direct jobs: rows are
              zero-padded to the widest row in the batch (digests are
              length-bound, so trailing zeros never change them).
  'sliding' : data = flat uint8 buffer, meta {'window', 'stride'};
              result [n_offsets] uint32 window hashes.
  'gear'    : data = flat uint8 buffer; result [len] uint32 rolling hash.
"""
from __future__ import annotations

import atexit
import collections
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.kernels import ops


LANES = ("fg", "batch", "scrub")       # dequeue priority, highest first


class LaneQueue:
    """Priority job queue: lanes dequeue strictly in ``LANES`` order —
    interactive foreground traffic first, then ``batch`` (throughput
    tenants behind the storage gateway), then ``scrub`` (background
    scrub/repair traffic from the node runtime) — and shutdown sentinels
    (``None``) dequeue only once every lane is empty, so ``shutdown()``
    still drains queued background jobs instead of orphaning their
    waiters.  API mirrors the subset of ``queue.Queue`` the managers use
    (put/get/get_nowait)."""

    def __init__(self):
        self._cv = threading.Condition()
        self._lanes: Dict[str, collections.deque] = \
            {lane: collections.deque() for lane in LANES}
        self._sentinels = 0

    def put(self, item, lane: str = "fg"):
        with self._cv:
            if item is None:
                self._sentinels += 1
            else:
                self._lanes[lane].append(item)
            self._cv.notify()

    def _pop_locked(self):
        for lane in LANES:
            if self._lanes[lane]:
                return self._lanes[lane].popleft()
        self._sentinels -= 1            # caller checked _sentinels > 0
        return None

    def _nonempty(self) -> bool:
        return bool(self._sentinels
                    or any(self._lanes[lane] for lane in LANES))

    def get(self, timeout: Optional[float] = None):
        with self._cv:
            if not self._cv.wait_for(self._nonempty, timeout):
                raise queue.Empty
            return self._pop_locked()

    def get_nowait(self):
        with self._cv:
            if not self._nonempty():
                raise queue.Empty
            return self._pop_locked()

    def depth(self, lane: Optional[str] = None) -> int:
        """Queued jobs in one lane (or all lanes) — the load signal the
        node runtime's scrub backoff and the gateway stats read."""
        with self._cv:
            if lane is None:
                return sum(len(q) for q in self._lanes.values())
            return len(self._lanes[lane])

    def qsize(self) -> int:
        return self.depth()


@dataclass(eq=False)                   # identity semantics: jobs hold
class Job:                             # numpy fields, and the manager's
    # running-list membership/removal must never compare array contents
    kind: str                          # 'direct' | 'sliding' | 'gear'
    data: Optional[np.ndarray] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    callback: Optional[Callable] = None
    result: Any = None
    error: Optional[BaseException] = None
    done: threading.Event = field(default_factory=threading.Event)
    timings: Dict[str, float] = field(default_factory=dict)
    # normalized 'direct' payload (set at submit time)
    rows: Optional[np.ndarray] = None
    lens: Optional[np.ndarray] = None
    # jobs with equal fuse keys may share one kernel launch
    fuse_key: tuple = ()
    # 'fg' = interactive client traffic; 'batch' = throughput traffic
    # (gateway batch-QoS tenants) that yields to interactive jobs;
    # 'scrub' = lowest-priority background traffic (node-runtime
    # scrub/repair) tracked by the scrub_* stats counters
    lane: str = "fg"
    # pow2-padded staging shape, used to bound fused-batch memory:
    # the fused matrix is (sum n_rows) x (max staged_width) bytes
    n_rows: int = 1
    staged_width: int = 0

    def wait(self):
        self.done.wait()
        if self.error is not None:
            raise self.error
        return self.result


def _normalize_direct(data: np.ndarray, meta: Dict[str, Any]):
    """Return (rows [n, w] uint8, lens [n] int64) for a direct request."""
    data = np.asarray(data)
    if data.ndim == 2:
        rows = data.astype(np.uint8, copy=False)
        lens = meta.get("lens")
        if lens is None:
            lens = np.full((rows.shape[0],), rows.shape[1], np.int64)
        else:
            lens = np.asarray(lens, np.int64)
        return rows, lens
    seg = int(meta.get("seg_bytes", 4096))
    flat = data.reshape(-1).astype(np.uint8, copy=False)
    n = max((flat.size + seg - 1) // seg, 1)
    rows = np.zeros((n, seg), np.uint8)
    rows.reshape(-1)[:flat.size] = flat
    lens = np.full((n,), seg, np.int64)
    tail = flat.size - (n - 1) * seg
    lens[-1] = (tail + 3) // 4 * 4
    return rows, lens


class CrystalTPU:
    """Coalescing offload engine for hashing jobs.

    Parameters mirror the paper's ablation switches plus coalescing:
      buffer_reuse:      keep and reuse staging buffers (idle queue)
      overlap:           async dispatch (no per-stage synchronization)
      devices:           accelerators to round-robin over (default: all)
      coalesce:          fuse queued same-fuse-key jobs into one batch
                         launch — 'direct' with 'direct', 'sliding' with
                         identical window/stride, 'gear' with 'gear'
                         (stream jobs additionally only fuse within the
                         same buffer-size octave class, so a tiny CDC
                         job never pads out to a huge neighbour)
      max_batch:         max jobs fused into a single launch
      max_fused_rows:    cap on total direct rows in one fused launch —
                         bounds the padded [B, W] staging matrix when
                         many multi-row jobs (e.g. read-path verify
                         slices) queue up at once
      max_fused_bytes:   cap on one fused launch's padded staging matrix
                         (total rows x widest pow2 row, direct AND
                         stream): a burst of wide jobs stops fusing
                         before the batch matrix grows past this budget
      coalesce_window_s: extra wait for stragglers once the queue is
                         empty.  Default 0: fusion only captures jobs
                         already queued behind a running launch, so a
                         lone synchronous write never stalls waiting
                         for writers that don't exist; raise it for
                         bursty many-writer workloads.

    Priority lanes (``LANES`` order): ``lane='batch'`` queues behind
    every interactive ``fg`` job (the gateway's throughput QoS class),
    and ``lane='scrub'`` queues behind both — background integrity
    scrubbing and repair verification (repro.core.noderuntime) share
    the engine without delaying client writes/reads.  Scrub-lane
    traffic is tracked by the ``scrub_jobs`` / ``scrub_launches`` /
    ``scrub_coalesced`` counters; ``queue_depth(lane)`` exposes the
    per-lane backlog (the node runtime's load-aware scrub backoff and
    the gateway's stats read it).
    """

    def __init__(self, devices=None, buffer_reuse: bool = True,
                 overlap: bool = True, n_slots: int = 8,
                 interpret: bool = True, coalesce: bool = True,
                 max_batch: int = 64, coalesce_window_s: float = 0.0,
                 max_fused_rows: int = 4096,
                 max_fused_bytes: int = 64 << 20):
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.buffer_reuse = buffer_reuse
        self.overlap = overlap
        self.interpret = interpret
        self.coalesce = coalesce
        self.max_batch = max(1, int(max_batch))
        self.max_fused_rows = max(1, int(max_fused_rows))
        self.max_fused_bytes = max(1, int(max_fused_bytes))
        self.coalesce_window_s = coalesce_window_s
        self.outstanding: LaneQueue = LaneQueue()
        self.idle: "queue.Queue[dict]" = queue.Queue()
        for _ in range(n_slots):
            self.idle.put({})          # slot: staging-buffer cache by shape
        self.running: List[Job] = []
        self._lock = threading.Lock()
        self.stats = {"jobs": 0, "bytes": 0, "launches": 0,
                      "coalesced": 0, "max_fused": 0,
                      "scrub_jobs": 0, "scrub_launches": 0,
                      "scrub_coalesced": 0}
        self._managers = [
            threading.Thread(target=self._manager_loop, args=(d,),
                             daemon=True, name=f"crystal-mgr-{i}")
            for i, d in enumerate(self.devices)]
        self._alive = True
        self._shutdown_started = False
        for t in self._managers:
            t.start()

    # ------------------------------------------------------------------
    # submission API
    # ------------------------------------------------------------------
    def submit(self, kind: str, data: np.ndarray, meta=None,
               callback=None, lane: str = "fg") -> Job:
        """Submit one hashing job.  ``lane='batch'`` queues behind
        interactive ``fg`` traffic (the gateway's throughput QoS);
        ``lane='scrub'`` marks background node-runtime traffic that
        queues behind both and is tracked by the ``scrub_*`` stats
        counters.  Any lane's job fuses with any same-fuse-key job once
        a manager picks it up."""
        if not self._alive:
            raise RuntimeError("CrystalTPU engine is shut down")
        if lane not in LANES:
            raise ValueError(f"unknown lane {lane!r}")
        job = Job(kind=kind, data=np.asarray(data), meta=meta or {},
                  callback=callback, lane=lane)
        if kind == "direct":
            job.rows, job.lens = _normalize_direct(job.data, job.meta)
            job.fuse_key = ("direct",)
            n, w = job.rows.shape
            job.n_rows = n
            job.staged_width = 1 << (max(w, 4) - 1).bit_length()
        elif kind in ("sliding", "gear"):
            # stream jobs fuse only within a buffer-size octave class
            # (~8x width span): rows are padded to the batch max, so
            # fusing a 4 KB CDC job with a 64 MB one would hash ~16000x
            # padding for the small job — the class bound keeps fusion
            # for genuinely similar bursts
            octave = (max(job.data.size, 1) + 3).bit_length() // 3
            if kind == "sliding":
                job.fuse_key = ("sliding",
                                int(job.meta.get("window", 48)),
                                int(job.meta.get("stride", 4)), octave)
            else:
                job.fuse_key = ("gear", int(job.meta.get("version", 1)),
                                octave)
            n_words = (max(job.data.size, 1) + 3) // 4
            job.staged_width = 4 << (max(n_words, 4) - 1).bit_length()
        else:
            job.fuse_key = (kind, id(job))      # never fuses; error later
        self.outstanding.put(job, lane=job.lane)
        return job

    def map_stream(self, kind: str, buffers, meta=None) -> List[Job]:
        """Submit a stream of jobs back-to-back (the paper's batched
        streaming workload) and return the job list."""
        return [self.submit(kind, b, meta) for b in buffers]

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)

    def queue_depth(self, lane: Optional[str] = None) -> int:
        """Jobs queued (not yet picked up by a manager) in ``lane``, or
        in every lane when ``lane`` is None."""
        return self.outstanding.depth(lane)

    def shutdown(self):
        """Stop the managers after the queue drains.  Idempotent: only
        the first call posts shutdown sentinels and joins — repeat calls
        (interpreter-exit atexit hook racing an explicit shutdown, a
        gateway closing over an already-stopped engine) return at once
        instead of double-posting sentinels."""
        with self._lock:
            first = not self._shutdown_started
            self._shutdown_started = True
            self._alive = False
        if not first:
            return
        for _ in self._managers:
            self.outstanding.put(None)
        for t in self._managers:
            t.join(timeout=5)

    # ------------------------------------------------------------------
    # manager internals
    # ------------------------------------------------------------------
    def _get_slot(self) -> dict:
        if self.buffer_reuse:
            return self.idle.get()
        return {}

    def _put_slot(self, slot: dict):
        if self.buffer_reuse:
            self.idle.put(slot)

    def _stage_sync(self, x):
        """Baseline (no overlap): force completion at stage boundary."""
        if not self.overlap:
            jax.block_until_ready(x)
        return x

    def _staging(self, slot: dict, shape, dtype) -> np.ndarray:
        """Host staging buffer: reused from the slot cache, or a fresh
        allocation per job (the paper's unoptimized malloc-per-task)."""
        if not self.buffer_reuse:
            return np.zeros(shape, dtype)
        key = (shape, np.dtype(dtype).str)
        buf = slot.get(key)
        if buf is None:
            buf = np.zeros(shape, dtype)
            slot[key] = buf
        else:
            buf.fill(0)
        return buf

    def _drain_batch(self, first: Job):
        """Greedy coalescing: pull queued jobs with ``first``'s fuse key
        behind it (direct with direct, sliding with identical
        window/stride, gear with gear).  Returns (batch, carry) where
        carry is a non-fusable job that was popped and must be executed
        next."""
        batch = [first]
        if not (self.coalesce and first.kind in ("direct", "sliding",
                                                 "gear")):
            return batch, None
        rows, width = first.n_rows, first.staged_width
        deadline = time.perf_counter() + self.coalesce_window_s
        while len(batch) < self.max_batch:
            try:
                nxt = self.outstanding.get_nowait()
            except queue.Empty:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    break
                try:
                    nxt = self.outstanding.get(timeout=wait)
                except queue.Empty:
                    break
            if nxt is None:               # shutdown token: repost + stop
                self.outstanding.put(None)
                break
            if nxt.fuse_key != first.fuse_key:
                return batch, nxt
            # cap the fused launch by its actual padded staging matrix
            # (every row pads to the batch-max width) and, for direct,
            # by total rows — not just by job count: many multi-row or
            # wide jobs must not stack into an unbounded batch
            new_width = max(width, nxt.staged_width)
            if (rows + nxt.n_rows) * new_width > self.max_fused_bytes:
                return batch, nxt
            if nxt.kind == "direct" and \
                    rows + nxt.n_rows > self.max_fused_rows:
                return batch, nxt
            rows += nxt.n_rows
            width = new_width
            batch.append(nxt)
        return batch, None

    def _manager_loop(self, device):
        # terminates only on its shutdown token, never on the _alive
        # flag: a carried (popped-but-unfused) job must still execute
        # even if shutdown() lands while the previous batch runs
        carry: Optional[Job] = None
        while True:
            if carry is not None:
                job, carry = carry, None
            else:
                job = self.outstanding.get()
                if job is None:
                    return
            batch, carry = self._drain_batch(job)
            slot = self._get_slot()
            try:
                with self._lock:
                    self.running.extend(batch)
                if job.kind == "direct":
                    self._execute_direct(device, slot, batch)
                else:
                    self._execute_stream_batch(device, slot, batch)
            except BaseException as e:          # surfaced via wait()
                for j in batch:
                    j.error = e
            finally:
                with self._lock:
                    for j in batch:
                        if j in self.running:
                            self.running.remove(j)
                self._put_slot(slot)
                for j in batch:
                    j.done.set()
                    if j.callback is not None:
                        try:
                            j.callback(j)
                        except Exception:
                            pass

    def _account(self, n_jobs: int, nbytes: int, n_scrub: int = 0):
        with self._lock:
            self.stats["jobs"] += n_jobs
            self.stats["bytes"] += nbytes
            self.stats["launches"] += 1
            self.stats["coalesced"] += n_jobs - 1
            self.stats["max_fused"] = max(self.stats["max_fused"], n_jobs)
            if n_scrub:
                # a launch containing any scrub job counts once, so
                # scrub_launches < scrub_jobs is the fused-scrub signature
                self.stats["scrub_jobs"] += n_scrub
                self.stats["scrub_launches"] += 1
                self.stats["scrub_coalesced"] += n_scrub - 1

    # -- fused direct batch --------------------------------------------
    def _execute_direct(self, device, slot: dict, batch: List[Job]):
        t0 = time.perf_counter()
        # stage 1-2: staging + transfer in.  One padded [B, W] batch for
        # the whole burst; rows are length-bound so zero padding to the
        # widest row never changes a digest.  B and W are bucketed to
        # powers of two to bound jit retraces across ragged bursts.
        W = max(j.rows.shape[1] for j in batch)
        W = 1 << (max(W, 4) - 1).bit_length()
        n_rows = sum(j.rows.shape[0] for j in batch)
        B = 1 << (max(n_rows, 1) - 1).bit_length()
        staging = self._staging(slot, (B, W), np.uint8)
        lens = np.zeros((B,), np.int64)
        r = 0
        for j in batch:
            n, w = j.rows.shape
            staging[r:r + n, :w] = j.rows
            lens[r:r + n] = j.lens
            r += n
        words = staging.view("<u4") if staging.flags.c_contiguous \
            else np.ascontiguousarray(staging).view("<u4")
        dev_words = jax.device_put(words, device)
        dev_lens = jax.device_put((lens // 4).astype(np.int32), device)
        self._stage_sync(dev_words)
        t1 = time.perf_counter()
        # stage 3: ONE kernel launch for the fused batch, device-resident
        dig = ops.direct_hash_device(dev_words, dev_lens,
                                     interpret=self.interpret)
        self._stage_sync(dig)
        t2 = time.perf_counter()
        # stage 4: transfer out (digests only — 16 B per row)
        host = ops.digest_bytes(dig)
        t3 = time.perf_counter()
        timings = {"in": t1 - t0, "kernel": t2 - t1, "out": t3 - t2}
        r = 0
        for j in batch:
            n = j.rows.shape[0]
            j.result = host[r:r + n].copy()
            j.timings = dict(timings)       # batch-wide stage times
            r += n
        self._account(len(batch), int(np.sum(lens)),
                      sum(j.lane == "scrub" for j in batch))

    # -- fused streaming batch (sliding / gear) ------------------------
    def _execute_stream_batch(self, device, slot: dict, batch: List[Job]):
        """Execute a burst of same-config stream jobs as ONE padded
        [B, L] multi-row kernel launch.  Rows are zero-padded to the
        widest buffer; B and the word width are bucketed to powers of
        two to bound retraces across ragged bursts.  Each job's hashes
        are sliced out of the fused phase-matrix output."""
        kind = batch[0].kind
        if kind not in ("sliding", "gear"):
            raise ValueError(f"unknown job kind {kind!r}")
        t0 = time.perf_counter()
        flats = [j.data.reshape(-1).astype(np.uint8, copy=False)
                 for j in batch]
        lens = [f.size for f in flats]
        n_words = (max(max(lens), 1) + 3) // 4
        Wb = 1 << (max(n_words, 4) - 1).bit_length()
        B = 1 << (len(batch) - 1).bit_length()
        staging = self._staging(slot, (B, Wb), np.uint32)
        rows_u8 = staging.view(np.uint8).reshape(B, Wb * 4)
        for i, f in enumerate(flats):
            rows_u8[i, :f.size] = f
        dev_words = jax.device_put(staging, device)
        self._stage_sync(dev_words)
        t1 = time.perf_counter()
        if kind == "sliding":
            window = int(batch[0].meta.get("window", 48))
            stride = int(batch[0].meta.get("stride", 4))
            phases = tuple(range(0, 4, stride))
            out = ops.sliding_hash_batch_device(dev_words, window // 4,
                                                phases,
                                                interpret=self.interpret)
            self._stage_sync(out)
            t2 = time.perf_counter()
            host = np.asarray(out)                       # [B, R, Wc]
            for i, j in enumerate(batch):
                n_off = (lens[i] - window) // stride + 1
                j.result = ops.sliding_finish(host[i], phases, n_off)
        else:
            out = ops.gear_hash_batch_device(
                dev_words, interpret=self.interpret,
                version=int(batch[0].meta.get("version", 1)))
            self._stage_sync(out)
            t2 = time.perf_counter()
            host = np.asarray(out)                       # [B, 4, Wc]
            for i, j in enumerate(batch):
                j.result = ops.gear_finish(host[i], lens[i])
        t3 = time.perf_counter()
        timings = {"in": t1 - t0, "kernel": t2 - t1, "out": t3 - t2}
        for j in batch:
            j.timings = dict(timings)       # batch-wide stage times
        self._account(len(batch), int(sum(lens)),
                      sum(j.lane == "scrub" for j in batch))


# ----------------------------------------------------------------------
# process-wide default engine: shared across SAIs so concurrent writers'
# requests coalesce into common launches
# ----------------------------------------------------------------------
_DEFAULT: Optional[CrystalTPU] = None
_DEFAULT_LOCK = threading.Lock()
_ATEXIT_REGISTERED = False


def _shutdown_default_engine():
    """atexit hook: interpreter exit must never race live manager
    threads (daemon threads dying mid-launch while jax tears down)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        eng, _DEFAULT = _DEFAULT, None
    if eng is not None:
        eng.shutdown()                 # idempotent: explicit shutdowns ok


def default_engine() -> CrystalTPU:
    """The process-wide shared offload engine (created on first use,
    recreated if a previous default was shut down).  The first creation
    registers an ``atexit`` shutdown hook so engines left running at
    interpreter exit are drained and joined cleanly."""
    global _DEFAULT, _ATEXIT_REGISTERED
    with _DEFAULT_LOCK:
        if _DEFAULT is None or not _DEFAULT._alive:
            if not _ATEXIT_REGISTERED:
                atexit.register(_shutdown_default_engine)
                _ATEXIT_REGISTERED = True
            _DEFAULT = CrystalTPU()
        return _DEFAULT
