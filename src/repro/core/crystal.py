"""CrystalTPU — the generalized offload engine (CrystalGPU analog).

The paper's CrystalGPU layer sits between the storage system and the GPU
runtime and provides application-agnostic optimizations that make hashing
offload pay off:
  (1) buffer reuse   — amortize (pinned) staging-buffer allocation across
                       a stream of hashing jobs,
  (2) transfer/compute overlap — pipeline H2D copy of job i+1 with the
                       kernel of job i,
  (3) transparent multi-device — an *engine mesh*: every device owns a
                       manager thread and a private lane queue, and jobs
                       are placed by a load-aware dispatch score instead
                       of blind round-robin,
  (4) request coalescing — fuse many small outstanding hash requests
                       (concurrent writers, checkpoint leaves, read-path
                       verification) into ONE padded batch kernel launch,
                       so per-launch overhead is amortized over the whole
                       burst.  This covers every job kind: ``direct``
                       rows stack into one [B, W] batch, and bursts of
                       same-config ``sliding`` / ``gear`` stream jobs
                       stack into one padded [B, L] multi-row launch via
                       the ``ops.*_batch_device`` entry points.

Engine mesh (this module's multi-device structure):

  dispatch   — each submitted job carries a cost estimate from the
               :class:`KernelCostModel` (seconds ~ overhead +
               sec_per_byte * padded_bytes, seeded from the roofline
               hash-kernel constants in ``repro.roofline.analysis`` and
               EWMA-regressed online from measured launch wall times).
               The dispatcher scores every device by
               ``pending_s * slowdown`` — its queued model-seconds
               backlog times an EWMA of observed-vs-estimated launch
               latency — and routes to the cheapest device, with a
               fuse-key affinity exception: a job whose fuse key matches
               a device's most recent submission lands there when that
               device's backlog is within one job-cost of the best, so
               coalescable bursts stay fused instead of spraying across
               the mesh.  Ties break round-robin.
  sharding   — a whale job (padded staging footprint >=
               ``shard_min_bytes`` with >= 2 devices) is split into
               per-device sub-launches via the pure planning helpers in
               ``ops`` (``shard_row_ranges`` for direct row ranges,
               ``stream_shard_plan`` for stride-aligned sliding slices
               and 32-byte-overlap gear slices) and the child digests
               are reassembled in submission order into the parent
               job's result — one whale checkpoint leaf no longer
               serializes on a single manager while other devices idle.
               Counted by ``sharded_jobs`` / ``shards``.
  adaptive   — with ``adaptive_fusion=True`` the :class:`FusionPolicy`
    fusion     retunes ``max_fused_rows`` / ``max_fused_bytes`` from
               the measured cost model (grow the fused batch until
               launch overhead is ~25% of the launch, shrink it when
               the latency target ``target_launch_s`` binds) and widens
               or narrows the stream octave-class span when launches
               are overhead-dominated or padding-wasteful.  The
               constructor caps act as the starting point; adapted
               values stay within a bounded window around them and are
               exposed via ``snapshot_stats()["policy"]``.
  resilience — a manager thread that dies on an unexpected exception no
               longer strands its queue: the in-flight (picked) jobs'
               futures fail with the exception, the still-queued jobs
               are re-dispatched to surviving devices, the manager loop
               restarts, and ``manager_restarts`` counts the event.

``snapshot_stats()`` exposes the flat engine counters plus
``per_device`` (jobs, launches, bytes, EWMA launch latency overall and
per ``(kind, width-bucket)``, queue depth, queued padded bytes, pending
model-seconds, slowdown, restarts), ``policy`` (current caps + octave
span), and ``sharded_jobs`` / ``shards`` / ``manager_restarts``.
``queue_depth(lane, device=...)`` reads one device's backlog;
without ``device`` it sums the mesh (the node runtime's scrub backoff
and the gateway read it).

Data stays device-resident from ``device_put`` through the kernel: hosts
prepare word-packed staging buffers, the device buffer is handed straight
to the jit'd kernel entry points (``ops.*_device``), and only the (small)
digest/fingerprint output is pulled back to the host.

TPU/JAX adaptation: JAX's runtime is asynchronous by design, so overlap is
expressed by *not* synchronizing between stage boundaries (async dispatch
pipelines transfer and compute), while the no-overlap baseline inserts
``block_until_ready`` after every stage — mirroring the paper's staged
Table-1 execution.

Job normal forms
----------------
  'direct'  : data = [n, w] uint8 rows (w % 4 == 0) and meta['lens'] =
              [n] byte lengths (multiples of 4, <= w); result [n, 16]
              uint8 digests.  Legacy form: data = flat uint8 buffer plus
              meta['seg_bytes'] — split into fixed segments, word-aligned
              tail.  Coalescing fuses any mix of direct jobs: rows are
              zero-padded to the widest row in the batch (digests are
              length-bound, so trailing zeros never change them).
  'sliding' : data = flat uint8 buffer, meta {'window', 'stride'};
              result [n_offsets] uint32 window hashes.
  'gear'    : data = flat uint8 buffer; result [len] uint32 rolling hash.
"""
from __future__ import annotations

import atexit
import collections
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.kernels import ops
from repro.obs import HeartbeatBoard
from repro.obs import metrics as metrics_mod


LANES = ("fg", "batch", "scrub")       # dequeue priority, highest first


class LaneQueue:
    """Priority job queue: lanes dequeue strictly in ``LANES`` order —
    interactive foreground traffic first, then ``batch`` (throughput
    tenants behind the storage gateway), then ``scrub`` (background
    scrub/repair traffic from the node runtime) — and shutdown sentinels
    (``None``) dequeue only once every lane is empty, so ``shutdown()``
    still drains queued background jobs instead of orphaning their
    waiters.  API mirrors the subset of ``queue.Queue`` the managers use
    (put/get/get_nowait)."""

    def __init__(self):
        self._cv = threading.Condition()
        # guarded by self._cv
        self._lanes: Dict[str, collections.deque] = \
            {lane: collections.deque() for lane in LANES}
        self._sentinels = 0  # guarded by self._cv

    def put(self, item, lane: str = "fg"):
        with self._cv:
            if item is None:
                self._sentinels += 1
            else:
                self._lanes[lane].append(item)
            self._cv.notify()

    def _pop_locked(self):
        for lane in LANES:
            if self._lanes[lane]:
                return self._lanes[lane].popleft()
        self._sentinels -= 1            # caller checked _sentinels > 0
        return None

    def _nonempty(self) -> bool:  # ra: holds self._cv
        return bool(self._sentinels
                    or any(self._lanes[lane] for lane in LANES))

    def get(self, timeout: Optional[float] = None):
        with self._cv:
            if not self._cv.wait_for(self._nonempty, timeout):
                raise queue.Empty
            return self._pop_locked()

    def get_nowait(self):
        with self._cv:
            if not self._nonempty():
                raise queue.Empty
            return self._pop_locked()

    def depth(self, lane: Optional[str] = None) -> int:
        """Queued jobs in one lane (or all lanes) — the load signal the
        node runtime's scrub backoff and the gateway stats read."""
        with self._cv:
            if lane is None:
                return sum(len(q) for q in self._lanes.values())
            return len(self._lanes[lane])

    def qsize(self) -> int:
        return self.depth()


@dataclass(eq=False)                   # identity semantics: jobs hold
class Job:                             # numpy fields, and the manager's
    # running-list membership/removal must never compare array contents
    kind: str                          # 'direct' | 'sliding' | 'gear'
    data: Optional[np.ndarray] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    callback: Optional[Callable] = None
    result: Any = None
    error: Optional[BaseException] = None
    done: threading.Event = field(default_factory=threading.Event)
    timings: Dict[str, float] = field(default_factory=dict)
    # normalized 'direct' payload (set at submit time)
    rows: Optional[np.ndarray] = None
    lens: Optional[np.ndarray] = None
    # jobs with equal fuse keys may share one kernel launch
    fuse_key: tuple = ()
    # 'fg' = interactive client traffic; 'batch' = throughput traffic
    # (gateway batch-QoS tenants) that yields to interactive jobs;
    # 'scrub' = lowest-priority background traffic (node-runtime
    # scrub/repair) tracked by the scrub_* stats counters
    lane: str = "fg"
    # pow2-padded staging shape, used to bound fused-batch memory:
    # the fused matrix is (sum n_rows) x (max staged_width) bytes
    n_rows: int = 1
    staged_width: int = 0
    # cost-model estimate charged to the dispatch target's backlog
    # clock at submit and credited back when the launch retires
    cost_est: float = 0.0
    device_index: int = -1
    # trace stamps (perf_counter): dispatch enqueue, batch launch
    # start/end — consumers (SAI) turn these into engine queue/launch
    # spans after wait()
    t_submit: float = 0.0
    t_exec0: float = 0.0
    t_exec1: float = 0.0

    def wait(self):
        self.done.wait()
        if self.error is not None:
            raise self.error
        return self.result

    @property
    def padded_bytes(self) -> int:
        return self.n_rows * max(self.staged_width, 1)


def _normalize_direct(data: np.ndarray, meta: Dict[str, Any]):
    """Return (rows [n, w] uint8, lens [n] int64) for a direct request."""
    data = np.asarray(data)
    if data.ndim == 2:
        rows = data.astype(np.uint8, copy=False)
        lens = meta.get("lens")
        if lens is None:
            lens = np.full((rows.shape[0],), rows.shape[1], np.int64)
        else:
            lens = np.asarray(lens, np.int64)
        return rows, lens
    seg = int(meta.get("seg_bytes", 4096))
    flat = data.reshape(-1).astype(np.uint8, copy=False)
    n = max((flat.size + seg - 1) // seg, 1)
    rows = np.zeros((n, seg), np.uint8)
    rows.reshape(-1)[:flat.size] = flat
    lens = np.full((n,), seg, np.int64)
    tail = flat.size - (n - 1) * seg
    lens[-1] = (tail + 3) // 4 * 4
    return rows, lens


def _cost_seeds() -> Dict[str, Tuple[float, float]]:
    """kind -> (sec_per_byte, launch_overhead_s) seeds for the cost
    model, derived from the roofline hash-kernel op counts; a static
    fallback keeps the engine importable if the roofline package is
    unavailable (stripped deployments)."""
    try:
        from repro.roofline.analysis import HASH_OPS_PER_BYTE, \
            hash_cost_seed
        out = {}
        for kind in HASH_OPS_PER_BYTE:
            s = hash_cost_seed(kind)
            out[kind] = (s["sec_per_byte"], s["launch_overhead_s"])
        return out
    except Exception:
        return {k: (5e-8, 2e-3) for k in ("direct", "sliding", "gear")}


class KernelCostModel:
    """Online launch-cost model: ``wall ~= overhead + sec_per_byte *
    padded_bytes`` per job kind.  Parameters come from an EWMA linear
    regression of measured launch wall time on padded staging bytes,
    seeded from the roofline kernel-cost constants so the very first
    dispatch decisions are already scale-aware.  When the observed byte
    sizes are degenerate (every launch the same size) the slope falls
    back to the seed and only the intercept is measured."""

    def __init__(self, seeds: Optional[Dict[str, Tuple[float, float]]]
                 = None, alpha: float = 0.2):
        self.alpha = alpha
        self._seed = dict(seeds or {})
        # kind -> [n, E[b], E[w], E[b^2], E[b*w]]  (EWMA moments)
        self._m: Dict[str, List[float]] = {}

    def observe(self, kind: str, nbytes: int, wall_s: float):
        b, w = float(nbytes), float(wall_s)
        m = self._m.get(kind)
        if m is None:
            self._m[kind] = [1, b, w, b * b, b * w]
            return
        a = self.alpha
        m[0] += 1
        m[1] += a * (b - m[1])
        m[2] += a * (w - m[2])
        m[3] += a * (b * b - m[3])
        m[4] += a * (b * w - m[4])

    def params(self, kind: str) -> Tuple[float, float]:
        """(overhead_s, sec_per_byte) for ``kind``."""
        seed_spb, seed_oh = self._seed.get(kind, (5e-8, 2e-3))
        m = self._m.get(kind)
        if m is None or m[0] < 2:
            return seed_oh, seed_spb
        var = m[3] - m[1] * m[1]
        cov = m[4] - m[1] * m[2]
        if var <= max(1e-6 * m[3], 1e-9):
            spb = seed_spb                  # degenerate byte variance
        else:
            spb = cov / var
        spb = min(max(spb, 1e-13), 1.0)
        oh = max(m[2] - spb * m[1], 0.0)
        return oh, spb

    def estimate(self, kind: str, nbytes: int) -> float:
        oh, spb = self.params(kind)
        return oh + spb * float(nbytes)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for kind in set(self._seed) | set(self._m):
            oh, spb = self.params(kind)
            out[kind] = {"overhead_s": oh, "sec_per_byte": spb,
                         "observations": self._m.get(kind, [0])[0]}
        return out


class FusionPolicy:
    """Fusion caps + stream octave classes, optionally retuned online.

    Static mode (``adaptive=False``, the default): ``cur_rows`` /
    ``cur_bytes`` stay at the constructor values — existing engines
    behave exactly as before.  Adaptive mode grows the fused-batch byte
    budget until launch overhead is ~25% of the modeled launch time
    (``B_opt = 3 * overhead / sec_per_byte``), shrinks it when the
    ``target_launch_s`` latency bound binds, rounds to a power of two
    with 2x hysteresis, and keeps the result inside a bounded window
    around the configured caps (so explicit small caps remain
    meaningful bounds).  The row cap follows from the byte budget and
    the EWMA padded-bytes-per-row of recent launches.

    The stream octave class is the true power-of-two octave
    ``size.bit_length() // octave_span`` (span 1 = one class per
    power of two); adaptive mode widens the span (fuse across more
    size octaves) when launches are overhead-dominated and padding is
    cheap, and narrows it when padding waste dominates."""

    def __init__(self, max_fused_rows: int, max_fused_bytes: int,
                 adaptive: bool = False, target_launch_s: float = 0.25,
                 octave_span: int = 1):
        self.adaptive = bool(adaptive)
        self.target_launch_s = float(target_launch_s)
        self.base_rows = max(1, int(max_fused_rows))
        self.base_bytes = max(1, int(max_fused_bytes))
        self.cur_rows = self.base_rows
        self.cur_bytes = self.base_bytes
        self.rows_floor = max(1, self.base_rows // 8)
        self.rows_ceil = self.base_rows * 8
        self.bytes_floor = max(4096, self.base_bytes // 64)
        self.bytes_ceil = self.base_bytes * 8
        self.octave_span = max(1, min(int(octave_span), 3))
        self._pad_ratio = 1.0
        self._row_bytes = 0.0
        self._wall = 0.0
        self._obs = 0

    def octave_class(self, size: int) -> int:
        return max(int(size), 1).bit_length() // self.octave_span

    def observe(self, padded: int, actual: int, n_rows: int,
                wall_s: float, overhead_s: float, sec_per_byte: float):
        """Feed one retired launch (caller holds the engine lock)."""
        a = 0.25
        self._pad_ratio += a * (padded / max(actual, 1) - self._pad_ratio)
        if n_rows:
            rb = padded / n_rows
            self._row_bytes = rb if not self._row_bytes \
                else self._row_bytes + a * (rb - self._row_bytes)
        self._wall = wall_s if not self._wall \
            else self._wall + a * (wall_s - self._wall)
        self._obs += 1
        if not self.adaptive:
            return
        spb = max(sec_per_byte, 1e-13)
        oh = max(overhead_s, 0.0)
        want = 3.0 * oh / spb            # overhead down to ~25%/launch
        if self.target_launch_s > oh:
            want = min(want, (self.target_launch_s - oh) / spb)
        want = min(max(want, self.bytes_floor), self.bytes_ceil)
        want = 1 << (max(int(want), 1) - 1).bit_length()
        want = min(want, self.bytes_ceil)
        if want >= 2 * self.cur_bytes or 2 * want <= self.cur_bytes:
            self.cur_bytes = want        # 2x hysteresis
        rb = max(self._row_bytes, 64.0)
        n = min(max(int(self.cur_bytes / rb), self.rows_floor),
                self.rows_ceil)
        self.cur_rows = min(1 << (max(n, 1) - 1).bit_length(),
                            self.rows_ceil)
        if self._obs % 16 == 0:
            body = spb * max(self.cur_bytes, 1)
            if oh > body and self._pad_ratio < 4.0:
                self.octave_span = min(self.octave_span + 1, 3)
            elif self._pad_ratio > 6.0 and self.octave_span > 1:
                self.octave_span -= 1

    def snapshot(self) -> Dict[str, float]:
        return {"adaptive": int(self.adaptive),
                "max_fused_rows": self.cur_rows,
                "max_fused_bytes": self.cur_bytes,
                "octave_span": self.octave_span,
                "pad_ratio": self._pad_ratio,
                "ewma_launch_s": self._wall}


class _DeviceState:
    """Per-device mesh state: a private lane queue, the backlog signals
    the dispatcher scores (queued padded bytes + pending model-seconds +
    EWMA observed/estimated slowdown), the picked list crash recovery
    fails over, and per-(kind, width-bucket) launch-latency EWMAs.
    Mutable fields are guarded by the engine lock; the queue has its own
    condition variable (never acquired while holding the engine lock in
    a blocking wait)."""

    __slots__ = ("index", "device", "queue", "queued_bytes", "pending_s",
                 "slowdown", "last_fuse_key", "picked", "ewma_launch_s",
                 "ewma_bucket_s", "jobs", "launches", "bytes", "restarts",
                 "launch_hist")

    def __init__(self, index: int, device, launch_hist=None):
        self.index = index
        self.device = device
        self.queue = LaneQueue()
        self.queued_bytes = 0
        self.pending_s = 0.0
        self.slowdown = 1.0
        self.last_fuse_key: Optional[tuple] = None
        self.picked: List[Job] = []
        self.ewma_launch_s = 0.0
        self.ewma_bucket_s: Dict[tuple, float] = {}
        self.jobs = 0
        self.launches = 0
        self.bytes = 0
        self.restarts = 0
        # full launch-latency distribution (p50/p95/p99), not just the
        # EWMA mean the dispatcher scores with
        self.launch_hist = launch_hist if launch_hist is not None \
            else metrics_mod.Histogram(f"device{index}/launch_s")

    def load_score(self) -> float:
        return self.pending_s * self.slowdown

    def stats_row(self) -> Dict[str, Any]:
        return {"jobs": self.jobs, "launches": self.launches,
                "bytes": self.bytes,
                "ewma_launch_s": self.ewma_launch_s,
                "ewma_bucket_s": {f"{k}/{w}": v for (k, w), v
                                  in self.ewma_bucket_s.items()},
                "launch_hist": self.launch_hist.summary(),
                "queue_depth": self.queue.depth(),
                "queued_bytes": self.queued_bytes,
                "pending_s": self.pending_s,
                "slowdown": self.slowdown,
                "manager_restarts": self.restarts}


class CrystalTPU:
    """Coalescing offload engine mesh for hashing jobs.

    Parameters mirror the paper's ablation switches plus coalescing:
      buffer_reuse:      keep and reuse staging buffers (idle queue)
      overlap:           async dispatch (no per-stage synchronization)
      devices:           accelerators forming the mesh (default: all);
                         each gets its own manager thread + lane queue
                         and jobs are placed by the load-aware dispatch
                         score (see module docstring)
      coalesce:          fuse queued same-fuse-key jobs into one batch
                         launch — 'direct' with 'direct', 'sliding' with
                         identical window/stride, 'gear' with 'gear'
                         (stream jobs additionally only fuse within the
                         same buffer-size octave class, so a tiny CDC
                         job never pads out to a huge neighbour)
      max_batch:         max jobs fused into a single launch
      max_fused_rows:    cap on total direct rows in one fused launch —
                         bounds the padded [B, W] staging matrix when
                         many multi-row jobs (e.g. read-path verify
                         slices) queue up at once
      max_fused_bytes:   cap on one fused launch's padded staging matrix
                         (total rows x widest pow2 row, direct AND
                         stream): a burst of wide jobs stops fusing
                         before the batch matrix grows past this budget
      coalesce_window_s: extra wait for stragglers once the queue is
                         empty.  Default 0: fusion only captures jobs
                         already queued behind a running launch, so a
                         lone synchronous write never stalls waiting
                         for writers that don't exist; raise it for
                         bursty many-writer workloads.
      adaptive_fusion:   let the measured cost model retune the fusion
                         caps and octave span at runtime (FusionPolicy);
                         off by default — static engines behave exactly
                         as before
      target_launch_s:   adaptive-fusion latency bound: stop growing the
                         fused batch once its modeled launch time would
                         exceed this
      shard_min_bytes:   padded staging footprint above which a single
                         job is sharded across the mesh (>= 2 devices);
                         per-device sub-launches reassemble into the
                         parent result in submission order

    Priority lanes (``LANES`` order): ``lane='batch'`` queues behind
    every interactive ``fg`` job (the gateway's throughput QoS class),
    and ``lane='scrub'`` queues behind both — background integrity
    scrubbing and repair verification (repro.core.noderuntime) share
    the engine without delaying client writes/reads.  Scrub-lane
    traffic is tracked by the ``scrub_jobs`` / ``scrub_launches`` /
    ``scrub_coalesced`` counters; ``queue_depth(lane)`` exposes the
    per-lane backlog (the node runtime's load-aware scrub backoff and
    the gateway's stats read it), summed across the mesh unless a
    ``device`` index is given.
    """

    def __init__(self, devices=None, buffer_reuse: bool = True,
                 overlap: bool = True, n_slots: int = 8,
                 interpret: bool = True, coalesce: bool = True,
                 max_batch: int = 64, coalesce_window_s: float = 0.0,
                 max_fused_rows: int = 4096,
                 max_fused_bytes: int = 64 << 20,
                 adaptive_fusion: bool = False,
                 target_launch_s: float = 0.25,
                 shard_min_bytes: int = 8 << 20):
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.buffer_reuse = buffer_reuse
        self.overlap = overlap
        self.interpret = interpret
        self.coalesce = coalesce
        self.max_batch = max(1, int(max_batch))
        self.coalesce_window_s = coalesce_window_s
        self.shard_min_bytes = max(1, int(shard_min_bytes))
        self.policy = FusionPolicy(max_fused_rows, max_fused_bytes,
                                   adaptive=adaptive_fusion,
                                   target_launch_s=target_launch_s)
        self.cost = KernelCostModel(_cost_seeds())
        # jobs submitted while the mesh has no devices park here (their
        # depth still shows in queue_depth); nothing drains them — same
        # semantics as the former shared queue with zero managers
        self.outstanding: LaneQueue = LaneQueue()
        self.idle: "queue.Queue[dict]" = queue.Queue()
        for _ in range(n_slots):
            self.idle.put({})          # slot: staging-buffer cache by shape
        self.running: List[Job] = []  # guarded by self._lock
        self._lock = threading.Lock()
        self._rr = 0  # guarded by self._lock
        self.metrics = metrics_mod.MetricsRegistry()
        # atomic counters: manager threads and submitters bump these
        # concurrently; reads keep the old plain-dict shape
        self.stats = self.metrics.group(
            ("jobs", "bytes", "launches", "coalesced", "max_fused",
             "scrub_jobs", "scrub_launches", "scrub_coalesced",
             "sharded_jobs", "shards", "manager_restarts"))
        # test hooks: _fault_hook(dev_index, batch) runs after a batch is
        # drained but OUTSIDE the launch try (an exception there kills
        # the manager thread -> crash-recovery path); _launch_hook runs
        # INSIDE it (injected latency counts as measured launch wall,
        # an exception fails only that batch)
        self._fault_hook: Optional[Callable] = None
        self._launch_hook: Optional[Callable] = None
        self._dev_states = [
            _DeviceState(i, d,
                         self.metrics.histogram(f"device{i}/launch_s"))
            for i, d in enumerate(self.devices)]
        # per-manager liveness: beats per loop iteration, parks while
        # blocked on an empty lane queue (idle mesh reads healthy)
        self.heartbeats = HeartbeatBoard()
        self._managers = [
            threading.Thread(target=self._manager_main, args=(s,),
                             daemon=True, name=f"crystal-mgr-{s.index}")
            for s in self._dev_states]
        self._alive = True
        self._shutdown_started = False  # guarded by self._lock
        for t in self._managers:
            t.start()

    # backward-compatible views of the (possibly adapted) fusion caps
    @property
    def max_fused_rows(self) -> int:
        return self.policy.cur_rows

    @property
    def max_fused_bytes(self) -> int:
        return self.policy.cur_bytes

    # ------------------------------------------------------------------
    # submission API
    # ------------------------------------------------------------------
    def submit(self, kind: str, data: np.ndarray, meta=None,
               callback=None, lane: str = "fg") -> Job:
        """Submit one hashing job.  ``lane='batch'`` queues behind
        interactive ``fg`` traffic (the gateway's throughput QoS);
        ``lane='scrub'`` marks background node-runtime traffic that
        queues behind both and is tracked by the ``scrub_*`` stats
        counters.  Any lane's job fuses with any same-fuse-key job once
        a manager picks it up.  Jobs whose padded staging footprint
        reaches ``shard_min_bytes`` on a >= 2 device mesh are sharded
        into per-device sub-launches (child jobs appear in the stats;
        the returned parent resolves when all shards do)."""
        if not self._alive:
            raise RuntimeError("CrystalTPU engine is shut down")
        if lane not in LANES:
            raise ValueError(f"unknown lane {lane!r}")
        job = self._make_job(kind, np.asarray(data), meta or {},
                             callback, lane)
        plan = self._shard_plan(job)
        if plan is not None:
            return self._submit_sharded(job, plan)
        self._dispatch(job)
        return job

    def map_stream(self, kind: str, buffers, meta=None) -> List[Job]:
        """Submit a stream of jobs back-to-back (the paper's batched
        streaming workload) and return the job list."""
        return [self.submit(kind, b, meta) for b in buffers]

    def _make_job(self, kind: str, data: np.ndarray, meta: Dict[str, Any],
                  callback, lane: str, rows: Optional[np.ndarray] = None,
                  lens: Optional[np.ndarray] = None) -> Job:
        job = Job(kind=kind, data=data, meta=meta, callback=callback,
                  lane=lane)
        if kind == "direct":
            if rows is None:
                rows, lens = _normalize_direct(job.data, job.meta)
            job.rows, job.lens = rows, lens
            job.fuse_key = ("direct",)
            n, w = rows.shape
            job.n_rows = n
            job.staged_width = 1 << (max(w, 4) - 1).bit_length()
        elif kind in ("sliding", "gear"):
            # stream jobs fuse only within a buffer-size octave class:
            # rows pad to the batch max, so fusing a 4 KB CDC job with a
            # 64 MB one would hash ~16000x padding for the small job —
            # the class bound keeps fusion for genuinely similar bursts
            octave = self.policy.octave_class(job.data.size)
            if kind == "sliding":
                job.fuse_key = ("sliding",
                                int(job.meta.get("window", 48)),
                                int(job.meta.get("stride", 4)), octave)
            else:
                job.fuse_key = ("gear", int(job.meta.get("version", 1)),
                                octave)
            n_words = (max(job.data.size, 1) + 3) // 4
            job.staged_width = 4 << (max(n_words, 4) - 1).bit_length()
        else:
            job.fuse_key = (kind, id(job))      # never fuses; error later
        return job

    # ------------------------------------------------------------------
    # load-aware dispatch
    # ------------------------------------------------------------------
    def _dispatch(self, job: Job, exclude: Optional[int] = None,
                  spread: bool = False) -> Job:
        """Place one job on the mesh: cheapest device by
        ``pending_s * slowdown``, with fuse-key affinity (a coalescable
        job follows its burst while the affine device's backlog stays
        within one job-cost of the best) and round-robin tie-breaking.
        ``exclude`` skips a device being crash-recovered; ``spread``
        disables the affinity pull (shard children were split to run on
        *different* devices — affinity would fuse them right back)."""
        with self._lock:
            job.cost_est = self.cost.estimate(job.kind, job.padded_bytes)
            states = self._dev_states
            cands = [s for s in states if s.index != exclude] or states
            if not cands:
                self.outstanding.put(job, lane=job.lane)
                return job
            self._rr = (self._rr + 1) % len(cands)
            rr = self._rr
            best = min(cands, key=lambda s: (s.load_score(),
                                             (s.index - rr) % len(cands)))
            tgt = best
            if self.coalesce and job.fuse_key and not spread:
                for s in cands:
                    if (s.last_fuse_key == job.fuse_key
                            and s.load_score() <= best.load_score()
                            + job.cost_est * max(s.slowdown, 1.0)):
                        tgt = s
                        break
            tgt.pending_s += job.cost_est
            tgt.queued_bytes += job.padded_bytes
            tgt.last_fuse_key = job.fuse_key
            job.device_index = tgt.index
            q = tgt.queue
        job.t_submit = time.perf_counter()
        q.put(job, lane=job.lane)
        return job

    # ------------------------------------------------------------------
    # whale-job sharding
    # ------------------------------------------------------------------
    def _shard_plan(self, job: Job):
        """Per-device sub-launch plan for a whale job, or None."""
        if len(self._dev_states) < 2:
            return None
        padded = job.padded_bytes
        if padded < self.shard_min_bytes:
            return None
        n_dev = len(self._dev_states)
        k = min(n_dev, max(2, padded // max(self.shard_min_bytes // 2, 1)))
        if job.kind == "direct":
            if job.n_rows < 2:
                return None
            k = min(k, job.n_rows)
            return [("rows", a, b, 0)
                    for a, b in ops.shard_row_ranges(job.n_rows, k)]
        if job.kind in ("sliding", "gear"):
            plan = ops.stream_shard_plan(
                int(job.data.size), job.kind, k,
                window=int(job.meta.get("window", 48)),
                stride=int(job.meta.get("stride", 4)))
            if plan is None:
                return None
            return [("span", a, b, drop) for a, b, drop in plan]
        return None

    def _submit_sharded(self, parent: Job, plan) -> Job:
        """Split ``parent`` into child sub-launches, one per plan entry;
        the last-finishing child's callback assembles the digests back
        into the parent's result in submission order."""
        k = len(plan)
        results: List[Optional[Job]] = [None] * k
        drops = [spec[3] for spec in plan]
        state_lock = threading.Lock()
        remaining = [k]

        def child_cb(i):
            def cb(child):
                with state_lock:
                    results[i] = child
                    remaining[0] -= 1
                    last = remaining[0] == 0
                if last:
                    self._assemble_shards(parent, results, drops)
            return cb

        flat = None if parent.kind == "direct" \
            else parent.data.reshape(-1)
        children = []
        for i, spec in enumerate(plan):
            _, a, b, _ = spec
            if parent.kind == "direct":
                child = self._make_job(
                    "direct", parent.rows[a:b], dict(parent.meta),
                    child_cb(i), parent.lane,
                    rows=parent.rows[a:b], lens=parent.lens[a:b])
            else:
                child = self._make_job(parent.kind, flat[a:b],
                                       dict(parent.meta), child_cb(i),
                                       parent.lane)
            children.append(child)
        self.stats.inc("sharded_jobs")
        self.stats.inc("shards", k)
        for child in children:
            self._dispatch(child, spread=True)
        return parent

    def _assemble_shards(self, parent: Job, results: List[Job], drops):
        err = next((c.error for c in results if c.error is not None),
                   None)
        if err is not None:
            parent.error = err
        else:
            try:
                if parent.kind == "direct":
                    parent.result = np.concatenate(
                        [c.result for c in results], axis=0)
                else:
                    parent.result = np.concatenate(
                        [c.result[d:] for c, d in zip(results, drops)])
            except BaseException as e:
                parent.error = e
        merged: Dict[str, float] = {}
        for c in results:                 # shards overlap: max per stage
            for kk, v in (c.timings or {}).items():
                merged[kk] = max(merged.get(kk, 0.0), v)
        parent.timings = merged
        # trace stamps span the union of the children's execution
        executed = [c for c in results if c.t_exec1 > 0.0]
        if executed:
            parent.t_submit = min(c.t_submit for c in executed)
            parent.t_exec0 = min(c.t_exec0 for c in executed)
            parent.t_exec1 = max(c.t_exec1 for c in executed)
        parent.done.set()
        if parent.callback is not None:
            try:
                parent.callback(parent)
            except Exception:
                pass

    # ------------------------------------------------------------------
    # stats / introspection
    # ------------------------------------------------------------------
    def snapshot_stats(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = dict(self.stats)
            out["per_device"] = {s.index: s.stats_row()
                                 for s in self._dev_states}
            out["policy"] = self.policy.snapshot()
            out["cost_model"] = self.cost.snapshot()
        out["heartbeats"] = self.heartbeats.snapshot()
        return out

    def queue_depth(self, lane: Optional[str] = None,
                    device: Optional[int] = None) -> int:
        """Jobs queued (not yet picked up by a manager) in ``lane`` (or
        every lane when None) — on one device's queue when ``device``
        is an index, else summed across the mesh."""
        if device is not None:
            return self._dev_states[device].queue.depth(lane)
        return (sum(s.queue.depth(lane) for s in self._dev_states)
                + self.outstanding.depth(lane))

    def shutdown(self):
        """Stop the managers after every queue drains.  Idempotent: only
        the first call posts shutdown sentinels and joins — repeat calls
        (interpreter-exit atexit hook racing an explicit shutdown, a
        gateway closing over an already-stopped engine) return at once
        instead of double-posting sentinels."""
        with self._lock:
            first = not self._shutdown_started
            self._shutdown_started = True
            self._alive = False
        if not first:
            return
        for s in self._dev_states:
            s.queue.put(None)
        for t in self._managers:
            t.join(timeout=10)

    # ------------------------------------------------------------------
    # manager internals
    # ------------------------------------------------------------------
    def _get_slot(self) -> dict:
        if self.buffer_reuse:
            return self.idle.get()
        return {}

    def _put_slot(self, slot: dict):
        if self.buffer_reuse:
            self.idle.put(slot)

    def _stage_sync(self, x):
        """Baseline (no overlap): force completion at stage boundary."""
        if not self.overlap:
            jax.block_until_ready(x)
        return x

    def _staging(self, slot: dict, shape, dtype) -> np.ndarray:
        """Host staging buffer: reused from the slot cache, or a fresh
        allocation per job (the paper's unoptimized malloc-per-task)."""
        if not self.buffer_reuse:
            return np.zeros(shape, dtype)
        key = (shape, np.dtype(dtype).str)
        buf = slot.get(key)
        if buf is None:
            buf = np.zeros(shape, dtype)
            slot[key] = buf
        else:
            buf.fill(0)
        return buf

    def _note_picked(self, dev: _DeviceState, job: Job):
        with self._lock:
            dev.picked.append(job)
            dev.queued_bytes = max(dev.queued_bytes - job.padded_bytes, 0)

    def _drain_batch(self, dev: _DeviceState, first: Job):
        """Greedy coalescing on one device's queue: pull queued jobs
        with ``first``'s fuse key behind it (direct with direct, sliding
        with identical window/stride, gear with gear).  Returns (batch,
        carry) where carry is a non-fusable job that was popped and must
        be executed next."""
        batch = [first]
        if not (self.coalesce and first.kind in ("direct", "sliding",
                                                 "gear")):
            return batch, None
        rows, width = first.n_rows, first.staged_width
        max_rows = self.policy.cur_rows
        max_bytes = self.policy.cur_bytes
        deadline = time.perf_counter() + self.coalesce_window_s
        while len(batch) < self.max_batch:
            try:
                nxt = dev.queue.get_nowait()
            except queue.Empty:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    break
                try:
                    nxt = dev.queue.get(timeout=wait)
                except queue.Empty:
                    break
            if nxt is None:               # shutdown token: repost + stop
                dev.queue.put(None)
                break
            self._note_picked(dev, nxt)
            if nxt.fuse_key != first.fuse_key:
                return batch, nxt
            # cap the fused launch by its actual padded staging matrix
            # (every row pads to the batch-max width) and, for direct,
            # by total rows — not just by job count: many multi-row or
            # wide jobs must not stack into an unbounded batch
            new_width = max(width, nxt.staged_width)
            if (rows + nxt.n_rows) * new_width > max_bytes:
                return batch, nxt
            if nxt.kind == "direct" and rows + nxt.n_rows > max_rows:
                return batch, nxt
            rows += nxt.n_rows
            width = new_width
            batch.append(nxt)
        return batch, None

    def _manager_main(self, dev: _DeviceState):
        """Crash-resilient wrapper: an exception escaping the manager
        loop fails the picked in-flight jobs, re-dispatches the queued
        remainder to surviving devices, counts ``manager_restarts``,
        and restarts the loop — the queue is never stranded."""
        while True:
            try:
                self._manager_loop(dev)
                return                    # clean sentinel exit
            except BaseException as e:
                self._recover_manager(dev, e)

    def _manager_loop(self, dev: _DeviceState):
        # terminates only on its shutdown token, never on the _alive
        # flag: a carried (popped-but-unfused) job must still execute
        # even if shutdown() lands while the previous batch runs
        hb = self.heartbeats.heartbeat(f"manager{dev.index}")
        carry: Optional[Job] = None
        while True:
            hb.beat()
            if carry is not None:
                job, carry = carry, None
            else:
                hb.park()       # indefinite block on an empty lane queue
                job = dev.queue.get()
                if job is None:
                    hb.park()   # clean shutdown: stay dormant
                    return
                hb.beat()
                self._note_picked(dev, job)
            batch, carry = self._drain_batch(dev, job)
            if self._fault_hook is not None:
                self._fault_hook(dev.index, batch)
            slot = self._get_slot()
            wall0 = time.perf_counter()
            failed = False
            try:
                with self._lock:
                    self.running.extend(batch)
                if self._launch_hook is not None:
                    self._launch_hook(dev.index, batch)
                if job.kind == "direct":
                    self._execute_direct(dev, slot, batch)
                else:
                    self._execute_stream_batch(dev, slot, batch)
            except BaseException as e:          # surfaced via wait()
                failed = True
                for j in batch:
                    j.error = e
            finally:
                wall1 = time.perf_counter()
                self._retire(dev, batch, wall1 - wall0, failed)
                self._put_slot(slot)
                for j in batch:
                    j.t_exec0, j.t_exec1 = wall0, wall1
                    j.done.set()
                    if j.callback is not None:
                        try:
                            j.callback(j)
                        except Exception:
                            pass

    def _retire(self, dev: _DeviceState, batch: List[Job], wall_s: float,
                failed: bool):
        """Credit the backlog clock, feed the cost model + fusion policy
        with the measured launch wall time, and update the per-device
        latency EWMAs (successful launches only)."""
        kind = batch[0].kind
        padded = sum(j.padded_bytes for j in batch)
        if kind == "direct":
            actual = sum(int(j.lens.sum()) for j in batch
                         if j.lens is not None)
            n_rows = sum(j.n_rows for j in batch)
        else:
            actual = sum(int(j.data.size) for j in batch)
            n_rows = len(batch)
        wbucket = max(j.staged_width for j in batch)
        with self._lock:
            for j in batch:
                if j in self.running:
                    self.running.remove(j)
                if j in dev.picked:
                    dev.picked.remove(j)
                dev.pending_s = max(dev.pending_s - j.cost_est, 0.0)
            if failed or kind not in ("direct", "sliding", "gear"):
                return
            est = max(self.cost.estimate(kind, padded), 1e-9)
            self.cost.observe(kind, padded, wall_s)
            oh, spb = self.cost.params(kind)
            self.policy.observe(padded, actual, n_rows, wall_s, oh, spb)
            dev.launch_hist.record(wall_s)
            key = (kind, wbucket)
            prev = dev.ewma_bucket_s.get(key)
            dev.ewma_bucket_s[key] = wall_s if prev is None \
                else 0.75 * prev + 0.25 * wall_s
            dev.ewma_launch_s = wall_s if not dev.ewma_launch_s \
                else 0.75 * dev.ewma_launch_s + 0.25 * wall_s
            ratio = min(max(wall_s / est, 0.05), 50.0)
            dev.slowdown = min(max(0.7 * dev.slowdown + 0.3 * ratio,
                                   0.05), 50.0)

    def _recover_manager(self, dev: _DeviceState, err: BaseException):
        """Fail the picked in-flight jobs with ``err``, move the queued
        remainder to surviving devices (back onto our own queue when the
        mesh has no other device), and count the restart."""
        with self._lock:
            picked, dev.picked = dev.picked, []
            self.stats.inc("manager_restarts")
            dev.restarts += 1
            for j in picked:
                dev.pending_s = max(dev.pending_s - j.cost_est, 0.0)
                if j in self.running:
                    self.running.remove(j)
        for j in picked:
            if not j.done.is_set():
                j.error = err
                j.done.set()
                if j.callback is not None:
                    try:
                        j.callback(j)
                    except Exception:
                        pass
        moved: List[Job] = []
        while True:                       # sentinel dequeues only once
            try:                          # the lanes are empty, so this
                item = dev.queue.get_nowait()   # drains every queued job
            except queue.Empty:
                break
            if item is None:
                dev.queue.put(None)       # keep our shutdown token
                break
            moved.append(item)
        exclude = dev.index if len(self._dev_states) > 1 else None
        for j in moved:
            with self._lock:
                dev.pending_s = max(dev.pending_s - j.cost_est, 0.0)
                dev.queued_bytes = max(dev.queued_bytes - j.padded_bytes,
                                       0)
            self._dispatch(j, exclude=exclude)

    def _account(self, dev: _DeviceState, n_jobs: int, nbytes: int,
                 n_scrub: int = 0):
        self.stats.inc("jobs", n_jobs)
        self.stats.inc("bytes", nbytes)
        self.stats.inc("launches")
        self.stats.inc("coalesced", n_jobs - 1)
        self.stats.max_update("max_fused", n_jobs)
        with self._lock:
            dev.jobs += n_jobs
            dev.launches += 1
            dev.bytes += nbytes
        if n_scrub:
            # a launch containing any scrub job counts once, so
            # scrub_launches < scrub_jobs is the fused-scrub signature
            self.stats.inc("scrub_jobs", n_scrub)
            self.stats.inc("scrub_launches")
            self.stats.inc("scrub_coalesced", n_scrub - 1)

    # -- fused direct batch --------------------------------------------
    def _execute_direct(self, dev: _DeviceState, slot: dict,
                        batch: List[Job]):
        t0 = time.perf_counter()
        # stage 1-2: staging + transfer in.  One padded [B, W] batch for
        # the whole burst; rows are length-bound so zero padding to the
        # widest row never changes a digest.  B and W are bucketed to
        # powers of two to bound jit retraces across ragged bursts.
        W = max(j.rows.shape[1] for j in batch)
        W = 1 << (max(W, 4) - 1).bit_length()
        n_rows = sum(j.rows.shape[0] for j in batch)
        B = 1 << (max(n_rows, 1) - 1).bit_length()
        staging = self._staging(slot, (B, W), np.uint8)
        lens = np.zeros((B,), np.int64)
        r = 0
        for j in batch:
            n, w = j.rows.shape
            staging[r:r + n, :w] = j.rows
            lens[r:r + n] = j.lens
            r += n
        words = staging.view("<u4") if staging.flags.c_contiguous \
            else np.ascontiguousarray(staging).view("<u4")
        dev_words = jax.device_put(words, dev.device)
        dev_lens = jax.device_put((lens // 4).astype(np.int32),
                                  dev.device)
        self._stage_sync(dev_words)
        t1 = time.perf_counter()
        # stage 3: ONE kernel launch for the fused batch, device-resident
        dig = ops.direct_hash_device(dev_words, dev_lens,
                                     interpret=self.interpret)
        self._stage_sync(dig)
        t2 = time.perf_counter()
        # stage 4: transfer out (digests only — 16 B per row)
        host = ops.digest_bytes(dig)
        t3 = time.perf_counter()
        timings = {"in": t1 - t0, "kernel": t2 - t1, "out": t3 - t2}
        r = 0
        for j in batch:
            n = j.rows.shape[0]
            j.result = host[r:r + n].copy()
            j.timings = dict(timings)       # batch-wide stage times
            r += n
        self._account(dev, len(batch), int(np.sum(lens)),
                      sum(j.lane == "scrub" for j in batch))

    # -- fused streaming batch (sliding / gear) ------------------------
    def _execute_stream_batch(self, dev: _DeviceState, slot: dict,
                              batch: List[Job]):
        """Execute a burst of same-config stream jobs as ONE padded
        [B, L] multi-row kernel launch.  Rows are zero-padded to the
        widest buffer; B and the word width are bucketed to powers of
        two to bound retraces across ragged bursts.  Each job's hashes
        are sliced out of the fused phase-matrix output."""
        kind = batch[0].kind
        if kind not in ("sliding", "gear"):
            raise ValueError(f"unknown job kind {kind!r}")
        t0 = time.perf_counter()
        flats = [j.data.reshape(-1).astype(np.uint8, copy=False)
                 for j in batch]
        lens = [f.size for f in flats]
        n_words = (max(max(lens), 1) + 3) // 4
        Wb = 1 << (max(n_words, 4) - 1).bit_length()
        B = 1 << (len(batch) - 1).bit_length()
        staging = self._staging(slot, (B, Wb), np.uint32)
        rows_u8 = staging.view(np.uint8).reshape(B, Wb * 4)
        for i, f in enumerate(flats):
            rows_u8[i, :f.size] = f
        dev_words = jax.device_put(staging, dev.device)
        self._stage_sync(dev_words)
        t1 = time.perf_counter()
        if kind == "sliding":
            window = int(batch[0].meta.get("window", 48))
            stride = int(batch[0].meta.get("stride", 4))
            phases = tuple(range(0, 4, stride))
            out = ops.sliding_hash_batch_device(dev_words, window // 4,
                                                phases,
                                                interpret=self.interpret)
            self._stage_sync(out)
            t2 = time.perf_counter()
            host = np.asarray(out)                       # [B, R, Wc]
            for i, j in enumerate(batch):
                n_off = (lens[i] - window) // stride + 1
                j.result = ops.sliding_finish(host[i], phases, n_off)
        else:
            out = ops.gear_hash_batch_device(
                dev_words, interpret=self.interpret,
                version=int(batch[0].meta.get("version", 1)))
            self._stage_sync(out)
            t2 = time.perf_counter()
            host = np.asarray(out)                       # [B, 4, Wc]
            for i, j in enumerate(batch):
                j.result = ops.gear_finish(host[i], lens[i])
        t3 = time.perf_counter()
        timings = {"in": t1 - t0, "kernel": t2 - t1, "out": t3 - t2}
        for j in batch:
            j.timings = dict(timings)       # batch-wide stage times
        self._account(dev, len(batch), int(sum(lens)),
                      sum(j.lane == "scrub" for j in batch))


# ----------------------------------------------------------------------
# process-wide default engine: shared across SAIs so concurrent writers'
# requests coalesce into common launches
# ----------------------------------------------------------------------
_DEFAULT: Optional[CrystalTPU] = None
_DEFAULT_LOCK = threading.Lock()
_ATEXIT_REGISTERED = False


def _shutdown_default_engine():
    """atexit hook: interpreter exit must never race live manager
    threads (daemon threads dying mid-launch while jax tears down)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        eng, _DEFAULT = _DEFAULT, None
    if eng is not None:
        eng.shutdown()                 # idempotent: explicit shutdowns ok


def default_engine() -> CrystalTPU:
    """The process-wide shared offload engine (created on first use,
    recreated if a previous default was shut down).  The first creation
    registers an ``atexit`` shutdown hook so engines left running at
    interpreter exit are drained and joined cleanly."""
    global _DEFAULT, _ATEXIT_REGISTERED
    with _DEFAULT_LOCK:
        if _DEFAULT is None or not _DEFAULT._alive:
            if not _ATEXIT_REGISTERED:
                atexit.register(_shutdown_default_engine)
                _ATEXIT_REGISTERED = True
            _DEFAULT = CrystalTPU()
        return _DEFAULT
