"""CrystalTPU — the generalized offload engine (CrystalGPU analog).

The paper's CrystalGPU layer sits between the storage system and the GPU
runtime and provides application-agnostic optimizations that make hashing
offload pay off:
  (1) buffer reuse   — amortize (pinned) staging-buffer allocation across
                       a stream of hashing jobs,
  (2) transfer/compute overlap — pipeline H2D copy of job i+1 with the
                       kernel of job i,
  (3) transparent multi-device — round-robin dispatch over all devices,
  (4) request coalescing — fuse many small outstanding ``direct`` hash
                       requests (concurrent writers, checkpoint leaves)
                       into ONE padded batch kernel launch, so per-launch
                       overhead is amortized over the whole burst.

Engine structure (same master/manager-thread/queue design as CrystalGPU):
an idle queue of preallocated job slots, an outstanding queue of submitted
jobs, one manager thread per device, and completion callbacks.  Each
manager drains the outstanding queue: it takes one job, then greedily
pulls every further compatible ``direct`` job that is already queued (plus
stragglers within ``coalesce_window_s``) and executes the whole batch as a
single kernel launch.  ``stats["launches"] < stats["jobs"]`` is the
signature of a fused burst.

Data stays device-resident from ``device_put`` through the kernel: hosts
prepare word-packed staging buffers, the device buffer is handed straight
to the jit'd kernel entry points (``ops.*_device``), and only the (small)
digest/fingerprint output is pulled back to the host — the seed's
``np.asarray(dev_buf)`` host round-trip before every launch is gone.

TPU/JAX adaptation: JAX's runtime is asynchronous by design, so overlap is
expressed by *not* synchronizing between stage boundaries (async dispatch
pipelines transfer and compute), while the no-overlap baseline inserts
``block_until_ready`` after every stage — mirroring the paper's staged
Table-1 execution.

Job normal forms
----------------
  'direct'  : data = [n, w] uint8 rows (w % 4 == 0) and meta['lens'] =
              [n] byte lengths (multiples of 4, <= w); result [n, 16]
              uint8 digests.  Legacy form: data = flat uint8 buffer plus
              meta['seg_bytes'] — split into fixed segments, word-aligned
              tail.  Coalescing fuses any mix of direct jobs: rows are
              zero-padded to the widest row in the batch (digests are
              length-bound, so trailing zeros never change them).
  'sliding' : data = flat uint8 buffer, meta {'window', 'stride'};
              result [n_offsets] uint32 window hashes.
  'gear'    : data = flat uint8 buffer; result [len] uint32 rolling hash.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.kernels import ops


@dataclass
class Job:
    kind: str                          # 'direct' | 'sliding' | 'gear'
    data: Optional[np.ndarray] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    callback: Optional[Callable] = None
    result: Any = None
    error: Optional[BaseException] = None
    done: threading.Event = field(default_factory=threading.Event)
    timings: Dict[str, float] = field(default_factory=dict)
    # normalized 'direct' payload (set at submit time)
    rows: Optional[np.ndarray] = None
    lens: Optional[np.ndarray] = None

    def wait(self):
        self.done.wait()
        if self.error is not None:
            raise self.error
        return self.result


def _normalize_direct(data: np.ndarray, meta: Dict[str, Any]):
    """Return (rows [n, w] uint8, lens [n] int64) for a direct request."""
    data = np.asarray(data)
    if data.ndim == 2:
        rows = data.astype(np.uint8, copy=False)
        lens = meta.get("lens")
        if lens is None:
            lens = np.full((rows.shape[0],), rows.shape[1], np.int64)
        else:
            lens = np.asarray(lens, np.int64)
        return rows, lens
    seg = int(meta.get("seg_bytes", 4096))
    flat = data.reshape(-1).astype(np.uint8, copy=False)
    n = max((flat.size + seg - 1) // seg, 1)
    rows = np.zeros((n, seg), np.uint8)
    rows.reshape(-1)[:flat.size] = flat
    lens = np.full((n,), seg, np.int64)
    tail = flat.size - (n - 1) * seg
    lens[-1] = (tail + 3) // 4 * 4
    return rows, lens


class CrystalTPU:
    """Coalescing offload engine for hashing jobs.

    Parameters mirror the paper's ablation switches plus coalescing:
      buffer_reuse:      keep and reuse staging buffers (idle queue)
      overlap:           async dispatch (no per-stage synchronization)
      devices:           accelerators to round-robin over (default: all)
      coalesce:          fuse queued 'direct' jobs into one batch launch
      max_batch:         max jobs fused into a single launch
      coalesce_window_s: extra wait for stragglers once the queue is
                         empty.  Default 0: fusion only captures jobs
                         already queued behind a running launch, so a
                         lone synchronous write never stalls waiting
                         for writers that don't exist; raise it for
                         bursty many-writer workloads.
    """

    def __init__(self, devices=None, buffer_reuse: bool = True,
                 overlap: bool = True, n_slots: int = 8,
                 interpret: bool = True, coalesce: bool = True,
                 max_batch: int = 64, coalesce_window_s: float = 0.0):
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.buffer_reuse = buffer_reuse
        self.overlap = overlap
        self.interpret = interpret
        self.coalesce = coalesce
        self.max_batch = max(1, int(max_batch))
        self.coalesce_window_s = coalesce_window_s
        self.outstanding: "queue.Queue[Optional[Job]]" = queue.Queue()
        self.idle: "queue.Queue[dict]" = queue.Queue()
        for _ in range(n_slots):
            self.idle.put({})          # slot: staging-buffer cache by shape
        self.running: List[Job] = []
        self._lock = threading.Lock()
        self.stats = {"jobs": 0, "bytes": 0, "launches": 0,
                      "coalesced": 0, "max_fused": 0}
        self._managers = [
            threading.Thread(target=self._manager_loop, args=(d,),
                             daemon=True, name=f"crystal-mgr-{i}")
            for i, d in enumerate(self.devices)]
        self._alive = True
        for t in self._managers:
            t.start()

    # ------------------------------------------------------------------
    # submission API
    # ------------------------------------------------------------------
    def submit(self, kind: str, data: np.ndarray, meta=None,
               callback=None) -> Job:
        if not self._alive:
            raise RuntimeError("CrystalTPU engine is shut down")
        job = Job(kind=kind, data=np.asarray(data), meta=meta or {},
                  callback=callback)
        if kind == "direct":
            job.rows, job.lens = _normalize_direct(job.data, job.meta)
        self.outstanding.put(job)
        return job

    def map_stream(self, kind: str, buffers, meta=None) -> List[Job]:
        """Submit a stream of jobs back-to-back (the paper's batched
        streaming workload) and return the job list."""
        return [self.submit(kind, b, meta) for b in buffers]

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)

    def shutdown(self):
        self._alive = False
        for _ in self._managers:
            self.outstanding.put(None)
        for t in self._managers:
            t.join(timeout=5)

    # ------------------------------------------------------------------
    # manager internals
    # ------------------------------------------------------------------
    def _get_slot(self) -> dict:
        if self.buffer_reuse:
            return self.idle.get()
        return {}

    def _put_slot(self, slot: dict):
        if self.buffer_reuse:
            self.idle.put(slot)

    def _stage_sync(self, x):
        """Baseline (no overlap): force completion at stage boundary."""
        if not self.overlap:
            jax.block_until_ready(x)
        return x

    def _staging(self, slot: dict, shape, dtype) -> np.ndarray:
        """Host staging buffer: reused from the slot cache, or a fresh
        allocation per job (the paper's unoptimized malloc-per-task)."""
        if not self.buffer_reuse:
            return np.zeros(shape, dtype)
        key = (shape, np.dtype(dtype).str)
        buf = slot.get(key)
        if buf is None:
            buf = np.zeros(shape, dtype)
            slot[key] = buf
        else:
            buf.fill(0)
        return buf

    def _drain_batch(self, first: Job):
        """Greedy coalescing: pull queued direct jobs behind ``first``.
        Returns (batch, carry) where carry is a non-fusable job that was
        popped and must be executed next."""
        batch = [first]
        if not (self.coalesce and first.kind == "direct"):
            return batch, None
        deadline = time.perf_counter() + self.coalesce_window_s
        while len(batch) < self.max_batch:
            try:
                nxt = self.outstanding.get_nowait()
            except queue.Empty:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    break
                try:
                    nxt = self.outstanding.get(timeout=wait)
                except queue.Empty:
                    break
            if nxt is None:               # shutdown token: repost + stop
                self.outstanding.put(None)
                break
            if nxt.kind != "direct":
                return batch, nxt
            batch.append(nxt)
        return batch, None

    def _manager_loop(self, device):
        # terminates only on its shutdown token, never on the _alive
        # flag: a carried (popped-but-unfused) job must still execute
        # even if shutdown() lands while the previous batch runs
        carry: Optional[Job] = None
        while True:
            if carry is not None:
                job, carry = carry, None
            else:
                job = self.outstanding.get()
                if job is None:
                    return
            batch, carry = self._drain_batch(job)
            slot = self._get_slot()
            try:
                with self._lock:
                    self.running.extend(batch)
                if job.kind == "direct":
                    self._execute_direct(device, slot, batch)
                else:
                    self._execute_stream(device, slot, batch[0])
            except BaseException as e:          # surfaced via wait()
                for j in batch:
                    j.error = e
            finally:
                with self._lock:
                    for j in batch:
                        if j in self.running:
                            self.running.remove(j)
                self._put_slot(slot)
                for j in batch:
                    j.done.set()
                    if j.callback is not None:
                        try:
                            j.callback(j)
                        except Exception:
                            pass

    def _account(self, n_jobs: int, nbytes: int):
        with self._lock:
            self.stats["jobs"] += n_jobs
            self.stats["bytes"] += nbytes
            self.stats["launches"] += 1
            self.stats["coalesced"] += n_jobs - 1
            self.stats["max_fused"] = max(self.stats["max_fused"], n_jobs)

    # -- fused direct batch --------------------------------------------
    def _execute_direct(self, device, slot: dict, batch: List[Job]):
        t0 = time.perf_counter()
        # stage 1-2: staging + transfer in.  One padded [B, W] batch for
        # the whole burst; rows are length-bound so zero padding to the
        # widest row never changes a digest.  B and W are bucketed to
        # powers of two to bound jit retraces across ragged bursts.
        W = max(j.rows.shape[1] for j in batch)
        W = 1 << (max(W, 4) - 1).bit_length()
        n_rows = sum(j.rows.shape[0] for j in batch)
        B = 1 << (max(n_rows, 1) - 1).bit_length()
        staging = self._staging(slot, (B, W), np.uint8)
        lens = np.zeros((B,), np.int64)
        r = 0
        for j in batch:
            n, w = j.rows.shape
            staging[r:r + n, :w] = j.rows
            lens[r:r + n] = j.lens
            r += n
        words = staging.view("<u4") if staging.flags.c_contiguous \
            else np.ascontiguousarray(staging).view("<u4")
        dev_words = jax.device_put(words, device)
        dev_lens = jax.device_put((lens // 4).astype(np.int32), device)
        self._stage_sync(dev_words)
        t1 = time.perf_counter()
        # stage 3: ONE kernel launch for the fused batch, device-resident
        dig = ops.direct_hash_device(dev_words, dev_lens,
                                     interpret=self.interpret)
        self._stage_sync(dig)
        t2 = time.perf_counter()
        # stage 4: transfer out (digests only — 16 B per row)
        host = ops.digest_bytes(dig)
        t3 = time.perf_counter()
        timings = {"in": t1 - t0, "kernel": t2 - t1, "out": t3 - t2}
        r = 0
        for j in batch:
            n = j.rows.shape[0]
            j.result = host[r:r + n].copy()
            j.timings = dict(timings)       # batch-wide stage times
            r += n
        self._account(len(batch), int(np.sum(lens)))

    # -- single streaming job (sliding / gear) -------------------------
    def _execute_stream(self, device, slot: dict, job: Job):
        t0 = time.perf_counter()
        flat = job.data.reshape(-1).astype(np.uint8, copy=False)
        L = flat.size
        pad = (-L) % 4
        staging = self._staging(slot, ((L + pad) // 4,), np.uint32)
        staging.view(np.uint8)[:L] = flat
        dev_words = jax.device_put(staging, device)
        self._stage_sync(dev_words)
        t1 = time.perf_counter()
        if job.kind == "sliding":
            window = job.meta.get("window", 48)
            stride = job.meta.get("stride", 4)
            phases = tuple(range(0, 4, stride))
            out = ops.sliding_hash_device(dev_words, window // 4, phases,
                                          interpret=self.interpret)
            self._stage_sync(out)
            t2 = time.perf_counter()
            n_off = (L - window) // stride + 1
            host = ops.sliding_finish(np.asarray(out), phases, n_off)
        elif job.kind == "gear":
            out = ops.gear_hash_device(dev_words,
                                       interpret=self.interpret)
            self._stage_sync(out)
            t2 = time.perf_counter()
            host = ops.gear_finish(np.asarray(out), L)
        else:
            raise ValueError(f"unknown job kind {job.kind!r}")
        t3 = time.perf_counter()
        job.result = host
        job.timings = {"in": t1 - t0, "kernel": t2 - t1, "out": t3 - t2}
        self._account(1, L)


# ----------------------------------------------------------------------
# process-wide default engine: shared across SAIs so concurrent writers'
# requests coalesce into common launches
# ----------------------------------------------------------------------
_DEFAULT: Optional[CrystalTPU] = None
_DEFAULT_LOCK = threading.Lock()


def default_engine() -> CrystalTPU:
    """The process-wide shared offload engine (created on first use,
    recreated if a previous default was shut down)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None or not _DEFAULT._alive:
            _DEFAULT = CrystalTPU()
        return _DEFAULT
