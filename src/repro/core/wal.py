"""Write-ahead log + snapshot machinery for the metadata plane.

This module is deliberately *semantics-free*: it knows how to frame,
group-commit, snapshot, and replay opaque ``(kind, body)`` records.
What the records mean — commits, claims, retires, pins — lives in
``repro.core.castore``, which keeps the dependency arrow pointing one
way (castore -> wal) and lets the framing be fuzz-tested in isolation.

Frame layout (little-endian), one per record::

    [u32 length][u32 crc32][payload]
    payload = [u64 seq][u8 kind][body]

``length`` counts payload bytes; ``crc32`` covers the payload.  Replay
stops *cleanly* at the first frame that fails any check — truncated
length prefix, zero or oversized length, truncated payload, CRC
mismatch, or a sequence number that doesn't advance — and reports how
far it got.  Hostile or torn bytes must never surface as
``struct.error``/``IndexError`` (same discipline as the gateway wire
codec).

Durability model: ``append`` buffers a frame in userspace and returns
its sequence number immediately; a flusher thread group-commits the
buffer (write + flush + fsync) every ``flush_interval_s`` so many
writers share one fsync.  ``sync(seq)`` blocks until the given record
is on disk.  Before each fsync the log runs its registered
``pre_sync_hooks`` — the metadata manager hangs block-store flushes
there, so by the time a commit record is durable the block bytes it
references are too (data-before-metadata ordering without a per-write
fsync on the data path).

On-disk layout under the log directory::

    wal-<start_seq>.log     append-only record frames
    snap-<seq>.snap         one frame (kind SNAP_KIND) holding a full
                            state snapshot as of <seq>

``snapshot(payload)`` writes the snapshot to a temp file, fsyncs,
renames it into place, rotates to a fresh log file, and only then
purges older logs/snapshots — a crash anywhere in between leaves at
least one valid (snapshot, tail) pair on disk.
"""
from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from typing import Any, Callable, Iterator, List, Optional, Tuple

from repro.obs import HeartbeatBoard, MetricsRegistry

from .faultinject import CrashPoint, FaultInjector

_HDR = struct.Struct("<II")    # length, crc32
_META = struct.Struct("<QB")   # seq, kind

SNAP_KIND = 255
MAX_RECORD_BYTES = 64 << 20

_LOG_PREFIX, _LOG_SUFFIX = "wal-", ".log"
_SNAP_PREFIX, _SNAP_SUFFIX = "snap-", ".snap"


class WALError(ValueError):
    """A record failed validation during encode/decode."""


def encode_frame(seq: int, kind: int, body: bytes) -> bytes:
    payload = _META.pack(seq, kind) + body
    return _HDR.pack(len(payload), zlib.crc32(payload)) + payload


def iter_frames(buf: bytes) -> Iterator[Tuple[int, int, bytes, int]]:
    """Yield ``(seq, kind, body, end_offset)`` for each valid frame in
    ``buf``, stopping silently at the first invalid one.  Never raises
    on hostile bytes."""
    off, n = 0, len(buf)
    prev_seq = None
    while off + _HDR.size <= n:
        length, crc = _HDR.unpack_from(buf, off)
        if length < _META.size or length > MAX_RECORD_BYTES:
            return
        end = off + _HDR.size + length
        if end > n:
            return
        payload = buf[off + _HDR.size:end]
        if zlib.crc32(payload) != crc:
            return
        seq, kind = _META.unpack_from(payload, 0)
        if prev_seq is not None and seq <= prev_seq:
            return
        prev_seq = seq
        yield seq, kind, payload[_META.size:], end
        off = end


def _scan_file(path: str) -> Tuple[List[Tuple[int, int, bytes]], int, bool]:
    """Read every valid frame from ``path``.  Returns
    ``(records, good_end_offset, clean)`` where ``clean`` is False when
    trailing bytes past the last valid frame exist (torn tail)."""
    with open(path, "rb") as fh:
        buf = fh.read()
    recs, good = [], 0
    for seq, kind, body, end in iter_frames(buf):
        recs.append((seq, kind, body))
        good = end
    return recs, good, good == len(buf)


def _file_seq(name: str, prefix: str, suffix: str) -> Optional[int]:
    if not (name.startswith(prefix) and name.endswith(suffix)):
        return None
    try:
        return int(name[len(prefix):len(name) - len(suffix)])
    except ValueError:
        return None


class WriteAheadLog:
    """Group-committed, snapshot-compacted record log over a directory.

    Opening an existing directory performs recovery: the newest *valid*
    snapshot payload lands in ``recovered_snapshot`` (or None), the
    valid tail records after it in ``recovered_records``, and the torn
    garbage past the last good frame — if any — is truncated away so
    appends resume from a clean boundary (``torn_tail`` records that it
    happened).  The caller replays both into its own state before doing
    new work.
    """

    def __init__(self, path: str, *, flush_interval_s: float = 0.002,
                 snapshot_every: int = 1024, fsync: bool = True,
                 fault: Optional[FaultInjector] = None):
        self.path = path
        self.flush_interval_s = float(flush_interval_s)
        self.snapshot_every = int(snapshot_every)
        self.fsync = fsync
        self.fault = fault
        self.pre_sync_hooks: List[Callable[[], None]] = []
        os.makedirs(path, exist_ok=True)

        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._buf = bytearray()  # guarded by self._lock
        self._crashed = False
        self._closed = False
        self._pending_seq = 0  # guarded by self._lock
        self._flushed_seq = 0  # guarded by self._lock
        self._records_since_snap = 0
        self.metrics = MetricsRegistry()
        self.stats = self.metrics.group(
            ("appends", "fsyncs", "snapshots", "flush_waits"))
        # group-commit fsync latency distribution (the durability tax a
        # blocked sync() waiter actually pays)
        self._fsync_hist = self.metrics.histogram("fsync_s")

        self.recovered_snapshot: Optional[bytes] = None
        self.recovered_seq = 0          # seq of the recovered snapshot
        self.recovered_records: List[Tuple[int, int, bytes]] = []
        self.torn_tail = False
        self._recover_dir()

        self._stop = threading.Event()
        self.heartbeats = HeartbeatBoard()
        self._flusher: Optional[threading.Thread] = None
        if self.flush_interval_s > 0:
            self._flusher = threading.Thread(
                target=self._flush_loop, name="wal-flusher", daemon=True)
            self._flusher.start()
        else:
            # inline-fsync mode has no flusher thread: register the
            # heartbeat parked so watchdogs read "idle", not "stalled"
            self.heartbeats.heartbeat("flusher").park()

    # ------------------------------------------------------------ recovery

    def _recover_dir(self):  # ra: disable=RA01(runs from __init__ before the flusher thread exists)
        names = os.listdir(self.path)
        snaps = sorted((s, n) for n in names
                       if (s := _file_seq(n, _SNAP_PREFIX, _SNAP_SUFFIX))
                       is not None)
        logs = sorted((s, n) for n in names
                      if (s := _file_seq(n, _LOG_PREFIX, _LOG_SUFFIX))
                      is not None)

        snap_seq = 0
        for seq_hint, name in reversed(snaps):
            full = os.path.join(self.path, name)
            recs, _, _ = _scan_file(full)
            if len(recs) == 1 and recs[0][1] == SNAP_KIND:
                snap_seq, _, payload = recs[0]
                self.recovered_snapshot = payload
                self.recovered_seq = snap_seq
                break
            self.torn_tail = True   # corrupt/partial snapshot skipped

        last_seq = snap_seq
        active: Optional[Tuple[str, int]] = None    # (path, good_end)
        for _, name in logs:
            full = os.path.join(self.path, name)
            recs, good, clean = _scan_file(full)
            active = (full, good)
            for seq, kind, body in recs:
                if seq <= snap_seq:
                    continue
                if seq != last_seq + 1:
                    clean = False   # gap — stop replay here
                    break
                self.recovered_records.append((seq, kind, body))
                last_seq = seq
            if not clean:
                self.torn_tail = True
                break
        self._seq = last_seq
        self._pending_seq = self._flushed_seq = last_seq
        self._records_since_snap = len(self.recovered_records)

        if active is not None:
            path, good = active
            if os.path.getsize(path) != good:
                with open(path, "r+b") as fh:
                    fh.truncate(good)
            self._active_path = path
        else:
            self._active_path = os.path.join(
                self.path, f"{_LOG_PREFIX}{last_seq + 1:020d}{_LOG_SUFFIX}")
        self._fh = open(self._active_path, "ab")

    # ------------------------------------------------------------ appends

    def _check_alive(self):
        if self._crashed:
            raise CrashPoint("wal", -1)
        if self._closed:
            raise WALError("write-ahead log is closed")

    def append(self, kind: int, body: bytes) -> int:
        """Buffer one record; returns its sequence number.  Durable only
        after the covering group-commit — use ``sync``."""
        with self._lock:
            self._check_alive()
            seq = self._seq + 1
            act = None
            if self.fault is not None:
                try:
                    act = self.fault.fire("wal.append", kind=kind, seq=seq)
                except CrashPoint:
                    self._crashed = True
                    self._cv.notify_all()
                    raise
            frame = encode_frame(seq, kind, body)
            if act == "torn":
                # persist a partial frame, then die: the classic torn
                # final record recovery must truncate away
                self._write_out(self._buf + frame[:len(frame) - max(1, len(frame) // 3)],
                                do_fsync=True)
                self._buf.clear()
                self._crashed = True
                self._cv.notify_all()
                raise CrashPoint("wal.append:torn", seq)
            self._seq = seq
            self._buf += frame
            self._pending_seq = seq
            self._records_since_snap += 1
            self.stats.inc("appends")
            if self.flush_interval_s <= 0:
                self._flush_locked()
            else:
                self._cv.notify_all()
            return seq

    def sync(self, seq: Optional[int] = None):
        """Block until record ``seq`` (default: all appended so far) is
        flushed + fsynced.

        Group-commit leader election: rather than sleeping out the
        flusher's full batch window, a waiter yields one short batching
        grace (a quarter interval) for concurrent appenders to pile into
        the buffer, then performs the flush itself — every record
        buffered by then rides the same fsync.  Commit latency is
        bounded by ~interval/4 while bursts still share fsyncs."""
        with self._lock:
            target = self._pending_seq if seq is None else seq
            grace = min(max(self.flush_interval_s / 4, 1e-4), 0.05)
            while self._flushed_seq < target:
                self._check_alive()
                if self._flusher is None or not self._flusher.is_alive():
                    self._flush_locked()
                    break
                self.stats.inc("flush_waits")
                self._cv.wait(timeout=grace)
                if self._flushed_seq < target:
                    self._check_alive()
                    self._flush_locked()
            self._check_alive()

    @property
    def last_seq(self) -> int:
        return self._seq

    @property
    def records_since_snapshot(self) -> int:
        return self._records_since_snap

    def snapshot_stats(self) -> dict:
        """Counters plus the group-commit fsync latency histogram
        (count/sum/max/p50/p95/p99 in seconds)."""
        out = dict(self.stats)
        out["fsync_hist"] = self._fsync_hist.summary()
        out["heartbeats"] = self.heartbeats.snapshot()
        return out

    # ------------------------------------------------------------ flushing

    def _write_out(self, data: bytes, do_fsync: bool):
        self._fh.write(data)
        self._fh.flush()
        if do_fsync and self.fsync:
            os.fsync(self._fh.fileno())

    def _flush_locked(self):
        if not self._buf and self._flushed_seq == self._pending_seq:
            return
        for hook in self.pre_sync_hooks:
            hook()          # data-before-metadata: flush block stores
        act = None
        if self.fault is not None:
            try:
                act = self.fault.fire("wal.fsync", seq=self._pending_seq)
            except CrashPoint:
                self._crashed = True
                self._cv.notify_all()
                raise
        if act == "skip":
            # lying disk: report durable, keep bytes in userspace so a
            # simulated crash genuinely loses them
            self._buf_skipped = True
        else:
            t0 = time.perf_counter()
            self._write_out(bytes(self._buf), do_fsync=True)
            self._fsync_hist.record(time.perf_counter() - t0)
            self._buf.clear()
            self.stats.inc("fsyncs")
        self._flushed_seq = self._pending_seq
        self._cv.notify_all()

    def _flush_loop(self):
        hb = self.heartbeats.heartbeat("flusher")
        try:
            while not self._stop.is_set():
                hb.beat()
                if self.fault is not None:
                    # fired OUTSIDE the WAL lock: a "stall" arm wedges
                    # only this thread — writers keep committing via
                    # sync() leader election while the heartbeat ages
                    try:
                        self.fault.fire("wal.flusher")
                    except CrashPoint:
                        return
                with self._cv:
                    if (not self._buf
                            and self._flushed_seq == self._pending_seq
                            and not self._crashed):
                        self._cv.wait(timeout=0.1)
                    if self._stop.is_set() or self._crashed:
                        return
                    idle = (not self._buf
                            and self._flushed_seq == self._pending_seq)
                if idle:
                    # idle ticks cycle back through the beat + fault
                    # fire above, so a stall arm wedges an idle flusher
                    # too (the watchdog drill) and the heartbeat stays
                    # fresh without holding the condvar
                    continue
                # batch window: let concurrent writers pile into the buffer
                self._stop.wait(self.flush_interval_s)
                with self._lock:
                    if self._crashed:
                        return
                    try:
                        self._flush_locked()
                    except CrashPoint:
                        return
        finally:
            hb.park()   # clean exit/crash is dormancy, not a stall

    # ------------------------------------------------------------ snapshot

    def snapshot(self, payload: bytes) -> int:
        """Write a full-state snapshot as of the last appended record,
        rotate to a fresh log file, and purge older logs/snapshots.
        Returns the snapshot's sequence number."""
        with self._lock:
            self._check_alive()
            if self.fault is not None:
                try:
                    self.fault.fire("wal.snapshot", seq=self._seq)
                except CrashPoint:
                    self._crashed = True
                    self._cv.notify_all()
                    raise
            self._flush_locked()
            seq = self._seq
            frame = encode_frame(seq, SNAP_KIND, payload)
            final = os.path.join(
                self.path, f"{_SNAP_PREFIX}{seq:020d}{_SNAP_SUFFIX}")
            tmp = final + ".tmp"
            with open(tmp, "wb") as fh:
                fh.write(frame)
                fh.flush()
                if self.fsync:
                    os.fsync(fh.fileno())  # ra: disable=RA04(snapshot fsync IS the commit point; the lock is the serialiser)
            os.replace(tmp, final)
            # rotate: new appends land in a fresh file starting past seq
            self._fh.close()
            old_active = self._active_path
            self._active_path = os.path.join(
                self.path, f"{_LOG_PREFIX}{seq + 1:020d}{_LOG_SUFFIX}")
            self._fh = open(self._active_path, "ab")
            self._records_since_snap = 0
            self.stats.inc("snapshots")
            # purge only after the new snapshot is in place
            for name in os.listdir(self.path):
                full = os.path.join(self.path, name)
                s = _file_seq(name, _SNAP_PREFIX, _SNAP_SUFFIX)
                if s is not None and s < seq:
                    os.unlink(full)
                    continue
                s = _file_seq(name, _LOG_PREFIX, _LOG_SUFFIX)
                if s is not None and full != self._active_path and full != old_active:
                    os.unlink(full)
                elif full == old_active and full != self._active_path:
                    os.unlink(full)
            return seq

    # ------------------------------------------------------------ lifecycle

    def crash(self):
        """Mark the log dead (simulated process death): every later call
        raises CrashPoint; buffered-but-unflushed records are lost."""
        with self._lock:
            self._crashed = True
            self._cv.notify_all()

    @property
    def crashed(self) -> bool:
        return self._crashed

    def close(self):
        with self._lock:
            if self._closed or self._crashed:
                self._closed = True
                self._stop.set()
                self._cv.notify_all()
            else:
                self._flush_locked()
                self._closed = True
                self._stop.set()
                self._cv.notify_all()
        if self._flusher is not None:
            self._flusher.join(timeout=2.0)
        try:
            self._fh.close()
        except OSError:
            pass
