"""Content-defined chunking: boundary selection + min/max enforcement.

The device kernels (sliding-window MD5, gear) produce a hash per byte
offset; this module implements the paper's CPU post-processing stage —
"the CPU is used to check the hash values and decide on block
boundaries" — exactly as in the HashGPU design, where efficient global
synchronization across GPU threads is impossible and the final scan is
host-side.

Boundary rule (LBFS): a window hash h declares a chunk end when
``h & mask == magic``.  Boundaries are aligned down to 4 bytes (word
alignment, see DESIGN.md) and min/max chunk sizes are enforced greedily.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def boundary_mask_for(avg_chunk: int) -> int:
    """mask with log2(avg_chunk) low bits set."""
    bits = max(int(np.log2(max(avg_chunk, 2))), 1)
    return (1 << bits) - 1


def select_boundaries(hashes: np.ndarray, total_len: int, *,
                      window: int = 48, stride: int = 1,
                      avg_chunk: int = 4096, min_chunk: int = 0,
                      max_chunk: int = 0, magic: int = 0) -> List[int]:
    """Greedy boundary selection.

    hashes[i] is the hash of the window starting at byte i*stride; the
    candidate chunk end for window i is ``i*stride + window`` (aligned
    down to 4).  Returns chunk end offsets, always ending with total_len.
    """
    min_chunk = min_chunk or max(avg_chunk // 4, window)
    max_chunk = max_chunk or avg_chunk * 4
    mask = boundary_mask_for(avg_chunk)
    magic = magic & mask

    # NOTE: boundaries are byte-exact.  Aligning them to word multiples of
    # the ABSOLUTE offset would break CDC's shift-resilience (a k-byte
    # insert with k % 4 != 0 would desynchronize every later chunk);
    # word-alignment for the hash kernels is instead handled by padding
    # each chunk's *message* (see SAI digest convention).
    cand_idx = np.nonzero((hashes & mask) == magic)[0]
    cand_pos = cand_idx * stride + window
    cand_pos = cand_pos[(cand_pos > 0) & (cand_pos < total_len)]

    bounds: List[int] = []
    last = 0
    for pos in cand_pos:
        pos = int(pos)
        if pos - last < min_chunk:
            continue
        # force intermediate boundaries if a gap exceeded max_chunk
        while pos - last > max_chunk:
            last += max_chunk
            bounds.append(last)
        if pos - last >= min_chunk:
            bounds.append(pos)
            last = pos
    while total_len - last > max_chunk:
        last += max_chunk
        bounds.append(last)
    bounds.append(total_len)
    return bounds


def chunk_spans(bounds: List[int]) -> List[Tuple[int, int]]:
    out = []
    start = 0
    for b in bounds:
        out.append((start, b))
        start = b
    return out


def split_chunks(data: bytes, bounds: List[int]) -> List[bytes]:
    return [data[s:e] for s, e in chunk_spans(bounds)]
