"""The paper's primary contribution: accelerator-offloaded hashing for a
content-addressable storage system — HashTPU kernels (repro.kernels),
the CrystalTPU task runtime, the MosaStore-analog CA store and client SAI,
plus chunking / integrity substrates."""
from repro.core.castore import (MetadataManager, StorageNode, BlockMeta,  # noqa: F401
                                NodeFailure, RecoveryReport, make_store,
                                open_durable_store)
from repro.core.blockstore import BlockStore  # noqa: F401
from repro.core.wal import WALError, WriteAheadLog  # noqa: F401
from repro.core.faultinject import CrashPoint, FaultInjector  # noqa: F401
from repro.core.crystal import CrystalTPU, Job, default_engine  # noqa: F401
from repro.core.sai import (SAI, SAIConfig, ReadFuture, StoreIOError,  # noqa: F401
                            WriteFuture, WriteStats, pack_blocks)
from repro.core.noderuntime import (ClusterRuntime, NodeRuntime,  # noqa: F401
                                    NodeRuntimeConfig)
from repro.core import chunking, integrity  # noqa: F401
