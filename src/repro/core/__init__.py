"""The paper's primary contribution: accelerator-offloaded hashing for a
content-addressable storage system — HashTPU kernels (repro.kernels),
the CrystalTPU task runtime, the MosaStore-analog CA store and client SAI,
plus chunking / integrity substrates."""
from repro.core.castore import (MetadataManager, StorageNode, BlockMeta,  # noqa: F401
                                NodeFailure, make_store)
from repro.core.crystal import CrystalTPU, Job, default_engine  # noqa: F401
from repro.core.sai import (SAI, SAIConfig, ReadFuture, WriteFuture,  # noqa: F401
                            WriteStats, pack_blocks)
from repro.core.noderuntime import (ClusterRuntime, NodeRuntime,  # noqa: F401
                                    NodeRuntimeConfig)
from repro.core import chunking, integrity  # noqa: F401
