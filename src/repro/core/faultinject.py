"""Deterministic fault injection for the durability stack.

Generalizes the idea behind ``repro.train.fault`` (step-indexed
``fail_at_steps`` exceptions) into a reusable harness any storage
component can instrument: code under test calls
``injector.fire("site", **ctx)`` at its fault sites, and tests arm a
site to trigger on its N-th hit — either killing the "process"
(:class:`CrashPoint`), skipping the operation (``"skip"`` — e.g. an
fsync that lies), or tearing it (``"torn"`` — the caller persists a
partial record, then dies).

Sites instrumented by the durability layer (repro.core.wal /
blockstore / castore):

  ``wal.append``      one metadata WAL record about to be buffered
                      (``ctx: kind, seq``) — ``kill_after(n)`` here is
                      the "crash after n WAL records" crash point;
                      action ``"torn"`` persists a partial frame first
  ``wal.fsync``       a group-commit flush cycle about to fsync —
                      ``"skip"`` models a lying disk (records reported
                      durable, bytes lost with the process)
  ``wal.flusher``     one group-commit flusher loop iteration, fired
                      *outside* the WAL lock — a ``"stall"`` arm here
                      wedges only the flusher thread (writers keep
                      committing via ``sync()`` leader election), the
                      scenario heartbeat watchdogs must catch
  ``wal.snapshot``    a snapshot about to be written (crash =>
                      recovery falls back to the previous snapshot and
                      a longer tail)
  ``blockstore.put``  one block about to be appended to a segment
                      (``ctx: digest``) — ``"torn"`` persists a
                      partial record (the partial-segment-write case)
  ``blockstore.fsync``a segment flush about to fsync (``"skip"``)
  ``blockstore.drop`` one tombstone about to be appended (crash
                      mid-GC)

A component that receives a :class:`CrashPoint` from ``fire`` marks
itself crashed and raises it from every later call, so the rest of the
process observes the same thing it would observe of a dead peer: the
durable state on disk stops changing.  Tests then "restart" by
reopening the same directory with a fresh object graph.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional


class CrashPoint(BaseException):
    """Simulated process death at an instrumented fault site.

    Derives from ``BaseException`` so ``except Exception`` recovery
    paths don't accidentally swallow the "process" dying mid-write —
    exactly like a real SIGKILL wouldn't run them."""

    def __init__(self, site: str, hit: int):
        super().__init__(f"injected crash at {site} (hit {hit})")
        self.site = site
        self.hit = hit


class _Arm:
    __slots__ = ("after", "action", "times", "fired")

    def __init__(self, after: int, action, times: int):
        self.after = after
        self.action = action
        self.times = times
        self.fired = 0


class FaultInjector:
    """Arm deterministic faults at named sites.

    ``arm(site, after=N)`` makes the N-th ``fire(site)`` call trigger
    (counting from the arm, 1-based).  ``action``:

      * ``"crash"`` (default) — ``fire`` raises :class:`CrashPoint`
      * ``"skip"``  — ``fire`` returns ``"skip"``; the caller must skip
        the guarded operation (fsync dropped)
      * ``"torn"``  — ``fire`` returns ``"torn"``; the caller persists
        a deliberately partial record, then raises CrashPoint itself
      * ``"stall"`` — ``fire`` blocks the *calling thread* (outside the
        injector lock) until :meth:`clear_stall` releases the site or
        ``stall_max_s`` elapses — models a wedged-but-alive thread so
        health watchdogs can be proven to fire
      * a callable — invoked with the fire context; its return value is
        handed back to the caller (may itself raise)

    ``when={...}`` restricts matching to fires whose context contains
    the given key/value pairs (e.g. only WAL records of one kind), and
    only matching fires advance the hit counter for that arm.
    ``times`` repeats the trigger for that many matching hits after the
    threshold (default 1)."""

    def __init__(self, stall_max_s: float = 60.0):
        self._lock = threading.Lock()
        self._arms: Dict[str, List[tuple]] = {}
        self._stalls: Dict[str, threading.Event] = {}
        self.stall_max_s = stall_max_s
        self.hits: Dict[str, int] = {}
        self.log: List[tuple] = []

    def arm(self, site: str, after: int = 1, action="crash",
            times: int = 1, when: Optional[Dict[str, Any]] = None):
        with self._lock:
            self._arms.setdefault(site, []).append(
                (_Arm(max(1, int(after)), action, max(1, int(times))),
                 dict(when or {}), [0]))
        return self

    def kill_after(self, site: str, n: int,
                   when: Optional[Dict[str, Any]] = None):
        """Crash on the n-th matching hit of ``site`` (the ISSUE's
        ``kill_after(n_wal_records)`` spelled per-site)."""
        return self.arm(site, after=n, action="crash", when=when)

    def stall(self, site: str, after: int = 1,
              when: Optional[Dict[str, Any]] = None):
        """Wedge every later ``fire(site)`` caller until
        :meth:`clear_stall`.  The blocked thread stays alive (unlike a
        crash), which is exactly the failure mode heartbeat watchdogs
        exist to catch."""
        with self._lock:
            self._stalls.setdefault(site, threading.Event()).clear()
        return self.arm(site, after=after, action="stall",
                        times=1 << 30, when=when)

    def clear_stall(self, site: Optional[str] = None):
        """Release stalled callers (one site, or all when ``site`` is
        None) and disarm the matching stall arms so later fires pass."""
        with self._lock:
            sites = [site] if site is not None else list(self._stalls)
            for s in sites:
                ev = self._stalls.get(s)
                if ev is not None:
                    ev.set()
                self._arms[s] = [
                    entry for entry in self._arms.get(s, [])
                    if entry[0].action != "stall"
                ]
        return self

    def _stall_wait(self, site: str) -> str:
        with self._lock:
            ev = self._stalls.setdefault(site, threading.Event())
        ev.wait(timeout=self.stall_max_s)
        return "stall"

    def fire(self, site: str, **ctx) -> Optional[Any]:
        """Called by instrumented code at a fault site.  Returns the
        armed action result (``"skip"`` / ``"torn"`` / callable return)
        or None when nothing triggers; raises CrashPoint for ``"crash"``
        arms."""
        with self._lock:
            self.hits[site] = self.hits.get(site, 0) + 1
            self.log.append((site, dict(ctx)))
            triggered: Optional[Callable[[], Any]] = None
            for arm, when, count in self._arms.get(site, ()):
                if any(ctx.get(k) != v for k, v in when.items()):
                    continue
                count[0] += 1
                if count[0] < arm.after or arm.fired >= arm.times:
                    continue
                arm.fired += 1
                hit = count[0]
                if arm.action == "crash":
                    raise CrashPoint(site, hit)
                if arm.action == "stall":
                    triggered = lambda: self._stall_wait(site)           # noqa: E731,B023
                elif callable(arm.action):
                    act = arm.action
                    triggered = lambda: act(site=site, hit=hit, **ctx)  # noqa: E731,B023
                else:
                    result = arm.action
                    triggered = lambda: result                          # noqa: E731,B023
                break
        return triggered() if triggered is not None else None

    def reset(self):
        with self._lock:
            self._arms.clear()
            self.hits.clear()
            self.log.clear()
            for ev in self._stalls.values():
                ev.set()  # release any thread still wedged in a stall
            self._stalls.clear()


def tear_tail(path: str, keep_frac: float = 0.5, min_cut: int = 1):
    """Truncate ``path`` mid-record: keep ``keep_frac`` of the final
    bytes beyond a floor cut of ``min_cut`` bytes.  A post-crash test
    helper for simulating a torn final record on any append-only file
    (WAL log or block-store segment)."""
    import os
    size = os.path.getsize(path)
    cut = max(int((1.0 - keep_frac) * size), min_cut)
    new_size = max(size - cut, 0)
    with open(path, "r+b") as fh:
        fh.truncate(new_size)
    return new_size
