"""Storage-node runtime: offloaded scrubbing, refcounted GC, repair.

The paper's Figure 2 shows storage nodes as *active* participants of the
distributed store — they "preserve data integrity" continuously rather
than waiting for a client read to trip over a corrupt or lost block.
This module is that node-side runtime, built on the same coalescing
offload engine (CrystalTPU) the client write/read paths use:

  NodeRuntime      — one per storage node (Figure 2's "storage node"
                     box): a background **integrity scrubber** that
                     periodically streams the node's resident blocks
                     through fused ``direct`` hash submissions on the
                     engine's low-priority scrub lane.  Digest mismatch
                     => the copy is quarantined (taint + registry
                     removal) and repair is triggered.
  ClusterRuntime   — the supervisor (Figure 2's "manager" side of the
                     control plane): owns the scrub threads, a
                     **repair/re-replication pipeline** that restores
                     the replica count of quarantined or
                     under-replicated digests from healthy copies
                     (verifying every repaired copy through the engine
                     before registering it), a **reference-counted GC**
                     fed by the metadata manager's retire events (a
                     block claimed or pinned by a concurrent writer is
                     never collected), and a **Merkle spot-checker**
                     that validates a sampled block against its
                     file-level root via ``integrity.merkle_proof``.

Foreground priority (the paper's "impact on competing applications"
evaluation, Figures 12-17): every scrub/repair hash request is submitted
on the engine's ``lane='scrub'`` low-priority lane — managers only drain
it when no foreground job is queued — and the background loops pace
their batch submissions (``scrub_interval_s``), so client write/read
traffic keeps engine priority while scrub bursts still coalesce into
fused launches (``scrub_launches < scrub_jobs``).  Scrubbing is also
*load-aware*: before each burst the runtime checks the engine's
foreground queue depth and backs off (``scrub_backoff_depth`` /
``scrub_backoff_s``, counted by ``scrub_backoffs``) while client
traffic is backlogged, abandoning the sweep until the next cycle when
the pressure persists.  The
``benchmarks/scrub_interference.py`` run measures exactly this:
foreground write latency with and without a scrubbing runtime.

The supervisor exposes ``start`` / ``pause`` / ``resume`` / ``drain`` /
``stop`` and ``snapshot_stats``; the ``*_once`` methods run one
synchronous cycle each (deterministic — what the tests drive).
"""
from __future__ import annotations

import queue
import random
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import integrity
from repro.core.castore import MetadataManager, NodeFailure, StorageNode
from repro.obs import HeartbeatBoard, MetricsRegistry
from repro.core.crystal import CrystalTPU
from repro.core import crystal as crystal_mod
from repro.core.sai import pack_blocks


@dataclass
class NodeRuntimeConfig:
    scrub_batch_blocks: int = 16      # blocks per fused scrub burst
    scrub_interval_s: float = 0.02    # pace between scrub bursts (rate
    #                                   limit: keeps foreground priority)
    scrub_cycle_idle_s: float = 0.25  # pause between full node sweeps
    repair_poll_s: float = 0.05       # repair/GC maintenance cadence
    merkle_every_n: int = 4           # merkle spot-check every N
    #                                   maintenance cycles (0 = off)
    merkle_samples: int = 1           # sampled blocks per spot-check
    scrub_backoff_depth: int = 4      # pause scrubbing while the
    #                                   engine's foreground (fg+batch)
    #                                   queue is deeper than this (0=off)
    scrub_backoff_s: float = 0.02     # wait before re-checking the load
    underrep_scan_every_n: int = 16   # under-replication registry scan
    #                                   every N maintenance cycles (0=off)
    gc_full_scan_every_n: int = 64    # full-registry GC sweep every N
    #                                   cycles (retire events cover the
    #                                   common path; 0 = events only)
    seed: int = 0                     # sampling RNG seed


class NodeRuntime:
    """Background integrity scrubber for ONE storage node.

    ``scrub_once`` sweeps the node's resident (non-tainted, non-raw)
    blocks in batches: each block becomes one single-row ``direct``
    request on the engine's scrub lane, submitted back-to-back so the
    engine fuses the burst into one padded batch launch — the node-side
    mirror of the client write path's coalesced hashing.  A recomputed
    digest that differs from the content address quarantines that copy
    and hands the digest to the cluster repair pipeline."""

    def __init__(self, node: StorageNode, cluster: "ClusterRuntime"):
        self.node = node
        self.cluster = cluster

    def scrub_once(self, paced: bool = False, hb=None) -> Dict[str, int]:
        """One full sweep of this node.  Returns {scanned, corrupt}."""
        node = self.node
        digests = [] if node.failed else node.healthy_digests()
        return self.scrub_digests(digests, paced=paced, hb=hb)

    def scrub_digests(self, digests: List[bytes],
                      paced: bool = False, hb=None) -> Dict[str, int]:
        """Engine-verify a specific digest list on this node (the full
        sweep and the recovery suspect-scrub share this path).  Returns
        {scanned, corrupt}."""
        cl, node, cfg = self.cluster, self.node, self.cluster.cfg
        scanned = corrupt = 0
        for k in range(0, len(digests), cfg.scrub_batch_blocks):
            if not cl._gate(hb):
                break
            if not cl._load_gate():
                break                      # foreground busy: yield the
                #                            sweep, resume next cycle
            batch = []
            for d in digests[k:k + cfg.scrub_batch_blocks]:
                if d.startswith(b"raw!"):      # no content hash (ca=none)
                    continue
                try:
                    batch.append((d, node.get(d)))
                except (KeyError, NodeFailure):
                    continue                   # GC'd / failed meanwhile
            if not batch:
                continue
            # one job per block, submitted back-to-back: the engine
            # fuses the burst (plus any concurrent node's burst) into
            # common scrub-lane batch launches
            jobs = []
            for d, data in batch:
                rows, lens = pack_blocks([data])
                jobs.append(cl.engine.submit("direct", rows,
                                             {"lens": lens}, lane="scrub"))
            for (d, data), job in zip(batch, jobs):
                got = job.wait()[0].tobytes()
                scanned += 1
                if got != d:
                    corrupt += 1
                    cl._report_corruption(d, node.node_id)
            if paced and cfg.scrub_interval_s:
                cl._stop.wait(cfg.scrub_interval_s)
        cl._bump(scrubbed_blocks=scanned, corrupt_found=corrupt)
        return {"scanned": scanned, "corrupt": corrupt}


class ClusterRuntime:
    """Supervisor for the node-side background services.

    Owns one :class:`NodeRuntime` per storage node plus the shared
    repair/GC/Merkle maintenance machinery.  All hashing flows through
    the engine's low-priority scrub lane; the supervisor subscribes to
    the metadata manager's quarantine events (repair triggers — from its
    own scrubbers AND from client read-path verify failures) and retire
    events (GC candidates)."""

    def __init__(self, manager: MetadataManager,
                 engine: Optional[CrystalTPU] = None,
                 config: Optional[NodeRuntimeConfig] = None):
        self.manager = manager
        self._engine = engine
        self.cfg = config or NodeRuntimeConfig()
        self.node_runtimes = [NodeRuntime(n, self) for n in manager.nodes]
        self._repair_q: "queue.Queue[bytes]" = queue.Queue()
        self._gc_pending: List[bytes] = []
        self._rng = random.Random(self.cfg.seed)
        self._stop = threading.Event()
        self._resume = threading.Event()
        self._resume.set()
        self._threads: List[threading.Thread] = []
        self._stats_lock = threading.Lock()   # guards _gc_pending
        # per-loop liveness: beats between scrub bursts / maintenance
        # cycles, parks while paused (so pause() reads healthy-idle)
        self.heartbeats = HeartbeatBoard()
        self.metrics = MetricsRegistry()
        self.stats = self.metrics.group(
            ("scrubbed_blocks", "corrupt_found", "repairs_enqueued",
             "repaired_copies", "repair_lost", "gc_collected",
             "merkle_checks", "merkle_failures", "scrub_backoffs"))
        manager.add_quarantine_listener(self._on_quarantine)
        manager.add_retire_listener(self._on_retire)

    # ------------------------------------------------------------------
    # engine access / shared helpers
    # ------------------------------------------------------------------
    @property
    def engine(self) -> CrystalTPU:
        if self._engine is None or not self._engine._alive:
            self._engine = crystal_mod.default_engine()
        return self._engine

    def _bump(self, **deltas: int):
        for k, v in deltas.items():
            self.stats.inc(k, v)

    def _gate(self, hb=None) -> bool:
        """Respect pause/stop between scrub bursts.  True = proceed.
        ``hb`` (a heartbeat) parks while paused so a deliberately
        suspended runtime never reads as a stalled thread."""
        while not self._stop.is_set():
            if self._resume.wait(timeout=0.05):
                if hb is not None:
                    hb.beat()
                return True
            if hb is not None:
                hb.park()
        return False

    def _foreground_depth(self) -> int:
        """Client-facing backlog queued at the engine (fg + batch lanes;
        the scrub lane's own backlog doesn't count against itself)."""
        eng = self.engine
        return eng.queue_depth("fg") + eng.queue_depth("batch")

    def _load_gate(self) -> bool:
        """Load-aware scrub backoff (ROADMAP open item): when the
        engine's foreground queue is deeper than
        ``scrub_backoff_depth``, wait ``scrub_backoff_s`` once and
        re-check; if the backlog persists, tell the caller to abandon
        the current sweep (it resumes on the next scrub cycle).  Every
        deferred burst bumps the ``scrub_backoffs`` counter — the proof
        the mechanism triggered.  True = proceed with the burst."""
        cfg = self.cfg
        if not cfg.scrub_backoff_depth:
            return True
        if self._foreground_depth() <= cfg.scrub_backoff_depth:
            return True
        self._bump(scrub_backoffs=1)
        self._stop.wait(cfg.scrub_backoff_s)
        return self._foreground_depth() <= cfg.scrub_backoff_depth

    def _digest_of(self, data: bytes) -> bytes:
        """Canonical block digest via a scrub-lane engine submission."""
        rows, lens = pack_blocks([data])
        job = self.engine.submit("direct", rows, {"lens": lens},
                                 lane="scrub")
        return job.wait()[0].tobytes()

    # ------------------------------------------------------------------
    # event listeners (metadata manager -> runtime)
    # ------------------------------------------------------------------
    def _on_quarantine(self, digest: bytes, node_id: int, remaining):
        self._repair_q.put(digest)
        self._bump(repairs_enqueued=1)

    def _on_retire(self, path: str, orphans: List[bytes]):
        if orphans:
            with self._stats_lock:
                self._gc_pending.extend(orphans)

    def _report_corruption(self, digest: bytes, node_id: int):
        # quarantine_block taints the node copy, strips the replica from
        # the registry, and fires _on_quarantine -> repair queue
        self.manager.quarantine_block(digest, node_id)

    # ------------------------------------------------------------------
    # synchronous one-cycle services (tests / drain drive these)
    # ------------------------------------------------------------------
    def scrub_once(self) -> Dict[str, int]:
        """Sweep every node once.  Returns merged {scanned, corrupt}."""
        out = {"scanned": 0, "corrupt": 0}
        for nr in self.node_runtimes:
            res = nr.scrub_once()
            out["scanned"] += res["scanned"]
            out["corrupt"] += res["corrupt"]
        return out

    def scrub_suspects(self,
                       suspects: Dict[int, List[bytes]]) -> Dict[str, int]:
        """Engine-verify the blocks a crash recovery flagged as suspect
        (the trailing, possibly-unsynced records of each node's final
        block-store segment — ``RecoveryReport.suspects``).  Recovery is
        a scrub workload: each suspect streams through the engine's
        scrub lane exactly like a sweep burst; mismatches quarantine the
        copy and enqueue repair.  Suspects no longer resident (already
        reclaimed by recovery's unregistered-resident pass) are skipped.
        Returns {scanned, corrupt, skipped}."""
        out = {"scanned": 0, "corrupt": 0, "skipped": 0}
        by_node = {nr.node.node_id: nr for nr in self.node_runtimes}
        for nid, digests in suspects.items():
            nr = by_node.get(nid)
            if nr is None or nr.node.failed:
                out["skipped"] += len(digests)
                continue
            live = [d for d in digests if nr.node.has(d)]
            out["skipped"] += len(digests) - len(live)
            res = nr.scrub_digests(live)
            out["scanned"] += res["scanned"]
            out["corrupt"] += res["corrupt"]
        return out

    def scan_under_replicated(self) -> int:
        """Enqueue digests whose healthy replica count is below the
        configured replication factor (node failures, quarantines that
        predate this runtime)."""
        mgr = self.manager
        n = 0
        for digest, locs in list(mgr.block_registry.items()):
            healthy = [nid for nid in locs if mgr.nodes[nid].has(digest)]
            if len(healthy) < mgr.replication:
                self._repair_q.put(digest)
                n += 1
        self._bump(repairs_enqueued=n)
        return n

    def repair_once(self) -> int:
        """Drain the repair queue, restoring replica counts.  Returns
        the number of replica copies created."""
        seen = set()
        placed = 0
        while True:
            try:
                digest = self._repair_q.get_nowait()
            except queue.Empty:
                break
            if digest in seen:
                continue
            seen.add(digest)
            placed += self._repair_block(digest)
        return placed

    def _repair_block(self, digest: bytes) -> int:
        """Re-replicate one digest from a healthy verified copy.  Every
        candidate source is re-hashed through the engine before it is
        trusted; sources that fail the check are quarantined in turn.
        Returns replica copies created."""
        mgr = self.manager
        locs = mgr.lookup_block(digest)
        live = [nid for nid in locs if mgr.nodes[nid].has(digest)]
        if len(live) >= mgr.replication:
            return 0                              # healed meanwhile
        src_data = None
        for nid in live:
            try:
                data = mgr.nodes[nid].get(digest)
            except (KeyError, NodeFailure):
                continue
            if digest.startswith(b"raw!") or \
                    self._digest_of(data) == digest:
                src_data = data
                break
            self._report_corruption(digest, nid)  # bad source copy
        if src_data is None:
            if mgr.lookup_block(digest) or digest in mgr.quarantined:
                self._bump(repair_lost=1)         # no healthy copy left
            return 0
        live = [nid for nid in mgr.lookup_block(digest)
                if mgr.nodes[nid].has(digest)]
        need = mgr.replication - len(live)
        placed = 0
        for node in mgr.nodes:
            if placed >= need:
                break
            if node.failed or node.has(digest):
                continue
            try:
                node.put(digest, src_data)
            except NodeFailure:
                continue
            mgr.register_block(digest, (node.node_id,))
            mgr.clear_quarantine(digest, node.node_id)
            placed += 1
        self._bump(repaired_copies=placed)
        return placed

    def gc_once(self, full: bool = True) -> int:
        """Collect retire-event orphans; ``full=True`` additionally
        sweeps the whole registry for refcount-zero digests (an
        O(registry) pass under the manager lock — the background loop
        runs it only every ``gc_full_scan_every_n`` cycles).
        Claimed/pinned digests are skipped by
        ``MetadataManager.gc_collect``; they are retried on the next
        cycle once the in-flight write commits or aborts."""
        with self._stats_lock:
            pending, self._gc_pending = self._gc_pending, []
        removed = self.manager.gc_collect(pending) if pending else 0
        if full:
            removed += self.manager.gc_collect()
        # candidates that survived only because of a transient pin/claim
        # stay pending for the next cycle; re-referenced digests drop out
        with self._stats_lock:
            reg = self.manager.block_registry
            refs = self.manager.block_refs
            self._gc_pending.extend(d for d in pending
                                    if d in reg and refs.get(d, 0) <= 0)
        self._bump(gc_collected=removed)
        return removed

    def merkle_check_once(self, samples: Optional[int] = None) -> int:
        """Spot-check sampled blocks against their file-level Merkle
        root: fetch one block of a random committed version, recompute
        its digest on the engine, and verify the membership proof from
        the version's leaf digests (``integrity.merkle_proof``).  A
        failed proof quarantines the fetched copy (=> repair).  Returns
        the number of failures found."""
        mgr = self.manager
        failures = 0
        for _ in range(samples or self.cfg.merkle_samples):
            files = mgr.list_files()
            if not files:
                break
            path = self._rng.choice(files)
            fv, locmap = mgr.get_read_plan(path)
            if fv is None or not fv.blocks:
                continue
            idx = self._rng.randrange(len(fv.blocks))
            b = fv.blocks[idx]
            if b.digest.startswith(b"raw!"):
                continue
            data = src = None
            for nid in locmap.get(b.digest) or b.nodes:
                try:
                    data, src = mgr.nodes[nid].get(b.digest), nid
                    break
                except (KeyError, NodeFailure):
                    continue
            if data is None:                     # no copy reachable
                self._repair_q.put(b.digest)
                self._bump(repairs_enqueued=1)
                continue
            leaves = [blk.digest for blk in fv.blocks]
            proof = integrity.merkle_proof(leaves, idx)
            ok = integrity.merkle_verify(self._digest_of(data), idx,
                                         proof, fv.merkle_root)
            self._bump(merkle_checks=1)
            if not ok:
                failures += 1
                self._bump(merkle_failures=1)
                self._report_corruption(b.digest, src)
        return failures

    # ------------------------------------------------------------------
    # supervisor lifecycle
    # ------------------------------------------------------------------
    def start(self):
        """Start the background threads: one scrub loop per node plus
        one maintenance loop (repair -> GC -> periodic Merkle)."""
        if self._threads:
            return
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._scrub_loop, args=(nr,),
                             daemon=True,
                             name=f"noderuntime-scrub-{nr.node.node_id}")
            for nr in self.node_runtimes]
        self._threads.append(
            threading.Thread(target=self._maintenance_loop, daemon=True,
                             name="noderuntime-maint"))
        for t in self._threads:
            t.start()

    def pause(self):
        """Suspend scrub/repair submission (in-flight bursts finish)."""
        self._resume.clear()

    def resume(self):
        self._resume.set()

    def drain(self):
        """Synchronously finish all pending repair + GC work."""
        self.repair_once()
        self.gc_once()

    def stop(self):
        """Stop and join the background threads (pending repairs are
        drained first so quarantined blocks aren't left under-replicated
        across a shutdown).  A thread that outlives the join timeout
        stays tracked with ``_stop`` still set, so it cannot resume and
        a later ``start()`` refuses until it exits."""
        self._stop.set()
        self._resume.set()
        for t in self._threads:
            t.join(timeout=60)
        self._threads = [t for t in self._threads if t.is_alive()]
        if not self._threads:
            self._stop.clear()
        self.drain()

    def snapshot_stats(self) -> Dict[str, int]:
        """Runtime counters merged with the engine's scrub-lane
        coalescing counters (scrub_jobs / scrub_launches /
        scrub_coalesced)."""
        out = dict(self.stats)
        out.update({"scrub_jobs": 0, "scrub_launches": 0,
                    "scrub_coalesced": 0})
        if self._engine is not None and self._engine._alive:
            es = self._engine.snapshot_stats()
            for k in ("scrub_jobs", "scrub_launches", "scrub_coalesced"):
                out[k] = es[k]
        out["heartbeats"] = self.heartbeats.snapshot()
        return out

    # ------------------------------------------------------------------
    # background loops
    # ------------------------------------------------------------------
    def _scrub_loop(self, nr: NodeRuntime):
        hb = self.heartbeats.heartbeat(f"scrub{nr.node.node_id}")
        try:
            while not self._stop.is_set():
                if not self._gate(hb):
                    return
                try:
                    nr.scrub_once(paced=True, hb=hb)
                except Exception:
                    pass                  # keep the scrubber thread up
                hb.beat()
                self._stop.wait(self.cfg.scrub_cycle_idle_s)
        finally:
            hb.park()                     # clean exit is dormancy

    def _maintenance_loop(self):
        hb = self.heartbeats.heartbeat("maint")
        cfg, cycle = self.cfg, 0
        try:
            self._maintenance_cycles(hb, cfg, cycle)
        finally:
            hb.park()

    def _maintenance_cycles(self, hb, cfg, cycle):
        while not self._stop.is_set():
            if not self._gate(hb):
                return
            try:
                cycle += 1
                self.repair_once()
                self.gc_once(full=(cfg.gc_full_scan_every_n > 0 and
                                   cycle % cfg.gc_full_scan_every_n == 0))
                if cfg.underrep_scan_every_n and \
                        cycle % cfg.underrep_scan_every_n == 0:
                    self.scan_under_replicated()
                if cfg.merkle_every_n and \
                        cycle % cfg.merkle_every_n == 0:
                    self.merkle_check_once()
            except Exception:
                pass                      # keep the maintenance loop up
            hb.beat()
            self._stop.wait(cfg.repair_poll_s)
