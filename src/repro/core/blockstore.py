"""Digest-addressed persistent block store for ``StorageNode``.

Blocks live in append-only segment files under a per-node directory::

    seg-<id>.blk        [record]*

    record = [u32 magic][u8 flags][u32 length][16s digest][data]
    flags: 0 = block (length data bytes follow), 1 = tombstone (none)

The digest *is* the checksum — the engine-verified scrub path
recomputes content hashes, so records carry no separate CRC.  Writes
are buffered in userspace and group-flushed (``flush()`` — the
metadata WAL calls it from its pre-sync hooks so block bytes hit disk
before the commit records that reference them).  A segment that
reaches ``segment_bytes`` is flushed + fsynced and a fresh one opened,
so at most the *final* segment can be torn by a crash.

Opening an existing directory scans the segments to re-derive the
resident-block index (later records win; tombstones erase).  The scan
is header-walking only — O(#records) seeks, not O(bytes) hashing —
and it truncates a torn trailing record.  Every block whose record
lives in the final (possibly-torn) segment is reported in
``suspects``: recovery hands those to the engine-verified scrub path
rather than trusting them, which is exactly the paper's point —
recovery is a hashing workload the accelerator absorbs.
"""
from __future__ import annotations

import os
import struct
import threading
from typing import Dict, List, Optional, Tuple

from repro.obs import MetricsRegistry

from .faultinject import CrashPoint, FaultInjector

_REC = struct.Struct("<IBI16s")
MAGIC = 0x314B4C42          # "BLK1"
F_BLOCK, F_TOMB = 0, 1

_SEG_PREFIX, _SEG_SUFFIX = "seg-", ".blk"


class BlockStoreError(RuntimeError):
    pass


class BlockStore:
    """Append-only segmented block store addressed by 16-byte digest."""

    def __init__(self, path: str, *, segment_bytes: int = 8 << 20,
                 fsync: bool = True, fault: Optional[FaultInjector] = None):
        self.path = path
        self.segment_bytes = int(segment_bytes)
        self.fsync_enabled = fsync
        self.fault = fault
        os.makedirs(path, exist_ok=True)
        self._lock = threading.Lock()
        self._crashed = False
        # digest -> (seg_id, data_offset, length)
        self._index: Dict[bytes, Tuple[int, int, int]] = {}  # guarded by self._lock
        self._handles: Dict[int, object] = {}  # guarded by self._lock
        self.metrics = MetricsRegistry()
        self.stats = self.metrics.group(
            ("puts", "skipped_puts", "replaced", "drops", "flushes",
             "truncated_bytes", "scanned_records"))
        self.suspects: List[bytes] = []
        self._scan()

    # ------------------------------------------------------------ recovery

    def _seg_path(self, seg_id: int) -> str:
        return os.path.join(self.path,
                            f"{_SEG_PREFIX}{seg_id:012d}{_SEG_SUFFIX}")

    def _scan(self):  # ra: disable=RA01(runs from __init__ pre-publication, single-threaded)
        seg_ids = []
        for name in os.listdir(self.path):
            if name.startswith(_SEG_PREFIX) and name.endswith(_SEG_SUFFIX):
                try:
                    seg_ids.append(int(name[len(_SEG_PREFIX):
                                            len(name) - len(_SEG_SUFFIX)]))
                except ValueError:
                    continue
        seg_ids.sort()
        last_seg_digests: List[bytes] = []
        for seg_id in seg_ids:
            full = self._seg_path(seg_id)
            size = os.path.getsize(full)
            last_seg_digests = []
            with open(full, "rb") as fh:
                buf = fh.read()
            off = 0
            while off + _REC.size <= size:
                magic, flags, length, digest = _REC.unpack_from(buf, off)
                if magic != MAGIC or flags not in (F_BLOCK, F_TOMB):
                    break
                if flags == F_TOMB:
                    if length != 0:
                        break
                    self._index.pop(digest, None)
                    off += _REC.size
                    self.stats.inc("scanned_records")
                    continue
                end = off + _REC.size + length
                if length > self.segment_bytes * 4 or end > size:
                    break       # torn data tail
                self._index[digest] = (seg_id, off + _REC.size, length)
                last_seg_digests.append(digest)
                self.stats.inc("scanned_records")
                off = end
            if off != size:     # torn tail: drop the garbage
                self.stats.inc("truncated_bytes", size - off)
                with open(full, "r+b") as fh:
                    fh.truncate(off)
        if seg_ids:
            self._cur_seg = seg_ids[-1]  # guarded by self._lock
            self._cur_size = os.path.getsize(  # guarded by self._lock
                self._seg_path(self._cur_seg))
        else:
            self._cur_seg = 0
            self._cur_size = 0
        # only the final segment can have unsynced/torn records: its
        # resident blocks are suspects until the engine re-verifies them
        # (deduped — a replace rewrite appends a second record for the
        # same digest, but there is only one resident copy to verify)
        self.suspects = [d for d in dict.fromkeys(last_seg_digests)
                         if d in self._index]
        self._buf = bytearray()  # guarded by self._lock
        self._buf_base = self._cur_size  # disk offset where _buf begins; guarded by self._lock
        self._pending: Dict[bytes, bytes] = {}  # guarded by self._lock

    # ------------------------------------------------------------ helpers

    def _check_alive(self):
        if self._crashed:
            raise CrashPoint("blockstore", -1)

    def _fire(self, site: str, **ctx):
        if self.fault is None:
            return None
        try:
            return self.fault.fire(site, **ctx)
        except CrashPoint:
            self._crashed = True
            raise

    def _append_fh(self):  # ra: holds self._lock
        fh = self._handles.get(-self._cur_seg - 1)
        if fh is None:
            fh = open(self._seg_path(self._cur_seg), "ab")
            self._handles[-self._cur_seg - 1] = fh
        return fh

    def _rotate_locked(self):
        self._flush_locked(rotate_fsync=True)
        key = -self._cur_seg - 1
        fh = self._handles.pop(key, None)
        if fh is not None:
            fh.close()
        self._cur_seg += 1
        self._cur_size = 0
        self._buf_base = 0

    def _flush_locked(self, rotate_fsync: bool = False):
        if not self._buf:
            if rotate_fsync:
                fh = self._handles.get(-self._cur_seg - 1)
                if fh is not None:
                    fh.flush()
                    if self.fsync_enabled:
                        os.fsync(fh.fileno())
            return
        act = self._fire("blockstore.fsync", seg=self._cur_seg)
        if act == "skip":
            # lying disk: report success, keep bytes in userspace
            return
        fh = self._append_fh()
        fh.write(bytes(self._buf))
        fh.flush()
        if self.fsync_enabled:
            os.fsync(fh.fileno())
        self._buf_base += len(self._buf)
        self._buf.clear()
        self._pending.clear()
        self.stats.inc("flushes")

    # ------------------------------------------------------------ API

    def put(self, digest: bytes, data: bytes, replace: bool = False):
        """Append one block.  Re-putting a resident digest is a no-op
        (content-addressed dedup) unless ``replace`` — used by repair to
        overwrite a corrupt resident copy."""
        if len(digest) != 16:
            raise BlockStoreError(f"digest must be 16 bytes, got {len(digest)}")
        with self._lock:
            self._check_alive()
            if digest in self._index and not replace:
                self.stats.inc("skipped_puts")
                return
            act = self._fire("blockstore.put", digest=digest)
            rec = _REC.pack(MAGIC, F_BLOCK, len(data), digest) + bytes(data)
            if act == "torn":
                # persist a partial record directly, then die
                torn = rec[:max(_REC.size // 2, len(rec) - max(1, len(rec) // 3))]
                self._flush_locked()
                fh = self._append_fh()
                fh.write(torn)
                fh.flush()
                if self.fsync_enabled:
                    os.fsync(fh.fileno())  # ra: disable=RA04(fault-injection branch: simulated torn write must land before the crash)
                self._crashed = True
                raise CrashPoint("blockstore.put:torn", -1)
            if digest in self._index:
                self.stats.inc("replaced")
            off = self._cur_size
            self._buf += rec
            self._pending[digest] = bytes(data)
            self._index[digest] = (self._cur_seg, off + _REC.size, len(data))
            self._cur_size += len(rec)
            self.stats.inc("puts")
            if self._cur_size >= self.segment_bytes:
                self._rotate_locked()

    def get(self, digest: bytes) -> Optional[bytes]:
        with self._lock:
            self._check_alive()
            loc = self._index.get(digest)
            if loc is None:
                return None
            if digest in self._pending:
                return self._pending[digest]
            seg_id, off, length = loc
            fh = self._handles.get(seg_id)
            if fh is None:
                try:
                    fh = open(self._seg_path(seg_id), "rb")
                except FileNotFoundError:
                    return None
                self._handles[seg_id] = fh
            fh.seek(off)
            data = fh.read(length)
            if len(data) != length and seg_id == self._cur_seg:
                # record straddles the unflushed buffer
                base = self._buf_base
                if off >= base:
                    rel = off - base
                    data = bytes(self._buf[rel:rel + length])
                elif off + length > base:
                    data += bytes(self._buf[:off + length - base])
            return data if len(data) == length else None

    def has(self, digest: bytes) -> bool:
        with self._lock:
            return digest in self._index and not self._crashed

    def digests(self) -> List[bytes]:
        with self._lock:
            return list(self._index)

    def drop(self, digest: bytes):
        """Tombstone a block (logical delete; space reclaim is a
        compaction concern, not attempted here)."""
        with self._lock:
            self._check_alive()
            if digest not in self._index:
                return
            self._fire("blockstore.drop", digest=digest)
            rec = _REC.pack(MAGIC, F_TOMB, 0, digest)
            self._buf += rec
            self._cur_size += len(rec)
            self._index.pop(digest, None)
            self._pending.pop(digest, None)
            self.stats.inc("drops")

    def flush(self):
        """Write + fsync buffered records (WAL pre-sync hook target)."""
        with self._lock:
            self._check_alive()
            self._flush_locked()

    def snapshot_stats(self) -> dict:
        return dict(self.stats)

    def used_bytes(self) -> int:
        with self._lock:
            return sum(length for _, _, length in self._index.values())

    def clear(self):
        """Wipe the store (simulated disk replacement on a rebuilt node)."""
        with self._lock:
            self._check_alive()
            for fh in self._handles.values():
                try:
                    fh.close()
                except OSError:
                    pass
            self._handles.clear()
            for name in os.listdir(self.path):
                if name.startswith(_SEG_PREFIX) and name.endswith(_SEG_SUFFIX):
                    os.unlink(os.path.join(self.path, name))
            self._index.clear()
            self._buf = bytearray()
            self._pending = {}
            self._cur_seg += 1
            self._cur_size = 0
            self._buf_base = 0
            self.suspects = []

    def crash(self):
        with self._lock:
            self._crashed = True

    @property
    def crashed(self) -> bool:
        return self._crashed

    def close(self):
        with self._lock:
            if not self._crashed:
                self._flush_locked()
            for fh in self._handles.values():
                try:
                    fh.close()
                except OSError:
                    pass
            self._handles.clear()
            self._crashed = True
