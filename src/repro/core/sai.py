"""Client System Access Interface (SAI) — the MosaStore client analog.

Implements the paper's write path (Figure 3): buffered writes are chunked
(fixed-size or content-based via the accelerator), chunk hashes are
computed by HashTPU through the CrystalTPU offload engine, compared
against the block registry's indexed digest->locations map for similarity
detection, and only novel blocks are striped over the storage nodes.  The
read path re-hashes fetched blocks (implicit integrity check of content
addressing) and falls back to block replicas on node failure.

All hashing — direct block digests, sliding-window CDC, gear CDC — flows
through the offload engine (``SAI.engine``); an SAI constructed without an
explicit engine shares the process-wide default so concurrent writers'
hash requests coalesce into common batch launches.

Async pipeline (paper Table 1, overlapped execution): ``write_async``
returns a :class:`WriteFuture` and runs chunk -> hash -> store as staged
pipeline threads, so the chunk/hash stages of write i+1 overlap the store
stage of write i, and the engine fuses the resulting burst of hash
requests into batched kernel launches.

Configurations mirror the paper's evaluation matrix:
  ca='none'                 -> non-CA (direct write, no hashing)
  ca='fixed'                -> fixed-size blocks + direct hashing
  ca='cdc'                  -> content-based chunking (sliding-window MD5)
  ca='cdc-gear'             -> beyond-paper gear-hash CDC
  hasher='tpu' | 'cpu' | 'infinite'   ('infinite' = the paper's CA-Infinite
        oracle: hash computation takes zero time — upper performance bound)
"""
from __future__ import annotations

import hashlib
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import chunking
from repro.core import crystal as crystal_mod
from repro.core.castore import BlockMeta, MetadataManager, NodeFailure
from repro.core.crystal import CrystalTPU


@dataclass
class SAIConfig:
    ca: str = "fixed"                 # none | fixed | cdc | cdc-gear
    block_size: int = 1 << 20         # fixed-size block bytes
    avg_chunk: int = 1 << 20          # CDC target chunk
    min_chunk: int = 256 << 10
    max_chunk: int = 4 << 20
    window: int = 48
    stride: int = 4
    hasher: str = "tpu"               # tpu | cpu | infinite
    stripe_width: int = 4


@dataclass
class WriteStats:
    total_bytes: int = 0
    new_bytes: int = 0
    new_blocks: int = 0
    dup_blocks: int = 0
    stage_s: Dict[str, float] = field(default_factory=dict)

    @property
    def similarity(self) -> float:
        total = self.new_blocks + self.dup_blocks
        return self.dup_blocks / total if total else 0.0


class WriteFuture:
    """Handle for an in-flight pipelined write; resolves to WriteStats."""

    def __init__(self):
        self._done = threading.Event()
        self._stats: Optional[WriteStats] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> WriteStats:
        if not self._done.wait(timeout):
            raise TimeoutError("write still in flight")
        if self._error is not None:
            raise self._error
        return self._stats

    wait = result

    def _resolve(self, stats: WriteStats):
        self._stats = stats
        self._done.set()

    def _fail(self, error: BaseException):
        self._error = error
        self._done.set()


class _HashHandle:
    """Uniform handle over an in-flight chunk-digest computation: either
    host digests computed eagerly (cpu / infinite / empty input) or an
    offload-engine job whose result is materialized on wait()."""

    def __init__(self, job: Optional[crystal_mod.Job] = None,
                 digests: Optional[List[bytes]] = None):
        self._job = job
        self._digests = digests

    def wait(self) -> List[bytes]:
        if self._digests is None:
            rows = self._job.wait()                 # [n, 16] uint8
            self._digests = [rows[i].tobytes() for i in range(rows.shape[0])]
        return self._digests


_ORACLE_COUNTER = [0]
_ORACLE_LOCK = threading.Lock()


class SAI:
    def __init__(self, manager: MetadataManager, config: SAIConfig,
                 crystal: Optional[CrystalTPU] = None):
        self.manager = manager
        self.cfg = config
        self.crystal = crystal
        self._pipe_lock = threading.Lock()
        self._chunk_q: Optional[queue.Queue] = None
        self._store_q: Optional[queue.Queue] = None
        self._pipe_threads: List[threading.Thread] = []

    # ------------------------------------------------------------------
    # hashing backends — everything flows through the offload engine
    # ------------------------------------------------------------------
    @property
    def engine(self) -> CrystalTPU:
        """The offload engine: the explicit one, else the process-wide
        shared default (so independent writers coalesce)."""
        if self.crystal is None:
            self.crystal = crystal_mod.default_engine()
        return self.crystal

    def _pack_chunks(self, chunks: List[bytes]):
        """Pack chunks into padded rows for a direct-hash request.

        Canonical block digest = MD5( zero-pad-to-word(data) ||
        u32_le(byte_length) ): the length trailer disambiguates chunks
        that differ only in trailing zero padding (CDC boundaries are
        byte-exact).  Row width is bucketed to a power of two to bound
        jit retraces across writes with ragged max-chunk lengths."""
        seg = max(len(c) for c in chunks)
        seg = (seg + 3) // 4 * 4 + 4
        seg = 1 << (seg - 1).bit_length()
        rows = np.zeros((len(chunks), seg), np.uint8)
        lens = np.zeros((len(chunks),), np.int64)
        for i, c in enumerate(chunks):
            padded = (len(c) + 3) // 4 * 4
            rows[i, :len(c)] = np.frombuffer(c, np.uint8)
            rows[i, padded:padded + 4] = np.frombuffer(
                np.uint32(len(c)).tobytes(), np.uint8)
            lens[i] = padded + 4
        return rows, lens

    def _submit_hash(self, chunks: List[bytes]) -> _HashHandle:
        """Start hashing ``chunks``; non-blocking on the tpu path."""
        if not chunks:
            return _HashHandle(digests=[])
        if self.cfg.hasher in ("infinite", "cpu"):
            # 'infinite' is the paper's CA-Infinite oracle — its hashing
            # time is excluded from the timed stages by the caller.
            return _HashHandle(digests=[block_digest_cpu(c)
                                        for c in chunks])
        rows, lens = self._pack_chunks(chunks)
        return _HashHandle(job=self.engine.submit(
            "direct", rows, {"lens": lens}))

    def _hash_chunks(self, chunks: List[bytes]) -> List[bytes]:
        return self._submit_hash(chunks).wait()

    def _boundaries(self, data: bytes) -> List[int]:
        cfg = self.cfg
        if len(data) == 0:
            return []
        if cfg.ca == "fixed":
            n = (len(data) + cfg.block_size - 1) // cfg.block_size
            return [min((i + 1) * cfg.block_size, len(data))
                    for i in range(n)]
        if cfg.ca == "cdc":
            if cfg.hasher == "tpu":
                job = self.engine.submit(
                    "sliding", np.frombuffer(data, np.uint8),
                    {"window": cfg.window, "stride": cfg.stride})
                hashes = job.wait()
            else:
                hashes = _cpu_sliding(data, cfg.window, cfg.stride)
            return chunking.select_boundaries(
                hashes, len(data), window=cfg.window, stride=cfg.stride,
                avg_chunk=cfg.avg_chunk, min_chunk=cfg.min_chunk,
                max_chunk=cfg.max_chunk)
        if cfg.ca == "cdc-gear":
            if cfg.hasher == "tpu":
                job = self.engine.submit(
                    "gear", np.frombuffer(data, np.uint8), {})
                hashes = job.wait()
            else:
                hashes = _cpu_gear(data)
            return chunking.select_boundaries(
                hashes, len(data), window=1, stride=1,
                avg_chunk=cfg.avg_chunk, min_chunk=cfg.min_chunk,
                max_chunk=cfg.max_chunk)
        raise ValueError(self.cfg.ca)

    # ------------------------------------------------------------------
    # store stage (shared by sync write, async pipeline, checkpointer)
    # ------------------------------------------------------------------
    def _store_chunks(self, path: str, total_len: int,
                      chunks: List[bytes], digests: List[bytes],
                      stats: WriteStats) -> WriteStats:
        """Dedup against the indexed digest->locations registry, store
        novel blocks, commit the block-map."""
        mgr = self.manager
        locmap = mgr.lookup_blocks(digests)       # one lock acquisition
        blocks: List[BlockMeta] = []
        for chunk, digest in zip(chunks, digests):
            locs = locmap.get(digest)
            if locs:
                stats.dup_blocks += 1
            else:
                locs = mgr.place(digest)
                for nid in locs:
                    mgr.nodes[nid].put(digest, chunk)
                mgr.register_block(digest, locs)
                locmap[digest] = locs             # intra-write dups
                stats.new_blocks += 1
                stats.new_bytes += len(chunk)
            blocks.append(BlockMeta(digest, len(chunk), tuple(locs)))
        mgr.commit_blockmap(path, blocks, total_len)
        return stats

    def _write_raw(self, path: str, data: bytes) -> WriteStats:
        """ca='none': direct striping, no hashing (synthetic digests)."""
        cfg, mgr = self.cfg, self.manager
        stats = WriteStats(total_bytes=len(data))
        t0 = time.perf_counter()
        bs = cfg.block_size
        blocks = []
        for i in range(0, max(len(data), 1), bs):
            chunk = data[i:i + bs]
            with _ORACLE_LOCK:
                _ORACLE_COUNTER[0] += 1
                n = _ORACLE_COUNTER[0]
            digest = b"raw!" + n.to_bytes(12, "little")
            locs = mgr.place(digest)
            for nid in locs:
                mgr.nodes[nid].put(digest, chunk)
            mgr.register_block(digest, locs)
            blocks.append(BlockMeta(digest, len(chunk), locs))
            stats.new_blocks += 1
            stats.new_bytes += len(chunk)
        mgr.commit_blockmap(path, blocks, len(data))
        stats.stage_s = {"store": time.perf_counter() - t0}
        return stats

    # ------------------------------------------------------------------
    # write paths
    # ------------------------------------------------------------------
    def write(self, path: str, data: bytes) -> WriteStats:
        cfg = self.cfg
        if cfg.ca == "none":
            return self._write_raw(path, data)
        stats = WriteStats(total_bytes=len(data))
        t0 = time.perf_counter()
        bounds = self._boundaries(data)
        chunks = chunking.split_chunks(data, bounds)
        t1 = time.perf_counter()
        digests = self._submit_hash(chunks).wait()
        t2 = t1 if cfg.hasher == "infinite" else time.perf_counter()
        self._store_chunks(path, len(data), chunks, digests, stats)
        t3 = time.perf_counter()
        stats.stage_s = {"chunk": t1 - t0, "hash": t2 - t1,
                         "store": t3 - t2}
        return stats

    def write_async(self, path: str, data: bytes) -> WriteFuture:
        """Pipelined write: chunk+hash of this write overlap the store
        stage of the previous one (and hash requests from back-to-back
        writes coalesce in the engine).  Commit order matches submission
        order, so versioning is identical to sequential sync writes."""
        fut = WriteFuture()
        with self._pipe_lock:
            self._ensure_pipeline()
            self._chunk_q.put((fut, path, bytes(data)))
        return fut

    def flush(self):
        """Block until every pipelined write has committed."""
        with self._pipe_lock:
            chunk_q, store_q = self._chunk_q, self._store_q
        if chunk_q is not None:
            chunk_q.join()
            store_q.join()

    def close(self):
        """Drain and stop the pipeline threads (idempotent).  In-flight
        writes complete first; a later write_async restarts the
        pipeline.  SAIs that only use sync ``write`` have no threads."""
        with self._pipe_lock:
            chunk_q, threads = self._chunk_q, self._pipe_threads
            self._chunk_q = self._store_q = None
            self._pipe_threads = []
        if chunk_q is None:
            return
        chunk_q.put(None)            # chunk worker forwards to store
        for t in threads:
            t.join(timeout=60)

    def _ensure_pipeline(self):
        # caller holds _pipe_lock
        if self._chunk_q is not None:
            return
        self._chunk_q = queue.Queue()
        self._store_q = queue.Queue()
        self._pipe_threads = [
            threading.Thread(target=target, args=(self._chunk_q,
                                                  self._store_q),
                             daemon=True, name=name)
            for name, target in (("sai-chunk", self._chunk_loop),
                                 ("sai-store", self._store_loop))]
        for t in self._pipe_threads:
            t.start()

    def _chunk_loop(self, chunk_q, store_q):
        while True:
            item = chunk_q.get()
            if item is None:                         # close() sentinel
                store_q.put(None)
                chunk_q.task_done()
                return
            fut, path, data = item
            try:
                if self.cfg.ca == "none":
                    store_q.put((fut, path, data, None, None, {}))
                    continue
                t0 = time.perf_counter()
                bounds = self._boundaries(data)
                chunks = chunking.split_chunks(data, bounds)
                t1 = time.perf_counter()
                handle = self._submit_hash(chunks)   # non-blocking (tpu)
                store_q.put((fut, path, data, chunks, handle,
                             {"chunk": t1 - t0, "t_hash0": t1}))
            except BaseException as e:
                fut._fail(e)
            finally:
                chunk_q.task_done()

    def _store_loop(self, chunk_q, store_q):
        while True:
            item = store_q.get()
            if item is None:                         # close() sentinel
                store_q.task_done()
                return
            fut, path, data, chunks, handle, times = item
            try:
                if handle is None:                   # ca='none'
                    fut._resolve(self._write_raw(path, data))
                    continue
                stats = WriteStats(total_bytes=len(data))
                digests = handle.wait()
                t2 = time.perf_counter()
                self._store_chunks(path, len(data), chunks, digests,
                                   stats)
                t3 = time.perf_counter()
                hash_s = 0.0 if self.cfg.hasher == "infinite" \
                    else t2 - times["t_hash0"]
                stats.stage_s = {"chunk": times["chunk"],
                                 "hash": hash_s, "store": t3 - t2}
                fut._resolve(stats)
            except BaseException as e:
                fut._fail(e)
            finally:
                store_q.task_done()

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def read(self, path: str, version: int = -1,
             verify: bool = True) -> bytes:
        fv = self.manager.get_blockmap(path, version)
        if fv is None:
            raise FileNotFoundError(path)
        out = bytearray()
        for b in fv.blocks:
            data = None
            locs = self.manager.lookup_block(b.digest) or b.nodes
            last_err: Optional[Exception] = None
            for nid in locs:
                try:
                    data = self.manager.nodes[nid].get(b.digest)
                    break
                except (NodeFailure, KeyError) as e:
                    last_err = e
            if data is None:
                raise NodeFailure(
                    f"block {b.digest.hex()[:8]} unavailable: {last_err}")
            if verify and not b.digest.startswith(b"raw!"):
                if block_digest_cpu(data) != b.digest:
                    raise IOError(
                        f"integrity check failed for {b.digest.hex()[:8]}")
            out += data
        return bytes(out[:fv.total_len])


def _pad4(data: bytes) -> bytes:
    return data + b"\x00" * ((-len(data)) % 4)


def block_digest_cpu(data: bytes) -> bytes:
    """Canonical block digest (hashlib path):
    MD5( pad4(data) || u32_le(len) ) — identical to the TPU kernel path."""
    return hashlib.md5(
        _pad4(data) + np.uint32(len(data)).tobytes()).digest()


def _cpu_sliding(data: bytes, window: int, stride: int) -> np.ndarray:
    """Single-core CPU sliding-window hashing (the paper's CPU baseline)."""
    n = (len(data) - window) // stride + 1
    out = np.empty((n,), np.uint32)
    view = memoryview(data)
    for i in range(n):
        o = i * stride
        out[i] = int.from_bytes(
            hashlib.md5(view[o:o + window]).digest()[:4], "little")
    return out


def _cpu_gear(data: bytes, vectorized: bool = True) -> np.ndarray:
    """Gear hash (FastCDC recurrence) on the CPU.

    ``vectorized`` uses the 32-tap convolution form (SIMD-style numpy —
    the optimized CPU implementation); ``vectorized=False`` runs the
    literal sequential recurrence (tests assert both are identical)."""
    import numpy as _np
    b = _np.frombuffer(data, _np.uint8).astype(_np.uint32) + 1
    # mix32
    x = b.copy()
    x ^= x >> 16
    x = (x * _np.uint32(0x85EBCA6B)) & _np.uint32(0xFFFFFFFF)
    x ^= x >> 13
    x = (x * _np.uint32(0xC2B2AE35)) & _np.uint32(0xFFFFFFFF)
    x ^= x >> 16
    if vectorized:
        h = x.copy()
        for j in range(1, 32):
            h[j:] += x[:-j] << _np.uint32(j)
        return h
    acc = 0
    out = _np.empty(len(b), _np.uint32)
    for i in range(len(b)):
        acc = ((acc << 1) + int(x[i])) & 0xFFFFFFFF
        out[i] = acc
    return out
