"""Client System Access Interface (SAI) — the MosaStore client analog.

Implements the paper's write path (Figure 3): buffered writes are chunked
(fixed-size or content-based via the accelerator), chunk hashes are
computed by HashTPU through the CrystalTPU offload engine, compared
against the block registry's indexed digest->locations map for similarity
detection, and only novel blocks are striped over the storage nodes.  The
read path re-hashes fetched blocks (the paper's "traditional system that
uses hashing to preserve data integrity") and falls back to block
replicas on node failure.

All hashing — direct block digests, sliding-window CDC, gear CDC, and
read-path verification — flows through the offload engine
(``SAI.engine``); an SAI constructed without an explicit engine shares
the process-wide default so concurrent writers' and readers' hash
requests coalesce into common batch launches.

Async write pipeline (paper Table 1, overlapped execution):
``write_async`` returns a :class:`WriteFuture` and runs chunk -> hash ->
store as staged pipeline threads, so the chunk/hash stages of write i+1
overlap the store stage of write i, and the engine fuses the resulting
burst of hash requests into batched kernel launches.  The store stage is
sharded into per-path commit lanes (``SAIConfig.store_lanes``) hashed by
path, so concurrent writers to different paths no longer serialize on a
single store worker while commits stay in submission order per path.

Read/verify pipeline: ``read`` gathers all fetched blocks and verifies
them with ONE fused ``direct`` hash request (digest comparison on the
host — zero per-block ``hashlib`` calls on the tpu path), instead of the
per-block host hashing the paper shows must be amortized via batching.
``read_async`` returns a :class:`ReadFuture` and runs fetch -> verify ->
assemble as staged pipeline threads with replica failover retained:
verify of read i overlaps fetch of read i+1, and concurrent readers'
verify requests coalesce across SAIs through the shared engine.

``read_range(path, offset, length)`` is the Merkle-proof partial read:
only the covering blocks are fetched, and each is verified against the
version's stored ``merkle_root`` via ``integrity.merkle_proof`` instead
of re-reading (or re-hashing) the whole version.

A verify failure no longer kills the read outright: the corrupt copy is
reported to the metadata manager as a quarantine hint (feeding the node
runtime's repair pipeline, repro.core.noderuntime) and the block is
speculatively re-fetched from the next replica; IOError is raised only
when every replica fails its digest check.  An optional block-level LRU
read cache (``SAIConfig.read_cache_bytes``, default off) serves repeat
reads of hot verified blocks without touching the nodes or the engine
(hit/miss counters in ``SAI.read_stats``).

Configurations mirror the paper's evaluation matrix:
  ca='none'                 -> non-CA (direct write, no hashing)
  ca='fixed'                -> fixed-size blocks + direct hashing
  ca='cdc'                  -> content-based chunking (sliding-window MD5)
  ca='cdc-gear'             -> beyond-paper gear-hash CDC
  hasher='tpu' | 'cpu' | 'infinite'   ('infinite' = the paper's CA-Infinite
        oracle: hash computation takes zero time — upper performance bound)
"""
from __future__ import annotations

import hashlib
import os
import queue
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import chunking
from repro.core import crystal as crystal_mod
from repro.core import integrity
from repro.core.castore import BlockMeta, MetadataManager, NodeFailure
from repro.core.crystal import CrystalTPU
from repro.obs import HeartbeatBoard, MetricsRegistry, Trace


@dataclass
class SAIConfig:
    ca: str = "fixed"                 # none | fixed | cdc | cdc-gear
    block_size: int = 1 << 20         # fixed-size block bytes
    avg_chunk: int = 1 << 20          # CDC target chunk
    min_chunk: int = 256 << 10
    max_chunk: int = 4 << 20
    window: int = 48
    stride: int = 4
    hasher: str = "tpu"               # tpu | cpu | infinite
    stripe_width: int = 4
    store_lanes: int = 4              # parallel per-path commit lanes
    read_cache_bytes: int = 0         # block-level LRU read cache budget
    #                                   (0 = off); hits skip fetch+verify
    lane: str = "fg"                  # engine priority lane for every
    #                                   hash submission: 'fg' | 'batch' |
    #                                   'scrub' (gateway QoS classes map
    #                                   tenants onto these)
    durable_sync: bool = True         # with a WAL-backed manager, block
    #                                   each write until its commit
    #                                   record (and the block bytes it
    #                                   references) survive a crash —
    #                                   one group-commit fsync wait, not
    #                                   per-block fsyncs.  False =
    #                                   eventual durability (the flush
    #                                   interval).  No-op for in-memory
    #                                   stores.


@dataclass
class WriteStats:
    total_bytes: int = 0
    new_bytes: int = 0
    new_blocks: int = 0
    dup_blocks: int = 0
    stage_s: Dict[str, float] = field(default_factory=dict)

    @property
    def similarity(self) -> float:
        total = self.new_blocks + self.dup_blocks
        return self.dup_blocks / total if total else 0.0


class StoreIOError(IOError):
    """A store-stage block write failed (disk full, permissions, torn
    device).  Carries the failing path/digest/node so a
    ``WriteFuture.result()`` raises actionable context instead of the
    bare OSError the pipeline thread caught."""

    def __init__(self, path: str, digest: bytes, node_id: int,
                 cause: BaseException):
        super().__init__(
            f"store stage failed for {path!r} block {digest.hex()} "
            f"on node {node_id}: {cause}")
        self.path = path
        self.digest = digest
        self.node_id = node_id
        self.cause = cause


class WriteFuture:
    """Handle for an in-flight pipelined write; resolves to WriteStats."""

    def __init__(self):
        self._done = threading.Event()
        self._stats: Optional[WriteStats] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> WriteStats:
        if not self._done.wait(timeout):
            raise TimeoutError("write still in flight")
        if self._error is not None:
            raise self._error
        return self._stats

    wait = result

    def _resolve(self, stats: WriteStats):
        self._stats = stats
        self._done.set()

    def _fail(self, error: BaseException):
        self._error = error
        self._done.set()


class ReadFuture:
    """Handle for an in-flight pipelined read; resolves to the file
    bytes (verified when the read was submitted with verify=True)."""

    def __init__(self):
        self._done = threading.Event()
        self._data: Optional[bytes] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> bytes:
        if not self._done.wait(timeout):
            raise TimeoutError("read still in flight")
        if self._error is not None:
            raise self._error
        return self._data

    wait = result

    def _resolve(self, data: bytes):
        self._data = data
        self._done.set()

    def _fail(self, error: BaseException):
        self._error = error
        self._done.set()


class _HashHandle:
    """Uniform handle over an in-flight chunk-digest computation: either
    host digests computed eagerly (cpu / infinite / empty input) or one
    or more offload-engine jobs — a whale submission splits into
    independently packed chunk groups (see ``SAI._submit_hash``) —
    whose digests are materialized in submission order on wait()."""

    def __init__(self, jobs: Optional[List[crystal_mod.Job]] = None,
                 digests: Optional[List[bytes]] = None):
        self._jobs = jobs or []
        self._digests = digests

    def wait(self) -> List[bytes]:
        if self._digests is None:
            out: List[bytes] = []
            for job in self._jobs:
                rows = job.wait()                   # [n, 16] uint8
                out.extend(rows[i].tobytes()
                           for i in range(rows.shape[0]))
            self._digests = out
        return self._digests


def _trace_engine_jobs(trace: "Trace", handle: _HashHandle) -> None:
    """Turn the engine jobs' t_submit/t_exec stamps into
    engine/queue + engine/launch spans (per device, per lane).  Only
    meaningful after ``handle.wait()``; cpu/infinite hashers have no
    engine jobs and contribute no spans."""
    for job in handle._jobs:
        if job.t_exec1 <= 0.0:
            continue
        if job.t_submit > 0.0:
            trace.add_span("engine/queue", job.t_submit, job.t_exec0,
                           device=job.device_index, lane=job.lane)
        trace.add_span("engine/launch", job.t_exec0, job.t_exec1,
                       device=job.device_index, lane=job.lane)


_ORACLE_COUNTER = [0]
_ORACLE_LOCK = threading.Lock()
# ca='none' digests are synthetic, not content-derived: a per-process
# nonce keeps a restarted process from colliding with raw digests a
# durable store persisted under the previous process's counter values
_ORACLE_NONCE = os.urandom(4)


class SAI:
    def __init__(self, manager: MetadataManager, config: SAIConfig,
                 crystal: Optional[CrystalTPU] = None):
        self.manager = manager
        self.cfg = config
        self.crystal = crystal
        # block-level LRU read cache (digest -> verified bytes), active
        # when cfg.read_cache_bytes > 0; hits skip fetch AND re-verify
        # (entries are inserted only after a digest check passed)
        self._cache: "OrderedDict[bytes, bytes]" = OrderedDict()
        self._cache_used = 0
        self._cache_lock = threading.Lock()
        # atomic counters: concurrent read_async verify/fetch threads
        # bump these without holding the cache lock
        self.metrics = MetricsRegistry()
        self.read_stats = self.metrics.group(
            ("cache_hits", "cache_misses", "refetches",
             "cache_invalidations"))
        # a quarantine anywhere in a digest's replica set condemns the
        # cached copy too: the entry was verified at insertion, but its
        # provenance is now suspect, so the next read must re-fetch and
        # re-verify against the surviving replicas instead of serving
        # it.  Registered lazily on first cache use and removed by
        # close(), so closed SAIs don't leak into a long-lived
        # manager's listener list.
        self._cache_listener_on = False
        # pipeline-stage liveness: each stage thread beats per item and
        # parks across its blocking queue get (idle pipeline = healthy)
        self.heartbeats = HeartbeatBoard()
        self._pipe_lock = threading.Lock()
        self._chunk_q: Optional[queue.Queue] = None
        self._store_qs: Optional[List[queue.Queue]] = None
        self._fetch_q: Optional[queue.Queue] = None
        self._verify_q: Optional[queue.Queue] = None
        self._pipe_threads: List[threading.Thread] = []

    # ------------------------------------------------------------------
    # hashing backends — everything flows through the offload engine
    # ------------------------------------------------------------------
    @property
    def engine(self) -> CrystalTPU:
        """The offload engine: the explicit one, else the process-wide
        shared default (so independent writers coalesce)."""
        if self.crystal is None:
            self.crystal = crystal_mod.default_engine()
        return self.crystal

    def _pack_chunks(self, chunks: List[bytes]):
        return pack_blocks(chunks)

    def _submit_hash(self, chunks: List[bytes]) -> _HashHandle:
        """Start hashing ``chunks``; non-blocking on the tpu path.

        A whale submission (total bytes past twice the engine's shard
        threshold) splits into contiguous chunk groups packed and
        submitted independently: each group pads only to its own widest
        chunk (less padding than one global-width pack), hashing of
        group i overlaps the packing of group i+1, and the engine's
        load-aware dispatch spreads the groups across the device mesh.
        Digest order is preserved — groups are contiguous and the
        handle concatenates them in submission order."""
        if not chunks:
            return _HashHandle(digests=[])
        if self.cfg.hasher in ("infinite", "cpu"):
            # 'infinite' is the paper's CA-Infinite oracle — its hashing
            # time is excluded from the timed stages by the caller.
            return _HashHandle(digests=[block_digest_cpu(c)
                                        for c in chunks])
        eng = self.engine
        jobs = []
        for lo, hi in self._shard_groups(chunks, eng):
            rows, lens = self._pack_chunks(chunks[lo:hi])
            jobs.append(eng.submit("direct", rows, {"lens": lens},
                                   lane=self.cfg.lane))
        return _HashHandle(jobs=jobs)

    @staticmethod
    def _shard_groups(chunks: List[bytes], eng) -> List[tuple]:
        """Contiguous ``(lo, hi)`` chunk-index groups for one hash
        submission: a single group normally, several balanced-byte
        groups for whale leaves (big checkpoint tensors) so the engine
        mesh can hash them in parallel."""
        total = sum(len(c) for c in chunks)
        shard = int(getattr(eng, "shard_min_bytes", 0) or 0)
        n_dev = max(len(getattr(eng, "devices", ())), 1)
        if len(chunks) < 2 or shard <= 0 or total < 2 * shard:
            return [(0, len(chunks))]
        n_groups = min(len(chunks), max(2, total // shard), 4 * n_dev)
        target = total / n_groups
        groups = []
        lo = acc = 0
        for i, c in enumerate(chunks):
            acc += len(c)
            if acc >= target and len(groups) < n_groups - 1:
                groups.append((lo, i + 1))
                lo, acc = i + 1, 0
        if lo < len(chunks):
            groups.append((lo, len(chunks)))
        return groups

    def _hash_chunks(self, chunks: List[bytes]) -> List[bytes]:
        return self._submit_hash(chunks).wait()

    def _boundaries(self, data: bytes) -> List[int]:
        cfg = self.cfg
        if len(data) == 0:
            return []
        if cfg.ca == "fixed":
            n = (len(data) + cfg.block_size - 1) // cfg.block_size
            return [min((i + 1) * cfg.block_size, len(data))
                    for i in range(n)]
        if cfg.ca == "cdc":
            if cfg.hasher == "tpu":
                job = self.engine.submit(
                    "sliding", np.frombuffer(data, np.uint8),
                    {"window": cfg.window, "stride": cfg.stride},
                    lane=cfg.lane)
                hashes = job.wait()
            else:
                hashes = _cpu_sliding(data, cfg.window, cfg.stride)
            return chunking.select_boundaries(
                hashes, len(data), window=cfg.window, stride=cfg.stride,
                avg_chunk=cfg.avg_chunk, min_chunk=cfg.min_chunk,
                max_chunk=cfg.max_chunk)
        if cfg.ca == "cdc-gear":
            if cfg.hasher == "tpu":
                job = self.engine.submit(
                    "gear", np.frombuffer(data, np.uint8), {},
                    lane=cfg.lane)
                hashes = job.wait()
            else:
                hashes = _cpu_gear(data)
            return chunking.select_boundaries(
                hashes, len(data), window=1, stride=1,
                avg_chunk=cfg.avg_chunk, min_chunk=cfg.min_chunk,
                max_chunk=cfg.max_chunk)
        raise ValueError(self.cfg.ca)

    # ------------------------------------------------------------------
    # store stage (shared by sync write, async pipeline, checkpointer)
    # ------------------------------------------------------------------
    def _store_chunks(self, path: str, total_len: int,
                      chunks: List[bytes], digests: List[bytes],
                      stats: WriteStats,
                      trace: Optional[Trace] = None) -> WriteStats:
        """Dedup against the indexed digest->locations registry, store
        novel blocks, commit the block-map.

        Dedup is race-free across store lanes and concurrent SAIs: one
        atomic ``claim_blocks`` decides per digest whether it is already
        stored, ours to store, or being stored by a concurrent writer.
        All own claims are stored (and released) before waiting on other
        writers' claims — a writer never holds an unfinished claim while
        waiting, so claim waits cannot deadlock.

        Every digest is pinned for the whole claim -> store -> commit
        span, so the runtime GC can never reclaim a dedup-hit (or
        freshly stored) block before the block-map referencing it is
        committed."""
        mgr = self.manager
        mgr.pin_blocks(digests)
        try:
            locmap, claimed, waits = mgr.claim_blocks(digests)
            new_idx = set()
            try:
                for i, (chunk, digest) in enumerate(zip(chunks, digests)):
                    if digest in claimed:
                        locs = mgr.place(digest)
                        self._put_block(path, digest, chunk, locs)
                        mgr.finish_claim(digest, locs)
                        claimed.remove(digest)
                        locmap[digest] = locs
                        new_idx.add(i)
            finally:
                for digest in list(claimed):         # error path: release
                    mgr.finish_claim(digest, None)
            blocks: List[BlockMeta] = []
            for i, (chunk, digest) in enumerate(zip(chunks, digests)):
                locs = locmap.get(digest)
                if locs is None:
                    waits[digest].wait()
                    locs, is_new = self._resolve_block(path, digest, chunk)
                    if is_new:
                        new_idx.add(i)
                    locmap[digest] = locs
                if i in new_idx:
                    stats.new_blocks += 1
                    stats.new_bytes += len(chunk)
                else:
                    stats.dup_blocks += 1
                blocks.append(BlockMeta(digest, len(chunk), tuple(locs)))
            seq = mgr.commit_blockmap(path, blocks, total_len)
            if self.cfg.durable_sync and seq is not None:
                t0 = time.perf_counter()
                mgr.wait_durable(seq)
                if trace is not None:
                    trace.add_span("wal/commit", t0, time.perf_counter(),
                                   seq=seq)
        finally:
            mgr.unpin_blocks(digests)
        return stats

    def _put_block(self, path: str, digest: bytes, chunk: bytes, locs):
        """Store one block on its replica nodes, wrapping I/O failures
        with the failing path/digest (StoreIOError) so pipeline threads
        surface actionable errors on the WriteFuture."""
        for nid in locs:
            try:
                self.manager.nodes[nid].put(digest, chunk)
            except OSError as e:
                raise StoreIOError(path, digest, nid, e) from e

    def _resolve_block(self, path: str, digest: bytes, chunk: bytes):
        """Dup-or-store one block through the claim protocol (used when
        a concurrent writer's claim we waited on aborted): loops until
        the digest is either registered by someone (dup) or claimed and
        stored by us.  Returns (locations, is_new)."""
        mgr = self.manager
        while True:
            locmap, claimed, waits = mgr.claim_blocks([digest])
            if locmap:
                return locmap[digest], False
            if claimed:
                try:
                    locs = mgr.place(digest)
                    self._put_block(path, digest, chunk, locs)
                except BaseException:
                    mgr.finish_claim(digest, None)
                    raise
                mgr.finish_claim(digest, locs)
                return locs, True
            waits[digest].wait()

    def _write_raw(self, path: str, data: bytes) -> WriteStats:
        """ca='none': direct striping, no hashing (synthetic digests)."""
        cfg, mgr = self.cfg, self.manager
        stats = WriteStats(total_bytes=len(data))
        t0 = time.perf_counter()
        bs = cfg.block_size
        blocks = []
        pinned: List[bytes] = []
        try:
            for i in range(0, max(len(data), 1), bs):
                chunk = data[i:i + bs]
                with _ORACLE_LOCK:
                    _ORACLE_COUNTER[0] += 1
                    n = _ORACLE_COUNTER[0]
                digest = b"raw!" + _ORACLE_NONCE + n.to_bytes(8, "little")
                mgr.pin_blocks([digest])     # GC guard until commit
                pinned.append(digest)
                locs = mgr.place(digest)
                self._put_block(path, digest, chunk, locs)
                mgr.register_block(digest, locs)
                blocks.append(BlockMeta(digest, len(chunk), locs))
                stats.new_blocks += 1
                stats.new_bytes += len(chunk)
            seq = mgr.commit_blockmap(path, blocks, len(data))
            if self.cfg.durable_sync and seq is not None:
                mgr.wait_durable(seq)
        finally:
            mgr.unpin_blocks(pinned)
        stats.stage_s = {"store": time.perf_counter() - t0}
        return stats

    # ------------------------------------------------------------------
    # write paths
    # ------------------------------------------------------------------
    def write(self, path: str, data: bytes) -> WriteStats:
        cfg = self.cfg
        if cfg.ca == "none":
            return self._write_raw(path, data)
        stats = WriteStats(total_bytes=len(data))
        t0 = time.perf_counter()
        bounds = self._boundaries(data)
        chunks = chunking.split_chunks(data, bounds)
        t1 = time.perf_counter()
        digests = self._submit_hash(chunks).wait()
        t2 = t1 if cfg.hasher == "infinite" else time.perf_counter()
        self._store_chunks(path, len(data), chunks, digests, stats)
        t3 = time.perf_counter()
        stats.stage_s = {"chunk": t1 - t0, "hash": t2 - t1,
                         "store": t3 - t2}
        return stats

    def write_async(self, path: str, data: bytes,
                    trace: Optional[Trace] = None) -> WriteFuture:
        """Pipelined write: chunk+hash of this write overlap the store
        stage of the previous one (and hash requests from back-to-back
        writes coalesce in the engine).  The store stage is sharded into
        per-path commit lanes, so writers to different paths commit in
        parallel; commit order matches submission order per path, so
        versioning is identical to sequential sync writes.

        ``trace`` (an ``obs.Trace``) rides the pipeline queues and
        collects sai/chunk, sai/hash, sai/store, engine queue/launch,
        and wal/commit spans."""
        fut = WriteFuture()
        with self._pipe_lock:
            self._ensure_pipeline()
            self._chunk_q.put((fut, path, bytes(data), trace))  # ra: disable=RA04(unbounded queue: put cannot block; hoisting it would race close)
        return fut

    def flush(self):
        """Block until every pipelined write and read has completed."""
        with self._pipe_lock:
            chunk_q, store_qs = self._chunk_q, self._store_qs
            fetch_q, verify_q = self._fetch_q, self._verify_q
        if chunk_q is not None:
            chunk_q.join()
            for q in store_qs:
                q.join()
        if fetch_q is not None:
            fetch_q.join()
            verify_q.join()

    def close(self):
        """Drain and stop the pipeline threads (idempotent).  In-flight
        writes/reads complete first; a later write_async / read_async
        restarts its pipeline.  SAIs that only use sync ``write`` /
        ``read`` have no threads."""
        with self._pipe_lock:
            chunk_q, fetch_q = self._chunk_q, self._fetch_q
            threads = self._pipe_threads
            self._chunk_q = self._store_qs = None
            self._fetch_q = self._verify_q = None
            self._pipe_threads = []
        if chunk_q is not None:
            chunk_q.put(None)        # chunk worker forwards to each lane
        if fetch_q is not None:
            fetch_q.put(None)        # fetch worker forwards to verify
        for t in threads:
            t.join(timeout=60)
        with self._cache_lock:
            listener_on = self._cache_listener_on
            self._cache_listener_on = False
        if listener_on:              # don't leak into the manager's
            self.manager.remove_quarantine_listener(  # listener list
                self._on_quarantine_evict)

    def _ensure_pipeline(self):
        # caller holds _pipe_lock
        if self._chunk_q is not None:
            return
        self._chunk_q = queue.Queue()
        n_lanes = max(1, int(self.cfg.store_lanes))
        self._store_qs = [queue.Queue() for _ in range(n_lanes)]
        threads = [threading.Thread(target=self._chunk_loop,
                                    args=(self._chunk_q, self._store_qs),
                                    daemon=True, name="sai-chunk")]
        threads += [
            threading.Thread(target=self._store_loop, args=(q, i),
                             daemon=True, name=f"sai-store-{i}")
            for i, q in enumerate(self._store_qs)]
        self._pipe_threads.extend(threads)
        for t in threads:
            t.start()

    def _chunk_loop(self, chunk_q, store_qs):
        hb = self.heartbeats.heartbeat("chunk")
        while True:
            hb.park()                    # indefinite block while idle
            item = chunk_q.get()
            if item is None:                         # close() sentinel
                for q in store_qs:
                    q.put(None)
                chunk_q.task_done()
                return                   # heartbeat stays parked
            hb.beat()
            fut, path, data, trace = item
            # per-path lane: commits for one path stay FIFO while
            # different paths commit on parallel lanes
            store_q = store_qs[hash(path) % len(store_qs)]
            try:
                if self.cfg.ca == "none":
                    store_q.put((fut, path, data, None, None, {}, trace))
                    continue
                t0 = time.perf_counter()
                bounds = self._boundaries(data)
                chunks = chunking.split_chunks(data, bounds)
                t1 = time.perf_counter()
                if trace is not None:
                    trace.add_span("sai/chunk", t0, t1,
                                   chunks=len(chunks))
                handle = self._submit_hash(chunks)   # non-blocking (tpu)
                store_q.put((fut, path, data, chunks, handle,
                             {"chunk": t1 - t0, "t_hash0": t1}, trace))
            except BaseException as e:
                fut._fail(e)
            finally:
                chunk_q.task_done()

    def _store_loop(self, store_q, lane: int = 0):
        hb = self.heartbeats.heartbeat(f"store{lane}")
        while True:
            hb.park()
            item = store_q.get()
            if item is None:                         # close() sentinel
                store_q.task_done()
                return
            hb.beat()
            fut, path, data, chunks, handle, times, trace = item
            try:
                if handle is None:                   # ca='none'
                    fut._resolve(self._write_raw(path, data))
                    continue
                stats = WriteStats(total_bytes=len(data))
                digests = handle.wait()
                t2 = time.perf_counter()
                if trace is not None:
                    trace.add_span("sai/hash", times["t_hash0"], t2)
                    _trace_engine_jobs(trace, handle)
                self._store_chunks(path, len(data), chunks, digests,
                                   stats, trace=trace)
                t3 = time.perf_counter()
                if trace is not None:
                    trace.add_span("sai/store", t2, t3)
                hash_s = 0.0 if self.cfg.hasher == "infinite" \
                    else t2 - times["t_hash0"]
                stats.stage_s = {"chunk": times["chunk"],
                                 "hash": hash_s, "store": t3 - t2}
                fut._resolve(stats)
            except BaseException as e:
                fut._fail(e)
            finally:
                store_q.task_done()

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    # -- block-level LRU read cache (digest -> verified bytes) ---------
    def _ensure_cache_listener(self):
        if self.cfg.read_cache_bytes <= 0:
            return
        with self._cache_lock:
            if self._cache_listener_on:
                return
            self._cache_listener_on = True
        self.manager.add_quarantine_listener(self._on_quarantine_evict)

    def _cache_get(self, digest: bytes) -> Optional[bytes]:
        if self.cfg.read_cache_bytes <= 0:
            return None
        self._ensure_cache_listener()
        with self._cache_lock:
            data = self._cache.get(digest)
            if data is None:
                self.read_stats.inc("cache_misses")
                return None
            self._cache.move_to_end(digest)
            self.read_stats.inc("cache_hits")
            return data

    def _on_quarantine_evict(self, digest: bytes, node_id: int,
                             remaining):
        with self._cache_lock:
            data = self._cache.pop(digest, None)
            if data is not None:
                self._cache_used -= len(data)
                self.read_stats.inc("cache_invalidations")

    def _cache_put(self, digest: bytes, data: bytes):
        cap = self.cfg.read_cache_bytes
        if cap <= 0 or len(data) > cap:
            return
        self._ensure_cache_listener()
        with self._cache_lock:
            if digest in self._cache:
                self._cache.move_to_end(digest)
                return
            self._cache[digest] = data
            self._cache_used += len(data)
            while self._cache_used > cap:
                _, old = self._cache.popitem(last=False)
                self._cache_used -= len(old)

    def _fetch_blocks(self, blocks, locmap=None):
        """Fetch every block of a file version with replica failover.
        ``locmap`` carries the replica locations resolved by
        ``get_read_plan`` under one lock; blocks missing from it fall
        back to the block-map's recorded nodes (quarantined replicas
        are deprioritized to last resort).  Returns ``(datas, srcs)``
        where ``srcs[i]`` is the node id that served block i, or None
        for a read-cache hit (already verified)."""
        if locmap is None:
            locmap = {}
        mgr = self.manager
        # snapshot reference, checked without the manager lock: a
        # quarantine landing mid-read at worst serves the corrupt copy,
        # which the verify + speculative-refetch path then catches
        qmap = mgr.quarantined

        def try_locs(digest, locs):
            err = None
            # healthy replicas first; quarantined copies only as a
            # last resort (unverified reads of fully-corrupt blocks)
            qset = qmap.get(digest) if qmap else None
            if qset:
                locs = sorted(locs, key=lambda nid: nid in qset)
            for nid in locs:
                try:
                    return mgr.nodes[nid].get(digest), nid, None
                except (NodeFailure, KeyError) as e:
                    err = e
            return None, None, err

        datas: List[bytes] = []
        srcs: List[Optional[int]] = []
        for b in blocks:
            cached = self._cache_get(b.digest)
            if cached is not None:
                datas.append(cached)
                srcs.append(None)
                continue
            data, src, last_err = try_locs(b.digest,
                                           locmap.get(b.digest) or b.nodes)
            if data is None:
                # the plan may have gone stale (a node failed and
                # re-replication moved the block after the snapshot):
                # retry with a fresh registry lookup before giving up
                data, src, err2 = try_locs(b.digest,
                                           mgr.lookup_block(b.digest))
                last_err = err2 or last_err
            if data is None:
                raise NodeFailure(
                    f"block {b.digest.hex()[:8]} unavailable: {last_err}")
            datas.append(data)
            srcs.append(src)
        return datas, srcs

    def _submit_verify(self, blocks, datas: List[bytes], srcs=None):
        """Start re-hashing the verifiable fetched blocks as fused
        direct requests (non-blocking on the tpu path): at most
        ceil(n / max_batch) engine submissions, so one huge read never
        stages a single unbounded [n, W] padded matrix.  Synthetic
        ``raw!`` digests (ca='none') carry no content hash and cache
        hits were verified at insertion — both are skipped.  Returns
        ``(handles, idxs)`` with idxs the block indices under check."""
        idxs = [i for i, b in enumerate(blocks)
                if not b.digest.startswith(b"raw!")
                and (srcs is None or srcs[i] is not None)]
        group = self.engine.max_batch if self.cfg.hasher == "tpu" \
            else max(len(idxs), 1)
        handles = [self._submit_hash([datas[i] for i in idxs[k:k + group]])
                   for k in range(0, len(idxs), group)]
        return handles, idxs

    @staticmethod
    def _gather_digests(handles) -> List[bytes]:
        return [d for h in handles for d in h.wait()]

    def _finish_verify(self, blocks, datas, srcs, handles, idxs,
                       locmap=None):
        """Compare recomputed digests; on mismatch, speculatively
        re-fetch the block from the next replica (reporting the corrupt
        copy to the metadata manager as a quarantine hint for the node
        runtime's repair pipeline) and only raise IOError once every
        replica is exhausted.  Verified bytes enter the read cache."""
        digests = self._gather_digests(handles)
        for i, digest in zip(idxs, digests):
            if digest != blocks[i].digest:
                self._refetch_block(blocks[i], i, datas, srcs, locmap)
        for i in idxs:
            self._cache_put(blocks[i].digest, datas[i])

    def _refetch_block(self, b: BlockMeta, i: int, datas, srcs,
                       locmap=None):
        """Speculative re-fetch: the copy from ``srcs[i]`` failed its
        digest check — quarantine it and try the remaining replicas
        (freshest registry view first, then the block-map's recorded
        nodes) until one verifies."""
        mgr = self.manager
        tried = set()
        if srcs[i] is not None:
            tried.add(srcs[i])
            mgr.quarantine_block(b.digest, srcs[i])
        candidates = [nid for nid in
                      (tuple(mgr.lookup_block(b.digest))
                       + tuple((locmap or {}).get(b.digest, ())) + b.nodes)
                      if nid not in tried]
        for nid in dict.fromkeys(candidates):     # dedup, keep order
            tried.add(nid)
            try:
                data = mgr.nodes[nid].get(b.digest)
            except (NodeFailure, KeyError):
                continue
            if self._hash_chunks([data])[0] == b.digest:
                self.read_stats.inc("refetches")
                datas[i] = data
                srcs[i] = nid
                return
            mgr.quarantine_block(b.digest, nid)   # this copy is bad too
        raise IOError(
            f"integrity check failed for {b.digest.hex()[:8]}")

    def read(self, path: str, version: int = -1,
             verify: bool = True) -> bytes:
        """Verified read: all fetched blocks are re-hashed by ONE fused
        engine request (per-block ``hashlib`` only on the cpu hasher),
        digests are compared on the host, and the file is assembled.
        A digest mismatch triggers speculative re-fetch from the next
        replica (plus a quarantine hint to the node runtime) before
        raising IOError."""
        fv, locmap = self.manager.get_read_plan(path, version)
        if fv is None:
            raise FileNotFoundError(path)
        datas, srcs = self._fetch_blocks(fv.blocks, locmap)
        if verify:
            handles, idxs = self._submit_verify(fv.blocks, datas, srcs)
            self._finish_verify(fv.blocks, datas, srcs, handles, idxs,
                                locmap)
        return b"".join(datas)[:fv.total_len]

    def read_range(self, path: str, offset: int, length: int,
                   version: int = -1, verify: bool = True) -> bytes:
        """Merkle-proof partial read: fetch ONLY the blocks covering
        ``[offset, offset+length)`` and verify each against the stored
        file-level ``FileVersion.merkle_root`` via a membership proof
        (``integrity.merkle_proof``) — no other block of the version is
        ever fetched or hashed.  The proof path is built from the
        block-map's leaf digests and anchored at the committed root, so
        a partial read detects both corrupt block bytes (recomputed
        digest breaks the proof; speculative re-fetch from the next
        replica, as in full reads) and a tampered block-map entry (the
        stored digest itself fails the proof => IOError).  The range
        end is clamped to the file length and ``offset == total_len``
        (exactly at EOF) reads empty, but an offset strictly past EOF
        raises ``ValueError`` — it names bytes that never existed,
        which is a caller bug, not a short read; ``raw!`` blocks
        (ca='none') carry no content hash and are served unverified, as
        in full reads."""
        if offset < 0 or length < 0:
            raise ValueError("offset and length must be non-negative")
        fv, locmap = self.manager.get_read_plan(path, version)
        if fv is None:
            raise FileNotFoundError(path)
        if offset > fv.total_len:
            raise ValueError(
                f"offset {offset} past EOF ({fv.total_len}) for {path}")
        end = min(offset + length, fv.total_len)
        if end <= offset:
            return b""
        first = None
        start0 = pos = 0
        cover: List[BlockMeta] = []
        for i, b in enumerate(fv.blocks):
            if pos + b.length > offset and pos < end:
                if first is None:
                    first, start0 = i, pos
                cover.append(b)
            pos += b.length
            if pos >= end:
                break
        datas, srcs = self._fetch_blocks(cover, locmap)
        if verify:
            handles, idxs = self._submit_verify(cover, datas, srcs)
            recomputed = dict(zip(idxs, self._gather_digests(handles)))
            leaves = [b.digest for b in fv.blocks]
            # every non-raw covering block is proof-checked — including
            # read-cache hits (their bytes were digest-verified at
            # insertion; the proof still anchors the digest to the
            # root, so a tampered block-map is caught warm or cold) —
            # and the tree is built ONCE for the whole range
            check = [k for k, b in enumerate(cover)
                     if not b.digest.startswith(b"raw!")]
            proofs = integrity.merkle_proofs(
                leaves, [first + k for k in check])
            for k in check:
                digest = recomputed.get(k)
                if digest is not None and digest != cover[k].digest:
                    # corrupt fetched copy: quarantine + next replica
                    # (the refetch re-verifies the content hash, so
                    # bytes match the stored digest from here on)
                    self._refetch_block(cover[k], k, datas, srcs, locmap)
                gi = first + k
                if not integrity.merkle_verify(cover[k].digest, gi,
                                               proofs[gi],
                                               fv.merkle_root):
                    raise IOError(
                        f"merkle proof failed for block {gi} of {path}")
            for k in idxs:
                self._cache_put(cover[k].digest, datas[k])
        buf = b"".join(datas)
        return buf[offset - start0:end - start0]

    def read_async(self, path: str, version: int = -1,
                   verify: bool = True,
                   trace: Optional[Trace] = None) -> ReadFuture:
        """Pipelined read: fetch -> verify -> assemble as staged threads.
        The verify stage of read i (waiting on the engine digest) overlaps
        the fetch stage of read i+1, and verify requests from concurrent
        readers coalesce into common batch launches through the shared
        engine.  ``trace`` collects sai/fetch + sai/verify spans."""
        fut = ReadFuture()
        with self._pipe_lock:
            self._ensure_read_pipeline()
            self._fetch_q.put((fut, path, version, verify, trace))  # ra: disable=RA04(unbounded queue: put cannot block; hoisting it would race close)
        return fut

    def _ensure_read_pipeline(self):
        # caller holds _pipe_lock
        if self._fetch_q is not None:
            return
        self._fetch_q = queue.Queue()
        self._verify_q = queue.Queue()
        threads = [
            threading.Thread(target=self._fetch_loop,
                             args=(self._fetch_q, self._verify_q),
                             daemon=True, name="sai-fetch"),
            threading.Thread(target=self._verify_loop,
                             args=(self._verify_q,),
                             daemon=True, name="sai-verify")]
        self._pipe_threads.extend(threads)
        for t in threads:
            t.start()

    def _fetch_loop(self, fetch_q, verify_q):
        hb = self.heartbeats.heartbeat("fetch")
        while True:
            hb.park()
            item = fetch_q.get()
            if item is None:                         # close() sentinel
                verify_q.put(None)
                fetch_q.task_done()
                return
            hb.beat()
            fut, path, version, verify, trace = item
            try:
                t0 = time.perf_counter()
                fv, locmap = self.manager.get_read_plan(path, version)
                if fv is None:
                    raise FileNotFoundError(path)
                datas, srcs = self._fetch_blocks(fv.blocks, locmap)
                if trace is not None:
                    trace.add_span("sai/fetch", t0, time.perf_counter(),
                                   blocks=len(fv.blocks))
                if verify:
                    handles, idxs = self._submit_verify(fv.blocks, datas,
                                                        srcs)
                else:
                    handles, idxs = None, []
                verify_q.put((fut, fv, datas, srcs, handles, idxs,
                              locmap, trace))
            except BaseException as e:
                fut._fail(e)
            finally:
                fetch_q.task_done()

    def _verify_loop(self, verify_q):
        hb = self.heartbeats.heartbeat("verify")
        while True:
            hb.park()
            item = verify_q.get()
            if item is None:                         # close() sentinel
                verify_q.task_done()
                return
            hb.beat()
            fut, fv, datas, srcs, handles, idxs, locmap, trace = item
            try:
                if handles is not None:
                    t0 = time.perf_counter()
                    self._finish_verify(fv.blocks, datas, srcs, handles,
                                        idxs, locmap)
                    if trace is not None:
                        trace.add_span("sai/verify", t0,
                                       time.perf_counter())
                        for h in handles:
                            _trace_engine_jobs(trace, h)
                fut._resolve(b"".join(datas)[:fv.total_len])
            except BaseException as e:
                fut._fail(e)
            finally:
                verify_q.task_done()


def pack_blocks(chunks: List[bytes]):
    """Pack chunks into padded rows for a direct-hash request.

    Canonical block digest = MD5( zero-pad-to-word(data) ||
    u32_le(byte_length) ): the length trailer disambiguates chunks
    that differ only in trailing zero padding (CDC boundaries are
    byte-exact).  Row width is bucketed to a power of two to bound
    jit retraces across writes with ragged max-chunk lengths.  Shared
    by the SAI write/read paths and the node runtime's scrub/repair
    verification (repro.core.noderuntime)."""
    seg = max(len(c) for c in chunks)
    seg = (seg + 3) // 4 * 4 + 4
    seg = 1 << (seg - 1).bit_length()
    rows = np.zeros((len(chunks), seg), np.uint8)
    lens = np.zeros((len(chunks),), np.int64)
    for i, c in enumerate(chunks):
        padded = (len(c) + 3) // 4 * 4
        rows[i, :len(c)] = np.frombuffer(c, np.uint8)
        rows[i, padded:padded + 4] = np.frombuffer(
            np.uint32(len(c)).tobytes(), np.uint8)
        lens[i] = padded + 4
    return rows, lens


def _pad4(data: bytes) -> bytes:
    return data + b"\x00" * ((-len(data)) % 4)


def block_digest_cpu(data: bytes) -> bytes:
    """Canonical block digest (hashlib path):
    MD5( pad4(data) || u32_le(len) ) — identical to the TPU kernel path."""
    return hashlib.md5(
        _pad4(data) + np.uint32(len(data)).tobytes()).digest()


def _cpu_sliding(data: bytes, window: int, stride: int) -> np.ndarray:
    """Single-core CPU sliding-window hashing (the paper's CPU baseline)."""
    n = max((len(data) - window) // stride + 1, 0)
    out = np.empty((n,), np.uint32)
    view = memoryview(data)
    for i in range(n):
        o = i * stride
        out[i] = int.from_bytes(
            hashlib.md5(view[o:o + window]).digest()[:4], "little")
    return out


def _cpu_gear(data: bytes, vectorized: bool = True) -> np.ndarray:
    """Gear hash (FastCDC recurrence) on the CPU.

    ``vectorized`` uses the 32-tap convolution form (SIMD-style numpy —
    the optimized CPU implementation); ``vectorized=False`` runs the
    literal sequential recurrence (tests assert both are identical)."""
    import numpy as _np
    b = _np.frombuffer(data, _np.uint8).astype(_np.uint32) + 1
    # mix32
    x = b.copy()
    x ^= x >> 16
    x = (x * _np.uint32(0x85EBCA6B)) & _np.uint32(0xFFFFFFFF)
    x ^= x >> 13
    x = (x * _np.uint32(0xC2B2AE35)) & _np.uint32(0xFFFFFFFF)
    x ^= x >> 16
    if vectorized:
        h = x.copy()
        for j in range(1, 32):
            h[j:] += x[:-j] << _np.uint32(j)
        return h
    acc = 0
    out = _np.empty(len(b), _np.uint32)
    for i in range(len(b)):
        acc = ((acc << 1) + int(x[i])) & 0xFFFFFFFF
        out[i] = acc
    return out
