"""Client System Access Interface (SAI) — the MosaStore client analog.

Implements the paper's write path (Figure 3): buffered writes are chunked
(fixed-size or content-based via the accelerator), chunk hashes are
computed by HashTPU through CrystalTPU, compared against the previous
version's block-map for similarity detection, and only novel blocks are
striped over the storage nodes.  The read path re-hashes fetched blocks
(implicit integrity check of content addressing) and falls back to block
replicas on node failure.

Configurations mirror the paper's evaluation matrix:
  ca='none'                 -> non-CA (direct write, no hashing)
  ca='fixed'                -> fixed-size blocks + direct hashing
  ca='cdc'                  -> content-based chunking (sliding-window MD5)
  ca='cdc-gear'             -> beyond-paper gear-hash CDC
  hasher='tpu' | 'cpu' | 'infinite'   ('infinite' = the paper's CA-Infinite
        oracle: hash computation takes zero time — upper performance bound)
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import chunking
from repro.core.castore import BlockMeta, MetadataManager, NodeFailure
from repro.core.crystal import CrystalTPU
from repro.kernels import ops


@dataclass
class SAIConfig:
    ca: str = "fixed"                 # none | fixed | cdc | cdc-gear
    block_size: int = 1 << 20         # fixed-size block bytes
    avg_chunk: int = 1 << 20          # CDC target chunk
    min_chunk: int = 256 << 10
    max_chunk: int = 4 << 20
    window: int = 48
    stride: int = 4
    hasher: str = "tpu"               # tpu | cpu | infinite
    stripe_width: int = 4


@dataclass
class WriteStats:
    total_bytes: int = 0
    new_bytes: int = 0
    new_blocks: int = 0
    dup_blocks: int = 0
    stage_s: Dict[str, float] = field(default_factory=dict)

    @property
    def similarity(self) -> float:
        total = self.new_blocks + self.dup_blocks
        return self.dup_blocks / total if total else 0.0


_ORACLE_COUNTER = [0]


class SAI:
    def __init__(self, manager: MetadataManager, config: SAIConfig,
                 crystal: Optional[CrystalTPU] = None):
        self.manager = manager
        self.cfg = config
        self.crystal = crystal

    # ------------------------------------------------------------------
    # hashing backends
    # ------------------------------------------------------------------
    def _hash_chunks(self, chunks: List[bytes]) -> List[bytes]:
        cfg = self.cfg
        if cfg.hasher in ("infinite", "cpu"):
            # 'infinite' is the paper's CA-Infinite oracle — its hashing
            # time is excluded from the timed stages by the caller.
            return [block_digest_cpu(c) for c in chunks]
        # tpu: batch via HashTPU direct hashing.  Canonical block digest =
        # MD5( zero-pad-to-word(data) || u32_le(byte_length) ): the length
        # trailer disambiguates chunks that differ only in trailing zero
        # padding (CDC boundaries are byte-exact).
        seg = max(len(c) for c in chunks)
        seg = (seg + 3) // 4 * 4 + 4
        # bucket the padded width to a power of two: bounds jit retraces
        # across writes with ragged max-chunk lengths
        seg = 1 << (seg - 1).bit_length()
        arr = np.zeros((len(chunks), seg), np.uint8)
        lens = np.zeros((len(chunks),), np.int64)
        for i, c in enumerate(chunks):
            padded = (len(c) + 3) // 4 * 4
            arr[i, :len(c)] = np.frombuffer(c, np.uint8)
            arr[i, padded:padded + 4] = np.frombuffer(
                np.uint32(len(c)).tobytes(), np.uint8)
            lens[i] = padded + 4
        digs = ops.direct_hash(arr, lens)
        return [digs[i].tobytes() for i in range(len(chunks))]

    def _boundaries(self, data: bytes) -> List[int]:
        cfg = self.cfg
        if cfg.ca == "fixed":
            n = (len(data) + cfg.block_size - 1) // cfg.block_size
            return [min((i + 1) * cfg.block_size, len(data))
                    for i in range(n)]
        if cfg.ca == "cdc":
            if cfg.hasher == "tpu" and self.crystal is not None:
                job = self.crystal.submit(
                    "sliding", np.frombuffer(data, np.uint8),
                    {"window": cfg.window, "stride": cfg.stride})
                hashes = job.wait()
            elif cfg.hasher == "tpu":
                hashes = ops.sliding_window_hash(
                    data, window=cfg.window, stride=cfg.stride)
            else:
                hashes = _cpu_sliding(data, cfg.window, cfg.stride)
            return chunking.select_boundaries(
                hashes, len(data), window=cfg.window, stride=cfg.stride,
                avg_chunk=cfg.avg_chunk, min_chunk=cfg.min_chunk,
                max_chunk=cfg.max_chunk)
        if cfg.ca == "cdc-gear":
            if cfg.hasher == "tpu" and self.crystal is not None:
                job = self.crystal.submit(
                    "gear", np.frombuffer(data, np.uint8), {})
                hashes = job.wait()
            elif cfg.hasher == "tpu":
                hashes = ops.gear_hash(data)
            else:
                hashes = _cpu_gear(data)
            return chunking.select_boundaries(
                hashes, len(data), window=1, stride=1,
                avg_chunk=cfg.avg_chunk, min_chunk=cfg.min_chunk,
                max_chunk=cfg.max_chunk)
        raise ValueError(self.cfg.ca)

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def write(self, path: str, data: bytes) -> WriteStats:
        cfg = self.cfg
        stats = WriteStats(total_bytes=len(data))
        mgr = self.manager

        if cfg.ca == "none":
            t0 = time.perf_counter()
            bs = cfg.block_size
            blocks = []
            for i in range(0, max(len(data), 1), bs):
                chunk = data[i:i + bs]
                _ORACLE_COUNTER[0] += 1
                digest = b"raw!" + _ORACLE_COUNTER[0].to_bytes(12, "little")
                locs = mgr.place(digest)
                for nid in locs:
                    mgr.nodes[nid].put(digest, chunk)
                mgr.register_block(digest, locs)
                blocks.append(BlockMeta(digest, len(chunk), locs))
                stats.new_blocks += 1
                stats.new_bytes += len(chunk)
            mgr.commit_blockmap(path, blocks, len(data))
            stats.stage_s = {"store": time.perf_counter() - t0}
            return stats

        t0 = time.perf_counter()
        bounds = self._boundaries(data)
        chunks = chunking.split_chunks(data, bounds)
        t1 = time.perf_counter()
        if cfg.hasher == "infinite":
            digests = self._hash_chunks(chunks)
            t2 = t1                      # oracle: hashing is free
        else:
            digests = self._hash_chunks(chunks)
            t2 = time.perf_counter()

        prev = mgr.get_blockmap(path)
        known = {b.digest for b in prev.blocks} if prev else set()

        blocks: List[BlockMeta] = []
        for chunk, digest in zip(chunks, digests):
            if digest in known or mgr.lookup_block(digest):
                locs = mgr.lookup_block(digest) or \
                    next(b.nodes for b in prev.blocks if b.digest == digest)
                stats.dup_blocks += 1
            else:
                locs = mgr.place(digest)
                for nid in locs:
                    mgr.nodes[nid].put(digest, chunk)
                mgr.register_block(digest, locs)
                stats.new_blocks += 1
                stats.new_bytes += len(chunk)
            blocks.append(BlockMeta(digest, len(chunk), tuple(locs)))
        mgr.commit_blockmap(path, blocks, len(data))
        t3 = time.perf_counter()
        stats.stage_s = {"chunk": t1 - t0, "hash": t2 - t1,
                         "store": t3 - t2}
        return stats

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def read(self, path: str, version: int = -1,
             verify: bool = True) -> bytes:
        fv = self.manager.get_blockmap(path, version)
        if fv is None:
            raise FileNotFoundError(path)
        out = bytearray()
        for b in fv.blocks:
            data = None
            locs = self.manager.lookup_block(b.digest) or b.nodes
            last_err: Optional[Exception] = None
            for nid in locs:
                try:
                    data = self.manager.nodes[nid].get(b.digest)
                    break
                except (NodeFailure, KeyError) as e:
                    last_err = e
            if data is None:
                raise NodeFailure(
                    f"block {b.digest.hex()[:8]} unavailable: {last_err}")
            if verify and not b.digest.startswith(b"raw!"):
                if block_digest_cpu(data) != b.digest:
                    raise IOError(
                        f"integrity check failed for {b.digest.hex()[:8]}")
            out += data
        return bytes(out[:fv.total_len])


def _pad4(data: bytes) -> bytes:
    return data + b"\x00" * ((-len(data)) % 4)


def block_digest_cpu(data: bytes) -> bytes:
    """Canonical block digest (hashlib path):
    MD5( pad4(data) || u32_le(len) ) — identical to the TPU kernel path."""
    return hashlib.md5(
        _pad4(data) + np.uint32(len(data)).tobytes()).digest()


def _cpu_sliding(data: bytes, window: int, stride: int) -> np.ndarray:
    """Single-core CPU sliding-window hashing (the paper's CPU baseline)."""
    n = (len(data) - window) // stride + 1
    out = np.empty((n,), np.uint32)
    view = memoryview(data)
    for i in range(n):
        o = i * stride
        out[i] = int.from_bytes(
            hashlib.md5(view[o:o + window]).digest()[:4], "little")
    return out


def _cpu_gear(data: bytes, vectorized: bool = True) -> np.ndarray:
    """Gear hash (FastCDC recurrence) on the CPU.

    ``vectorized`` uses the 32-tap convolution form (SIMD-style numpy —
    the optimized CPU implementation); ``vectorized=False`` runs the
    literal sequential recurrence (tests assert both are identical)."""
    import numpy as _np
    b = _np.frombuffer(data, _np.uint8).astype(_np.uint32) + 1
    # mix32
    x = b.copy()
    x ^= x >> 16
    x = (x * _np.uint32(0x85EBCA6B)) & _np.uint32(0xFFFFFFFF)
    x ^= x >> 13
    x = (x * _np.uint32(0xC2B2AE35)) & _np.uint32(0xFFFFFFFF)
    x ^= x >> 16
    if vectorized:
        h = x.copy()
        for j in range(1, 32):
            h[j:] += x[:-j] << _np.uint32(j)
        return h
    acc = 0
    out = _np.empty(len(b), _np.uint32)
    for i in range(len(b)):
        acc = ((acc << 1) + int(x[i])) & 0xFFFFFFFF
        out[i] = acc
    return out
