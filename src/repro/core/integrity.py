"""Merkle-tree integrity over block digests.

The paper positions hashing for "data integrity checks" as a primary use
(the *different* workload evaluates exactly that configuration).  This
module adds file-level integrity on top of per-block digests: a Merkle
tree whose leaves are the block digests; the root commits the full file
and membership proofs verify single blocks without refetching the file.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Tuple


def _h(x: bytes) -> bytes:
    return hashlib.md5(x).digest()


def merkle_root(leaves: List[bytes]) -> bytes:
    if not leaves:
        return _h(b"")
    level = [_h(b"leaf" + l) for l in leaves]
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level), 2):
            a = level[i]
            b = level[i + 1] if i + 1 < len(level) else a
            nxt.append(_h(b"node" + a + b))
        level = nxt
    return level[0]


def merkle_proof(leaves: List[bytes], index: int) -> List[Tuple[bool, bytes]]:
    """Returns [(is_right_sibling, digest), ...] path to the root."""
    level = [_h(b"leaf" + l) for l in leaves]
    proof = []
    idx = index
    while len(level) > 1:
        sib = idx ^ 1
        if sib >= len(level):
            sib = idx
        proof.append((sib > idx, level[sib]))
        nxt = []
        for i in range(0, len(level), 2):
            a = level[i]
            b = level[i + 1] if i + 1 < len(level) else a
            nxt.append(_h(b"node" + a + b))
        level = nxt
        idx //= 2
    return proof


def merkle_proofs(leaves: List[bytes],
                  indices: List[int]) -> dict:
    """Membership proofs for several leaves from ONE tree build —
    {index: proof} with each proof identical to ``merkle_proof(leaves,
    index)``.  A ranged read covering k of n blocks pays O(n + k log n)
    instead of k full O(n) rebuilds."""
    levels = [[_h(b"leaf" + l) for l in leaves]]
    while len(levels[-1]) > 1:
        cur = levels[-1]
        nxt = []
        for i in range(0, len(cur), 2):
            a = cur[i]
            b = cur[i + 1] if i + 1 < len(cur) else a
            nxt.append(_h(b"node" + a + b))
        levels.append(nxt)
    out = {}
    for index in indices:
        proof = []
        idx = index
        for level in levels[:-1]:
            sib = idx ^ 1
            if sib >= len(level):
                sib = idx
            proof.append((sib > idx, level[sib]))
            idx //= 2
        out[index] = proof
    return out


def merkle_verify(leaf: bytes, index: int, proof: List[Tuple[bool, bytes]],
                  root: bytes) -> bool:
    cur = _h(b"leaf" + leaf)
    for is_right, sib in proof:
        cur = _h(b"node" + cur + sib) if is_right else _h(b"node" + sib + cur)
    return cur == root
