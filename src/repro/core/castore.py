"""Content-addressable distributed storage substrate (MosaStore analog).

Object-based architecture mirroring the paper's Figure 2: a centralized
metadata manager holding per-file block-maps (block hash, length, replica
locations), N storage nodes holding blocks keyed by content hash, and
client-side striping over nodes.  Replication + node-failure handling +
re-replication give the fault-tolerance substrate the training framework's
checkpoint layer builds on.
"""
from __future__ import annotations

import os
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.blockstore import BlockStore
from repro.core.faultinject import FaultInjector
from repro.core.integrity import merkle_root
from repro.core.wal import WALError, WriteAheadLog


class NodeFailure(RuntimeError):
    pass


class StorageNode:
    """One storage node: content-hash -> block bytes.

    A digest can be *tainted* (quarantined in place): the scrubber or a
    read-path verify failure found the resident copy corrupt.  Tainted
    copies are excluded from ``has`` / ``healthy_digests`` — placement
    and scrubbing treat them as gone — but ``get`` still serves them so
    unverified last-resort reads keep working until repair lands a fresh
    copy (``put`` on the digest clears the taint).

    With a :class:`~repro.core.blockstore.BlockStore` backend the node
    is *durable*: puts write through to segment files (fsynced by the
    metadata WAL's group-commit, not per put), ``blocks`` acts as an
    in-memory read cache, and ``get``/``has``/``healthy_digests`` fall
    back to the persistent index — so a node rebuilt from disk serves
    its pre-crash blocks with an empty cache."""

    def __init__(self, node_id: int, store: Optional[BlockStore] = None):
        self.node_id = node_id
        self.store = store
        self.blocks: Dict[bytes, bytes] = {}
        self.tainted: Set[bytes] = set()
        self.failed = False
        self._lock = threading.Lock()
        self.put_count = 0
        self.get_count = 0

    def put(self, digest: bytes, data: bytes):
        if self.failed:
            raise NodeFailure(f"node {self.node_id} down")
        with self._lock:
            if self.store is not None:
                # replace only when overwriting a known-corrupt resident
                # copy (repair); otherwise content addressing dedups
                self.store.put(digest, data,
                               replace=digest in self.tainted)
            self.blocks[digest] = data
            self.tainted.discard(digest)
            self.put_count += 1

    def get(self, digest: bytes) -> bytes:
        if self.failed:
            raise NodeFailure(f"node {self.node_id} down")
        with self._lock:
            self.get_count += 1
            data = self.blocks.get(digest)
            if data is None and self.store is not None:
                data = self.store.get(digest)
                if data is not None:
                    self.blocks[digest] = data     # warm the read cache
            if data is None:
                raise KeyError(digest.hex())
            return data

    def _resident(self, digest: bytes) -> bool:
        return digest in self.blocks or (self.store is not None
                                         and self.store.has(digest))

    def has(self, digest: bytes) -> bool:
        return (not self.failed and digest not in self.tainted
                and self._resident(digest))

    def taint(self, digest: bytes) -> bool:
        """Quarantine the resident copy in place (corrupt bytes kept for
        last-resort unverified reads).  Returns True if the digest was
        resident."""
        with self._lock:
            if not self._resident(digest):
                return False
            self.tainted.add(digest)
            return True

    def drop(self, digest: bytes) -> bool:
        """Reclaim a block (GC).  Returns True if bytes were freed."""
        with self._lock:
            self.tainted.discard(digest)
            freed = self.blocks.pop(digest, None) is not None
            if self.store is not None and self.store.has(digest):
                self.store.drop(digest)
                freed = True
            return freed

    def healthy_digests(self) -> List[bytes]:
        """Snapshot of resident, non-tainted digests (the scrub set)."""
        with self._lock:
            digs = set(self.blocks)
            if self.store is not None:
                digs.update(self.store.digests())
            return [d for d in digs if d not in self.tainted]

    def used_bytes(self) -> int:
        if self.store is not None:
            return self.store.used_bytes()
        return sum(len(v) for v in self.blocks.values())

    def flush(self):
        """Push buffered store writes to disk (WAL pre-sync hook)."""
        if self.store is not None and not self.store.crashed:
            self.store.flush()

    def fail(self):
        self.failed = True

    def recover_empty(self):
        self.failed = False
        self.blocks.clear()
        self.tainted.clear()
        if self.store is not None and not self.store.crashed:
            self.store.clear()


@dataclass
class BlockMeta:
    digest: bytes
    length: int
    nodes: Tuple[int, ...]            # replica locations


@dataclass
class FileVersion:
    blocks: List[BlockMeta]
    total_len: int
    timestamp: float = field(default_factory=time.time)
    # file-level Merkle root over the block digests (leaf order = block
    # order): commits the whole version, lets the scrubber spot-check a
    # single sampled block via integrity.merkle_proof without refetching
    # the file
    merkle_root: bytes = b""


# ---------------------------------------------------------------------------
# WAL record kinds + payload codecs
#
# Every recovery-relevant metadata transition appends one record to the
# write-ahead log (framing/group-commit in repro.core.wal; these are the
# semantics).  Payloads are little-endian struct layouts decoded with the
# same hostile-bytes discipline as the gateway wire codec: any truncation
# or garbage raises WALError — never struct.error / IndexError — and
# replay stops at the last good record.
# ---------------------------------------------------------------------------

REC_COMMIT = 1        # path, total_len, timestamp, root, [blocks]
REC_RETIRE = 2        # path, keep_latest
REC_CLAIM = 3         # [digests] a writer won the duty to store
REC_CLAIM_DONE = 4    # digest, [nodes] (empty nodes = aborted claim)
REC_REGISTER = 5      # digest, [nodes] merged into the registry
REC_QUAR = 6          # digest, node_id quarantined
REC_UNQUAR = 7        # digest, node_id cleared
REC_PIN = 8           # [digests] pinned (+1 each)
REC_UNPIN = 9         # [digests] unpinned (-1 each)
REC_GC = 10           # [digests] reclaimed (registry+refs dropped)
REC_RELOCATE = 11     # digest, [nodes] registry locations REPLACED

RECORD_NAMES = {
    REC_COMMIT: "commit", REC_RETIRE: "retire", REC_CLAIM: "claim",
    REC_CLAIM_DONE: "claim_done", REC_REGISTER: "register",
    REC_QUAR: "quarantine", REC_UNQUAR: "unquarantine",
    REC_PIN: "pin", REC_UNPIN: "unpin", REC_GC: "gc",
    REC_RELOCATE: "relocate",
}

_SNAP_VERSION = 1

_U8 = struct.Struct("<B")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_F64 = struct.Struct("<d")
_DIGEST_LEN = 16


class _RecReader:
    """Bounds-checked cursor over a record body (WALError on misuse)."""

    __slots__ = ("buf", "off")

    def __init__(self, buf: bytes):
        self.buf = buf
        self.off = 0

    def u(self, st: struct.Struct) -> int:
        if self.off + st.size > len(self.buf):
            raise WALError("truncated record body")
        (v,) = st.unpack_from(self.buf, self.off)
        self.off += st.size
        return v

    def raw(self, n: int) -> bytes:
        if n < 0 or self.off + n > len(self.buf):
            raise WALError("truncated record body")
        out = self.buf[self.off:self.off + n]
        self.off += n
        return out

    def digest(self) -> bytes:
        return self.raw(_DIGEST_LEN)

    def text(self) -> str:
        raw = self.raw(self.u(_U16))
        try:
            return raw.decode("utf-8")
        except UnicodeDecodeError as e:
            raise WALError(f"invalid utf-8 in record: {e}") from None

    def nodes(self) -> Tuple[int, ...]:
        n = self.u(_U16)
        return tuple(self.u(_U32) for _ in range(n))

    def digests(self) -> List[bytes]:
        n = self.u(_U32)
        return [self.digest() for _ in range(n)]

    def done(self):
        if self.off != len(self.buf):
            raise WALError("trailing garbage in record body")


def _enc_text(s: str) -> bytes:
    raw = s.encode("utf-8")
    if len(raw) > 0xFFFF:
        raise WALError(f"path too long for WAL record: {len(raw)}")
    return _U16.pack(len(raw)) + raw


def _enc_digest(d: bytes) -> bytes:
    if len(d) != _DIGEST_LEN:
        raise WALError(f"digest must be {_DIGEST_LEN} bytes, got {len(d)}")
    return bytes(d)


def _enc_nodes(nodes: Sequence[int]) -> bytes:
    return _U16.pack(len(nodes)) + b"".join(_U32.pack(n) for n in nodes)


def _enc_digests(digests: Sequence[bytes]) -> bytes:
    return _U32.pack(len(digests)) + b"".join(_enc_digest(d)
                                              for d in digests)


def enc_commit(path: str, fv: "FileVersion") -> bytes:
    parts = [_enc_text(path), _U64.pack(fv.total_len),
             _F64.pack(fv.timestamp),
             _U16.pack(len(fv.merkle_root)), bytes(fv.merkle_root),
             _U32.pack(len(fv.blocks))]
    for b in fv.blocks:
        parts.append(_enc_digest(b.digest))
        parts.append(_U64.pack(b.length))
        parts.append(_enc_nodes(b.nodes))
    return b"".join(parts)


def dec_commit(body: bytes) -> Tuple[str, "FileVersion"]:
    r = _RecReader(body)
    path = r.text()
    total_len = r.u(_U64)
    ts = r.u(_F64)
    root = r.raw(r.u(_U16))
    blocks = [BlockMeta(digest=r.digest(), length=r.u(_U64),
                        nodes=r.nodes())
              for _ in range(r.u(_U32))]
    r.done()
    return path, FileVersion(blocks=blocks, total_len=total_len,
                             timestamp=ts, merkle_root=root)


def enc_retire(path: str, keep_latest: int) -> bytes:
    return _enc_text(path) + _U32.pack(keep_latest)


def dec_retire(body: bytes) -> Tuple[str, int]:
    r = _RecReader(body)
    path, keep = r.text(), r.u(_U32)
    r.done()
    return path, keep


def enc_digest_list(digests: Sequence[bytes]) -> bytes:
    return _enc_digests(digests)


def dec_digest_list(body: bytes) -> List[bytes]:
    r = _RecReader(body)
    out = r.digests()
    r.done()
    return out


def enc_digest_nodes(digest: bytes, nodes: Sequence[int]) -> bytes:
    return _enc_digest(digest) + _enc_nodes(nodes)


def dec_digest_nodes(body: bytes) -> Tuple[bytes, Tuple[int, ...]]:
    r = _RecReader(body)
    d, nodes = r.digest(), r.nodes()
    r.done()
    return d, nodes


def enc_digest_node(digest: bytes, node_id: int) -> bytes:
    return _enc_digest(digest) + _U32.pack(node_id)


def dec_digest_node(body: bytes) -> Tuple[bytes, int]:
    r = _RecReader(body)
    d, nid = r.digest(), r.u(_U32)
    r.done()
    return d, nid


class MetadataManager:
    """Centralized manager: file -> versioned block-maps + block registry.

    Beyond placement and block-maps, the manager carries the state the
    storage-node runtime (repro.core.noderuntime) drives:

    * **reference counts** (``block_refs``): one count per committed
      block-map occurrence, incremented by ``commit_blockmap`` and
      decremented by ``retire_versions`` / ``delete_file``.  A digest
      whose count reaches zero is an orphan the GC may reclaim.
    * **pins** (``pin_blocks`` / ``unpin_blocks``): transient in-flight
      write protection — a writer pins its digests before the dedup
      claim and releases them after its block-map commit, so GC never
      reclaims a block between a dedup hit (or fresh store) and the
      commit that references it.
    * **quarantine** (``quarantine_block``): records a corrupt replica
      (digest, node), removes the node from the digest's registry
      locations so reads and placement avoid it, and notifies listeners
      (the runtime's repair pipeline) of the replica-count deficit.
    * **retire events** (``add_retire_listener``): version retirement
      reports newly-orphaned digests so the runtime GC can reclaim
      eagerly instead of rescanning the registry.
    """

    def __init__(self, nodes: Sequence[StorageNode], replication: int = 1,
                 wal: Optional[WriteAheadLog] = None):
        self.nodes = list(nodes)
        self.replication = max(1, replication)
        self.files: Dict[str, List[FileVersion]] = {}
        self.block_registry: Dict[bytes, Tuple[int, ...]] = {}
        self.block_refs: Dict[bytes, int] = {}
        self.quarantined: Dict[bytes, Set[int]] = {}
        self._pins: Dict[bytes, int] = {}
        self._claims: Dict[bytes, threading.Event] = {}
        self._retire_listeners: List[Callable] = []
        self._quarantine_listeners: List[Callable] = []
        self._rr = 0
        self._lock = threading.Lock()
        self.wal = wal
        self._replaying = False
        self.last_recovery: Optional["RecoveryReport"] = None
        if wal is not None:
            # data-before-metadata: every WAL group-commit flushes the
            # node block stores first, so a durable commit record never
            # references bytes that didn't make it to disk
            wal.pre_sync_hooks.append(self._flush_stores)

    # -- durability ----------------------------------------------------------
    def _flush_stores(self):
        for node in self.nodes:
            node.flush()

    def _log(self, kind: int, body: bytes) -> Optional[int]:
        """Append one WAL record for a transition just applied.  Must be
        called with ``self._lock`` held (record order mirrors lock
        order).  Returns the record's sequence number, or None when the
        store is in-memory or replaying."""
        wal = self.wal
        if wal is None or self._replaying or wal.crashed:
            return None
        seq = wal.append(kind, body)
        if (wal.snapshot_every > 0
                and wal.records_since_snapshot >= wal.snapshot_every):
            wal.snapshot(self._encode_snapshot_locked())
        return seq

    def wait_durable(self, seq: Optional[int] = None):
        """Block until WAL record ``seq`` (default: everything appended
        so far) — and therefore all block bytes it references — is on
        disk.  No-op for in-memory stores."""
        if self.wal is not None:
            self.wal.sync(seq)

    def snapshot(self) -> Optional[int]:
        """Force a snapshot + log compaction now.  Returns the snapshot
        sequence number (None for in-memory stores)."""
        if self.wal is None:
            return None
        with self._lock:
            return self.wal.snapshot(self._encode_snapshot_locked())

    def close(self):
        """Flush and close the durability layer (final compaction
        snapshot so the next open replays a near-empty tail)."""
        wal = self.wal
        if wal is not None and not wal.crashed:
            try:
                with self._lock:
                    wal.snapshot(self._encode_snapshot_locked())
            except Exception:
                pass
            wal.close()
        for node in self.nodes:
            if node.store is not None:
                node.store.close()

    # -- placement ---------------------------------------------------------
    def place(self, digest: bytes) -> Tuple[int, ...]:
        """Round-robin striping over live nodes with r replicas."""
        with self._lock:
            if digest in self.block_registry:
                locs = [n for n in self.block_registry[digest]
                        if not self.nodes[n].failed]
                if locs:
                    return tuple(locs)
            live = [n.node_id for n in self.nodes if not n.failed]
            if len(live) < self.replication:
                raise NodeFailure("not enough live nodes for replication")
            start = self._rr
            self._rr += 1
            return tuple(live[(start + k) % len(live)]
                         for k in range(self.replication))

    def register_block(self, digest: bytes, nodes: Tuple[int, ...]):
        with self._lock:
            prev = set(self.block_registry.get(digest, ()))
            self.block_registry[digest] = tuple(sorted(prev | set(nodes)))
            self._log(REC_REGISTER, enc_digest_nodes(digest, nodes))

    def lookup_block(self, digest: bytes) -> Tuple[int, ...]:
        with self._lock:
            return self.block_registry.get(digest, ())

    def lookup_blocks(self, digests) -> Dict[bytes, Tuple[int, ...]]:
        """Indexed digest->locations lookup for a whole write's digests
        under a single lock acquisition (the dedup fast path)."""
        with self._lock:
            reg = self.block_registry
            return {d: reg[d] for d in digests if d in reg}

    def claim_blocks(self, digests):
        """Atomic dedup decision for a whole write's digests under one
        lock: returns (locmap, claimed, waits) where ``locmap`` maps
        already-stored digests to locations, ``claimed`` is the set of
        digests this caller won the right (and duty) to store — it MUST
        call ``finish_claim`` for each, even on failure — and ``waits``
        maps digests being stored right now by a concurrent writer to
        events that fire when that store completes or aborts.  Prevents
        the check-then-act race where two store lanes both see a digest
        as absent and double-store the block."""
        locmap: Dict[bytes, Tuple[int, ...]] = {}
        claimed = set()
        waits: Dict[bytes, threading.Event] = {}
        with self._lock:
            reg = self.block_registry
            for d in digests:
                if d in locmap or d in claimed or d in waits:
                    continue
                locs = reg.get(d)
                if locs:
                    locmap[d] = locs
                elif d in self._claims:
                    waits[d] = self._claims[d]
                else:
                    self._claims[d] = threading.Event()
                    claimed.add(d)
            if claimed:
                self._log(REC_CLAIM, enc_digest_list(sorted(claimed)))
        return locmap, claimed, waits

    def finish_claim(self, digest: bytes,
                     nodes: Optional[Tuple[int, ...]] = None):
        """Complete (``nodes`` given: register the block) or abort
        (``nodes=None``) a claim from ``claim_blocks``, waking waiters
        either way."""
        with self._lock:
            if nodes:
                prev = set(self.block_registry.get(digest, ()))
                self.block_registry[digest] = tuple(sorted(prev
                                                           | set(nodes)))
            ev = self._claims.pop(digest, None)
            if ev is not None:
                self._log(REC_CLAIM_DONE,
                          enc_digest_nodes(digest, tuple(nodes or ())))
        if ev is not None:
            ev.set()

    # -- pins (in-flight write protection vs GC) -----------------------------
    def pin_blocks(self, digests):
        """Pin digests against GC for the duration of an in-flight write
        (claim -> store -> commit).  Counted: release with an identical
        ``unpin_blocks`` call."""
        with self._lock:
            pinned = sorted(set(digests))
            for d in pinned:
                self._pins[d] = self._pins.get(d, 0) + 1
            if pinned:
                self._log(REC_PIN, enc_digest_list(pinned))

    def unpin_blocks(self, digests):
        with self._lock:
            unpinned = sorted(set(digests))
            for d in unpinned:
                n = self._pins.get(d, 0) - 1
                if n > 0:
                    self._pins[d] = n
                else:
                    self._pins.pop(d, None)
            if unpinned:
                self._log(REC_UNPIN, enc_digest_list(unpinned))

    # -- block-maps ----------------------------------------------------------
    def commit_blockmap(self, path: str, blocks: List[BlockMeta],
                        total_len: int) -> Optional[int]:
        """Commit a new version.  Returns the WAL sequence number of the
        commit record (None for in-memory stores) — pass it to
        ``wait_durable`` to block until the version survives a crash."""
        root = merkle_root([b.digest for b in blocks])
        with self._lock:
            fv = FileVersion(blocks=blocks, total_len=total_len,
                             merkle_root=root)
            self.files.setdefault(path, []).append(fv)
            for b in blocks:
                self.block_refs[b.digest] = \
                    self.block_refs.get(b.digest, 0) + 1
            return self._log(REC_COMMIT, enc_commit(path, fv))

    def retire_versions(self, path: str, keep_latest: int = 1):
        """Retire old versions of ``path`` (``keep_latest=0`` deletes the
        file).  Decrements block refcounts and returns the list of
        newly-orphaned digests (refcount hit zero), which is also passed
        to retire listeners so the runtime GC can reclaim eagerly."""
        orphans: List[bytes] = []
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return orphans
            cut = max(0, len(versions) - keep_latest) if keep_latest > 0 \
                else len(versions)
            drop, keep = versions[:cut], versions[cut:]
            if keep:
                self.files[path] = keep
            else:
                self.files.pop(path, None)
            for v in drop:
                for b in v.blocks:
                    n = self.block_refs.get(b.digest, 0) - 1
                    if n > 0:
                        self.block_refs[b.digest] = n
                    else:
                        self.block_refs.pop(b.digest, None)
                        orphans.append(b.digest)
            if drop:
                self._log(REC_RETIRE, enc_retire(path, keep_latest))
            listeners = list(self._retire_listeners)
        for cb in listeners:
            try:
                cb(path, list(orphans))
            except Exception:
                pass
        return orphans

    def delete_file(self, path: str):
        return self.retire_versions(path, keep_latest=0)

    def add_retire_listener(self, cb: Callable):
        """cb(path, orphaned_digests) after versions are retired."""
        with self._lock:
            self._retire_listeners.append(cb)

    def get_blockmap(self, path: str,
                     version: int = -1) -> Optional[FileVersion]:
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None
            return versions[version]

    def get_read_plan(self, path: str, version: int = -1):
        """Block-map plus current replica locations for every block of a
        file version under ONE lock acquisition (the read fast path —
        the fetch stage avoids per-block ``lookup_block`` lock churn).
        Returns (FileVersion | None, {digest: locations})."""
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None, {}
            fv = versions[version]
            reg = self.block_registry
            return fv, {b.digest: reg[b.digest]
                        for b in fv.blocks if b.digest in reg}

    def num_versions(self, path: str) -> int:
        with self._lock:
            return len(self.files.get(path, ()))

    def stat_file(self, path: str,
                  version: int = -1) -> Optional[Dict[str, int]]:
        """File metadata for the gateway's STAT op under one lock:
        version count, the addressed version's byte length and block
        count.  None when the path (or version) does not exist."""
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None
            try:
                fv = versions[version]
            except IndexError:
                return None
            return {"versions": len(versions),
                    "total_len": fv.total_len,
                    "blocks": len(fv.blocks)}

    def list_files(self) -> List[str]:
        with self._lock:
            return sorted(self.files)

    # -- quarantine ----------------------------------------------------------
    def quarantine_block(self, digest: bytes, node_id: int):
        """Record that ``node_id``'s copy of ``digest`` is corrupt: the
        node is removed from the digest's registry locations (reads and
        placement avoid it), the node-side copy is tainted in place, and
        quarantine listeners (the runtime repair pipeline) are notified
        with the surviving healthy locations.  Returns those locations."""
        with self._lock:
            locs = self.block_registry.get(digest)
            remaining: Tuple[int, ...] = ()
            if locs is not None:
                remaining = tuple(n for n in locs if n != node_id)
                self.block_registry[digest] = remaining
            self.quarantined.setdefault(digest, set()).add(node_id)
            self._log(REC_QUAR, enc_digest_node(digest, node_id))
            listeners = list(self._quarantine_listeners)
        node = self.nodes[node_id]
        if not node.failed:
            node.taint(digest)
        for cb in listeners:
            try:
                cb(digest, node_id, remaining)
            except Exception:
                pass
        return remaining

    def is_quarantined(self, digest: bytes, node_id: int) -> bool:
        with self._lock:
            return node_id in self.quarantined.get(digest, ())

    def clear_quarantine(self, digest: bytes, node_id: int):
        """A verified fresh copy landed on ``node_id`` (repair)."""
        with self._lock:
            nodes = self.quarantined.get(digest)
            if nodes is not None:
                nodes.discard(node_id)
                if not nodes:
                    self.quarantined.pop(digest, None)
                self._log(REC_UNQUAR, enc_digest_node(digest, node_id))

    def add_quarantine_listener(self, cb: Callable):
        """cb(digest, node_id, remaining_locations) on quarantine."""
        with self._lock:
            self._quarantine_listeners.append(cb)

    def remove_quarantine_listener(self, cb: Callable):
        """Unsubscribe (no-op if absent) — closed SAIs/runtimes must
        not leak into a long-lived manager's listener list."""
        with self._lock:
            try:
                self._quarantine_listeners.remove(cb)
            except ValueError:
                pass

    # -- failure handling ----------------------------------------------------
    def handle_node_failure(self, node_id: int) -> int:
        """Re-replicate blocks that lost a replica.  Returns blocks moved."""
        self.nodes[node_id].fail()
        moved = 0
        updates: Dict[bytes, Tuple[int, ...]] = {}
        for digest, locs in list(self.block_registry.items()):
            live = [n for n in locs
                    if n != node_id and not self.nodes[n].failed]
            if len(live) >= self.replication:
                updates[digest] = tuple(live)
                continue
            if not live:
                continue                    # data loss (r=1): detected on read
            data = self.nodes[live[0]].get(digest)
            candidates = [n.node_id for n in self.nodes
                          if not n.failed and n.node_id not in live]
            for target in candidates[:self.replication - len(live)]:
                self.nodes[target].put(digest, data)
                live.append(target)
                moved += 1
            updates[digest] = tuple(sorted(live))
        with self._lock:
            for digest, locs in updates.items():
                self.block_registry[digest] = locs
                self._log(REC_RELOCATE, enc_digest_nodes(digest, locs))
        return moved

    def gc_collect(self, digests=None) -> int:
        """Reclaim orphaned blocks.  ``digests`` restricts the sweep to
        known candidates (retire-event orphans); default scans every
        registered digest with refcount zero.  A digest is reclaimed
        only if it is unreferenced, unpinned, AND unclaimed — a block a
        concurrent writer has claimed (or dedup-hit and pinned) is never
        collected, even at refcount zero.  Returns node-block copies
        freed (quarantined copies included)."""
        with self._lock:
            if digests is None:
                cands = [d for d in self.block_registry
                         if self.block_refs.get(d, 0) <= 0]
            else:
                cands = list(digests)
            victims = []
            for d in cands:
                if (self.block_refs.get(d, 0) > 0 or d in self._pins
                        or d in self._claims):
                    continue
                locs = set(self.block_registry.pop(d, ()))
                locs |= self.quarantined.pop(d, set())
                self.block_refs.pop(d, None)
                victims.append((d, locs))
            if victims:
                # logged before the node-side drops: replaying the GC
                # record after a mid-drop crash re-erases the registry
                # entries, and the orphaned on-disk copies are reclaimed
                # by recovery's unregistered-resident sweep
                self._log(REC_GC, enc_digest_list([d for d, _ in victims]))
        removed = 0
        for d, locs in victims:
            for nid in locs:
                node = self.nodes[nid]
                if not node.failed and node.drop(d):
                    removed += 1
        return removed

    def resync_refcounts(self) -> int:
        """Recount block refcounts from the committed block-maps — the
        authoritative source.  Recovers from out-of-band mutation of
        ``files`` (tests / administrative surgery).  Returns the number
        of digests whose count actually changed (drift) — zero after a
        clean WAL recovery, which is the crash-matrix invariant."""
        with self._lock:
            refs: Dict[bytes, int] = {}
            for versions in self.files.values():
                for v in versions:
                    for b in v.blocks:
                        refs[b.digest] = refs.get(b.digest, 0) + 1
            drift = sum(1 for d in set(refs) | set(self.block_refs)
                        if refs.get(d, 0) != self.block_refs.get(d, 0))
            self.block_refs = refs
            return drift

    def gc_unreferenced(self) -> int:
        """Full-scan GC: resync refcounts from the committed block-maps,
        then reclaim every orphan (refcount-zero registered digest)."""
        self.resync_refcounts()
        return self.gc_collect()

    def stats(self) -> dict:
        return {
            "files": len(self.files),
            "unique_blocks": len(self.block_registry),
            "stored_bytes": sum(n.used_bytes() for n in self.nodes
                                if not n.failed),
            "live_nodes": sum(not n.failed for n in self.nodes),
            "quarantined": sum(len(v) for v in self.quarantined.values()),
            "pinned": len(self._pins),
        }

    # -- snapshot codec ------------------------------------------------------
    def _encode_snapshot_locked(self) -> bytes:
        """Full manager state as one WAL snapshot payload (refcounts are
        recomputed from the block-maps at load, not serialized)."""
        parts = [_U8.pack(_SNAP_VERSION), _U32.pack(len(self.files))]
        for path in sorted(self.files):
            versions = self.files[path]
            parts.append(_enc_text(path))
            parts.append(_U32.pack(len(versions)))
            for fv in versions:
                parts.append(_U64.pack(fv.total_len))
                parts.append(_F64.pack(fv.timestamp))
                parts.append(_U16.pack(len(fv.merkle_root)))
                parts.append(bytes(fv.merkle_root))
                parts.append(_U32.pack(len(fv.blocks)))
                for b in fv.blocks:
                    parts.append(_enc_digest(b.digest))
                    parts.append(_U64.pack(b.length))
                    parts.append(_enc_nodes(b.nodes))
        parts.append(_U32.pack(len(self.block_registry)))
        for d in sorted(self.block_registry):
            parts.append(_enc_digest(d))
            parts.append(_enc_nodes(self.block_registry[d]))
        parts.append(_U32.pack(len(self.quarantined)))
        for d in sorted(self.quarantined):
            parts.append(_enc_digest(d))
            parts.append(_enc_nodes(sorted(self.quarantined[d])))
        return b"".join(parts)

    def _load_snapshot_locked(self, payload: bytes):
        r = _RecReader(payload)
        version = r.u(_U8)
        if version != _SNAP_VERSION:
            raise WALError(f"unknown snapshot version {version}")
        files: Dict[str, List[FileVersion]] = {}
        for _ in range(r.u(_U32)):
            path = r.text()
            versions = []
            for _ in range(r.u(_U32)):
                total_len = r.u(_U64)
                ts = r.u(_F64)
                root = r.raw(r.u(_U16))
                blocks = [BlockMeta(digest=r.digest(), length=r.u(_U64),
                                    nodes=r.nodes())
                          for _ in range(r.u(_U32))]
                versions.append(FileVersion(blocks=blocks,
                                            total_len=total_len,
                                            timestamp=ts,
                                            merkle_root=root))
            files[path] = versions
        registry: Dict[bytes, Tuple[int, ...]] = {}
        for _ in range(r.u(_U32)):
            d = r.digest()
            registry[d] = r.nodes()
        quarantined: Dict[bytes, Set[int]] = {}
        for _ in range(r.u(_U32)):
            d = r.digest()
            quarantined[d] = set(r.nodes())
        r.done()
        self.files = files
        self.block_registry = dict(registry)
        self.quarantined = quarantined
        refs: Dict[bytes, int] = {}
        for versions in files.values():
            for v in versions:
                for b in v.blocks:
                    refs[b.digest] = refs.get(b.digest, 0) + 1
        self.block_refs = refs

    # -- replay --------------------------------------------------------------
    def _apply_record(self, kind: int, body: bytes,
                      open_claims: Set[bytes]):
        """Re-apply one WAL record to in-memory state (no re-logging, no
        listeners, no node side effects — those are re-derived in the
        recovery finalize pass)."""
        if kind == REC_COMMIT:
            path, fv = dec_commit(body)
            self.files.setdefault(path, []).append(fv)
            for b in fv.blocks:
                self.block_refs[b.digest] = \
                    self.block_refs.get(b.digest, 0) + 1
        elif kind == REC_RETIRE:
            path, keep = dec_retire(body)
            versions = self.files.get(path)
            if not versions:
                return
            cut = max(0, len(versions) - keep) if keep > 0 \
                else len(versions)
            drop, keep_vs = versions[:cut], versions[cut:]
            if keep_vs:
                self.files[path] = keep_vs
            else:
                self.files.pop(path, None)
            for v in drop:
                for b in v.blocks:
                    n = self.block_refs.get(b.digest, 0) - 1
                    if n > 0:
                        self.block_refs[b.digest] = n
                    else:
                        self.block_refs.pop(b.digest, None)
        elif kind == REC_CLAIM:
            open_claims.update(dec_digest_list(body))
        elif kind == REC_CLAIM_DONE:
            d, nodes = dec_digest_nodes(body)
            open_claims.discard(d)
            if nodes:
                prev = set(self.block_registry.get(d, ()))
                self.block_registry[d] = tuple(sorted(prev | set(nodes)))
        elif kind == REC_REGISTER:
            d, nodes = dec_digest_nodes(body)
            prev = set(self.block_registry.get(d, ()))
            self.block_registry[d] = tuple(sorted(prev | set(nodes)))
        elif kind == REC_RELOCATE:
            d, nodes = dec_digest_nodes(body)
            self.block_registry[d] = tuple(nodes)
        elif kind == REC_QUAR:
            d, nid = dec_digest_node(body)
            locs = self.block_registry.get(d)
            if locs is not None:
                self.block_registry[d] = tuple(n for n in locs
                                               if n != nid)
            self.quarantined.setdefault(d, set()).add(nid)
        elif kind == REC_UNQUAR:
            d, nid = dec_digest_node(body)
            nodes = self.quarantined.get(d)
            if nodes is not None:
                nodes.discard(nid)
                if not nodes:
                    self.quarantined.pop(d, None)
        elif kind == REC_PIN:
            for d in dec_digest_list(body):
                self._pins[d] = self._pins.get(d, 0) + 1
        elif kind == REC_UNPIN:
            for d in dec_digest_list(body):
                n = self._pins.get(d, 0) - 1
                if n > 0:
                    self._pins[d] = n
                else:
                    self._pins.pop(d, None)
        elif kind == REC_GC:
            for d in dec_digest_list(body):
                self.block_registry.pop(d, None)
                self.block_refs.pop(d, None)
                self.quarantined.pop(d, None)
        else:
            raise WALError(f"unknown WAL record kind {kind}")

    def recover(self) -> "RecoveryReport":
        """Rebuild state from the WAL's recovered snapshot + tail and
        reconcile it against what actually survived on the node block
        stores.  Ordering:

        1. load the newest valid snapshot, replay the record tail
           (stopping at the first undecodable record);
        2. resolve half-open claims — *adopt* a claim whose block is
           resident somewhere (register those locations so a retrying
           writer dedups instead of double-storing), *release* the rest;
        3. prune registry locations whose node no longer holds the
           block (torn segment tail); a referenced digest with zero
           surviving locations is reported ``lost``;
        4. drop resident blocks no committed/claimed state references
           (stored, never registered — the crashed writer's waste);
        5. re-taint resident quarantined copies, clear stale pins
           (crashed writers hold none), verify refcounts (drift must be
           0 — replay and commit logic agree or recovery is broken).

        Block-integrity verification of the stores' *suspect* trailing
        blocks is NOT done here — hand ``report.suspects`` to
        ``ClusterRuntime.scrub_suspects`` so the engine does the hashing
        (recovery is a scrub workload)."""
        report = RecoveryReport()
        wal = self.wal
        if wal is None:
            self.last_recovery = report
            return report
        t0 = time.perf_counter()
        open_claims: Set[bytes] = set()
        with self._lock:
            self._replaying = True
            try:
                if wal.recovered_snapshot is not None:
                    self._load_snapshot_locked(wal.recovered_snapshot)
                    report.snapshot_seq = wal.recovered_seq
                report.torn_tail = wal.torn_tail
                for seq, kind, body in wal.recovered_records:
                    try:
                        self._apply_record(kind, body, open_claims)
                    except WALError:
                        # undecodable record: stop at the last good one
                        report.bad_records += 1
                        break
                    report.replayed += 1

                resident: Dict[int, Set[bytes]] = {}
                for node in self.nodes:
                    if node.store is not None:
                        resident[node.node_id] = set(node.store.digests())
                        report.suspects[node.node_id] = \
                            list(node.store.suspects)

                # 2. half-open claims: adopt if the block survived
                for d in sorted(open_claims):
                    locs = tuple(sorted(
                        nid for nid, digs in resident.items() if d in digs))
                    if locs:
                        prev = set(self.block_registry.get(d, ()))
                        self.block_registry[d] = tuple(sorted(prev
                                                              | set(locs)))
                        report.adopted_claims.append(d)
                    else:
                        report.released_claims.append(d)

                # 3. prune registry locations that didn't survive
                if resident:
                    for d, locs in list(self.block_registry.items()):
                        keep = tuple(n for n in locs
                                     if d in resident.get(n, ()))
                        if keep != locs:
                            report.pruned_locations += \
                                len(locs) - len(keep)
                            self.block_registry[d] = keep
                            if not keep and self.block_refs.get(d, 0) > 0:
                                report.lost_blocks.append(d)

                    # 4. resident blocks nothing references: reclaim
                    registered = set(self.block_registry)
                    for node in self.nodes:
                        if node.store is None:
                            continue
                        for d in resident[node.node_id] - registered:
                            node.store.drop(d)
                            report.dropped_unregistered += 1

                # 5. re-taint quarantined residents, clear stale pins
                for d, nids in self.quarantined.items():
                    for nid in nids:
                        if d in resident.get(nid, ()):
                            self.nodes[nid].tainted.add(d)
                report.dropped_pins = len(self._pins)
                self._pins.clear()
                self._claims.clear()
            finally:
                self._replaying = False
        report.refcount_drift = self.resync_refcounts()
        report.wall_s = time.perf_counter() - t0
        self.last_recovery = report
        return report


@dataclass
class RecoveryReport:
    """What a WAL+blockstore recovery found and fixed."""
    wall_s: float = 0.0
    snapshot_seq: int = 0              # seq of the snapshot restored
    replayed: int = 0                  # tail records applied
    bad_records: int = 0               # undecodable records (replay stop)
    torn_tail: bool = False            # garbage truncated from the log
    adopted_claims: List[bytes] = field(default_factory=list)
    released_claims: List[bytes] = field(default_factory=list)
    pruned_locations: int = 0          # registry locations not resident
    lost_blocks: List[bytes] = field(default_factory=list)
    dropped_unregistered: int = 0      # resident blocks nothing references
    dropped_pins: int = 0              # stale writer pins cleared
    refcount_drift: int = 0            # must be 0 (replay == commit logic)
    suspects: Dict[int, List[bytes]] = field(default_factory=dict)


def open_durable_store(data_dir: str, n_nodes: int = 4,
                       replication: int = 1, *,
                       flush_interval_s: float = 0.002,
                       snapshot_every: int = 1024,
                       segment_bytes: int = 8 << 20,
                       fsync: bool = True,
                       fault: Optional[FaultInjector] = None,
                       ) -> Tuple[MetadataManager, List[StorageNode],
                                  RecoveryReport]:
    """Open (or create) a durable store rooted at ``data_dir``: one
    block-store directory per node plus the metadata WAL under
    ``meta/``.  Recovery runs before this returns; hand
    ``report.suspects`` to ``ClusterRuntime.scrub_suspects`` for
    engine-verified integrity of the trailing blocks."""
    nodes = [StorageNode(i, store=BlockStore(
        os.path.join(data_dir, f"node{i:03d}"),
        segment_bytes=segment_bytes, fsync=fsync, fault=fault))
        for i in range(n_nodes)]
    wal = WriteAheadLog(os.path.join(data_dir, "meta"),
                        flush_interval_s=flush_interval_s,
                        snapshot_every=snapshot_every,
                        fsync=fsync, fault=fault)
    mgr = MetadataManager(nodes, replication=replication, wal=wal)
    report = mgr.recover()
    return mgr, nodes, report


def make_store(n_nodes: int = 4, replication: int = 1,
               data_dir: Optional[str] = None,
               **durable_kw) -> Tuple[MetadataManager, List[StorageNode]]:
    """In-memory store by default; pass ``data_dir`` for a durable one
    (recovery report lands on ``manager.last_recovery``)."""
    if data_dir is not None:
        mgr, nodes, _ = open_durable_store(
            data_dir, n_nodes=n_nodes, replication=replication,
            **durable_kw)
        return mgr, nodes
    nodes = [StorageNode(i) for i in range(n_nodes)]
    return MetadataManager(nodes, replication=replication), nodes
