"""Content-addressable distributed storage substrate (MosaStore analog).

Object-based architecture mirroring the paper's Figure 2: a centralized
metadata manager holding per-file block-maps (block hash, length, replica
locations), N storage nodes holding blocks keyed by content hash, and
client-side striping over nodes.  Replication + node-failure handling +
re-replication give the fault-tolerance substrate the training framework's
checkpoint layer builds on.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.integrity import merkle_root


class NodeFailure(RuntimeError):
    pass


class StorageNode:
    """One storage node: content-hash -> block bytes.

    A digest can be *tainted* (quarantined in place): the scrubber or a
    read-path verify failure found the resident copy corrupt.  Tainted
    copies are excluded from ``has`` / ``healthy_digests`` — placement
    and scrubbing treat them as gone — but ``get`` still serves them so
    unverified last-resort reads keep working until repair lands a fresh
    copy (``put`` on the digest clears the taint)."""

    def __init__(self, node_id: int):
        self.node_id = node_id
        self.blocks: Dict[bytes, bytes] = {}
        self.tainted: Set[bytes] = set()
        self.failed = False
        self._lock = threading.Lock()
        self.put_count = 0
        self.get_count = 0

    def put(self, digest: bytes, data: bytes):
        if self.failed:
            raise NodeFailure(f"node {self.node_id} down")
        with self._lock:
            self.blocks[digest] = data
            self.tainted.discard(digest)
            self.put_count += 1

    def get(self, digest: bytes) -> bytes:
        if self.failed:
            raise NodeFailure(f"node {self.node_id} down")
        with self._lock:
            self.get_count += 1
            if digest not in self.blocks:
                raise KeyError(digest.hex())
            return self.blocks[digest]

    def has(self, digest: bytes) -> bool:
        return (not self.failed and digest in self.blocks
                and digest not in self.tainted)

    def taint(self, digest: bytes) -> bool:
        """Quarantine the resident copy in place (corrupt bytes kept for
        last-resort unverified reads).  Returns True if the digest was
        resident."""
        with self._lock:
            if digest not in self.blocks:
                return False
            self.tainted.add(digest)
            return True

    def drop(self, digest: bytes) -> bool:
        """Reclaim a block (GC).  Returns True if bytes were freed."""
        with self._lock:
            self.tainted.discard(digest)
            return self.blocks.pop(digest, None) is not None

    def healthy_digests(self) -> List[bytes]:
        """Snapshot of resident, non-tainted digests (the scrub set)."""
        with self._lock:
            return [d for d in self.blocks if d not in self.tainted]

    def used_bytes(self) -> int:
        return sum(len(v) for v in self.blocks.values())

    def fail(self):
        self.failed = True

    def recover_empty(self):
        self.failed = False
        self.blocks.clear()
        self.tainted.clear()


@dataclass
class BlockMeta:
    digest: bytes
    length: int
    nodes: Tuple[int, ...]            # replica locations


@dataclass
class FileVersion:
    blocks: List[BlockMeta]
    total_len: int
    timestamp: float = field(default_factory=time.time)
    # file-level Merkle root over the block digests (leaf order = block
    # order): commits the whole version, lets the scrubber spot-check a
    # single sampled block via integrity.merkle_proof without refetching
    # the file
    merkle_root: bytes = b""


class MetadataManager:
    """Centralized manager: file -> versioned block-maps + block registry.

    Beyond placement and block-maps, the manager carries the state the
    storage-node runtime (repro.core.noderuntime) drives:

    * **reference counts** (``block_refs``): one count per committed
      block-map occurrence, incremented by ``commit_blockmap`` and
      decremented by ``retire_versions`` / ``delete_file``.  A digest
      whose count reaches zero is an orphan the GC may reclaim.
    * **pins** (``pin_blocks`` / ``unpin_blocks``): transient in-flight
      write protection — a writer pins its digests before the dedup
      claim and releases them after its block-map commit, so GC never
      reclaims a block between a dedup hit (or fresh store) and the
      commit that references it.
    * **quarantine** (``quarantine_block``): records a corrupt replica
      (digest, node), removes the node from the digest's registry
      locations so reads and placement avoid it, and notifies listeners
      (the runtime's repair pipeline) of the replica-count deficit.
    * **retire events** (``add_retire_listener``): version retirement
      reports newly-orphaned digests so the runtime GC can reclaim
      eagerly instead of rescanning the registry.
    """

    def __init__(self, nodes: Sequence[StorageNode], replication: int = 1):
        self.nodes = list(nodes)
        self.replication = max(1, replication)
        self.files: Dict[str, List[FileVersion]] = {}
        self.block_registry: Dict[bytes, Tuple[int, ...]] = {}
        self.block_refs: Dict[bytes, int] = {}
        self.quarantined: Dict[bytes, Set[int]] = {}
        self._pins: Dict[bytes, int] = {}
        self._claims: Dict[bytes, threading.Event] = {}
        self._retire_listeners: List[Callable] = []
        self._quarantine_listeners: List[Callable] = []
        self._rr = 0
        self._lock = threading.Lock()

    # -- placement ---------------------------------------------------------
    def place(self, digest: bytes) -> Tuple[int, ...]:
        """Round-robin striping over live nodes with r replicas."""
        with self._lock:
            if digest in self.block_registry:
                locs = [n for n in self.block_registry[digest]
                        if not self.nodes[n].failed]
                if locs:
                    return tuple(locs)
            live = [n.node_id for n in self.nodes if not n.failed]
            if len(live) < self.replication:
                raise NodeFailure("not enough live nodes for replication")
            start = self._rr
            self._rr += 1
            return tuple(live[(start + k) % len(live)]
                         for k in range(self.replication))

    def register_block(self, digest: bytes, nodes: Tuple[int, ...]):
        with self._lock:
            prev = set(self.block_registry.get(digest, ()))
            self.block_registry[digest] = tuple(sorted(prev | set(nodes)))

    def lookup_block(self, digest: bytes) -> Tuple[int, ...]:
        with self._lock:
            return self.block_registry.get(digest, ())

    def lookup_blocks(self, digests) -> Dict[bytes, Tuple[int, ...]]:
        """Indexed digest->locations lookup for a whole write's digests
        under a single lock acquisition (the dedup fast path)."""
        with self._lock:
            reg = self.block_registry
            return {d: reg[d] for d in digests if d in reg}

    def claim_blocks(self, digests):
        """Atomic dedup decision for a whole write's digests under one
        lock: returns (locmap, claimed, waits) where ``locmap`` maps
        already-stored digests to locations, ``claimed`` is the set of
        digests this caller won the right (and duty) to store — it MUST
        call ``finish_claim`` for each, even on failure — and ``waits``
        maps digests being stored right now by a concurrent writer to
        events that fire when that store completes or aborts.  Prevents
        the check-then-act race where two store lanes both see a digest
        as absent and double-store the block."""
        locmap: Dict[bytes, Tuple[int, ...]] = {}
        claimed = set()
        waits: Dict[bytes, threading.Event] = {}
        with self._lock:
            reg = self.block_registry
            for d in digests:
                if d in locmap or d in claimed or d in waits:
                    continue
                locs = reg.get(d)
                if locs:
                    locmap[d] = locs
                elif d in self._claims:
                    waits[d] = self._claims[d]
                else:
                    self._claims[d] = threading.Event()
                    claimed.add(d)
        return locmap, claimed, waits

    def finish_claim(self, digest: bytes,
                     nodes: Optional[Tuple[int, ...]] = None):
        """Complete (``nodes`` given: register the block) or abort
        (``nodes=None``) a claim from ``claim_blocks``, waking waiters
        either way."""
        with self._lock:
            if nodes:
                prev = set(self.block_registry.get(digest, ()))
                self.block_registry[digest] = tuple(sorted(prev
                                                           | set(nodes)))
            ev = self._claims.pop(digest, None)
        if ev is not None:
            ev.set()

    # -- pins (in-flight write protection vs GC) -----------------------------
    def pin_blocks(self, digests):
        """Pin digests against GC for the duration of an in-flight write
        (claim -> store -> commit).  Counted: release with an identical
        ``unpin_blocks`` call."""
        with self._lock:
            for d in set(digests):
                self._pins[d] = self._pins.get(d, 0) + 1

    def unpin_blocks(self, digests):
        with self._lock:
            for d in set(digests):
                n = self._pins.get(d, 0) - 1
                if n > 0:
                    self._pins[d] = n
                else:
                    self._pins.pop(d, None)

    # -- block-maps ----------------------------------------------------------
    def commit_blockmap(self, path: str, blocks: List[BlockMeta],
                        total_len: int):
        root = merkle_root([b.digest for b in blocks])
        with self._lock:
            self.files.setdefault(path, []).append(
                FileVersion(blocks=blocks, total_len=total_len,
                            merkle_root=root))
            for b in blocks:
                self.block_refs[b.digest] = \
                    self.block_refs.get(b.digest, 0) + 1

    def retire_versions(self, path: str, keep_latest: int = 1):
        """Retire old versions of ``path`` (``keep_latest=0`` deletes the
        file).  Decrements block refcounts and returns the list of
        newly-orphaned digests (refcount hit zero), which is also passed
        to retire listeners so the runtime GC can reclaim eagerly."""
        orphans: List[bytes] = []
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return orphans
            cut = max(0, len(versions) - keep_latest) if keep_latest > 0 \
                else len(versions)
            drop, keep = versions[:cut], versions[cut:]
            if keep:
                self.files[path] = keep
            else:
                self.files.pop(path, None)
            for v in drop:
                for b in v.blocks:
                    n = self.block_refs.get(b.digest, 0) - 1
                    if n > 0:
                        self.block_refs[b.digest] = n
                    else:
                        self.block_refs.pop(b.digest, None)
                        orphans.append(b.digest)
            listeners = list(self._retire_listeners)
        for cb in listeners:
            try:
                cb(path, list(orphans))
            except Exception:
                pass
        return orphans

    def delete_file(self, path: str):
        return self.retire_versions(path, keep_latest=0)

    def add_retire_listener(self, cb: Callable):
        """cb(path, orphaned_digests) after versions are retired."""
        with self._lock:
            self._retire_listeners.append(cb)

    def get_blockmap(self, path: str,
                     version: int = -1) -> Optional[FileVersion]:
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None
            return versions[version]

    def get_read_plan(self, path: str, version: int = -1):
        """Block-map plus current replica locations for every block of a
        file version under ONE lock acquisition (the read fast path —
        the fetch stage avoids per-block ``lookup_block`` lock churn).
        Returns (FileVersion | None, {digest: locations})."""
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None, {}
            fv = versions[version]
            reg = self.block_registry
            return fv, {b.digest: reg[b.digest]
                        for b in fv.blocks if b.digest in reg}

    def num_versions(self, path: str) -> int:
        with self._lock:
            return len(self.files.get(path, ()))

    def stat_file(self, path: str,
                  version: int = -1) -> Optional[Dict[str, int]]:
        """File metadata for the gateway's STAT op under one lock:
        version count, the addressed version's byte length and block
        count.  None when the path (or version) does not exist."""
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None
            try:
                fv = versions[version]
            except IndexError:
                return None
            return {"versions": len(versions),
                    "total_len": fv.total_len,
                    "blocks": len(fv.blocks)}

    def list_files(self) -> List[str]:
        with self._lock:
            return sorted(self.files)

    # -- quarantine ----------------------------------------------------------
    def quarantine_block(self, digest: bytes, node_id: int):
        """Record that ``node_id``'s copy of ``digest`` is corrupt: the
        node is removed from the digest's registry locations (reads and
        placement avoid it), the node-side copy is tainted in place, and
        quarantine listeners (the runtime repair pipeline) are notified
        with the surviving healthy locations.  Returns those locations."""
        with self._lock:
            locs = self.block_registry.get(digest)
            remaining: Tuple[int, ...] = ()
            if locs is not None:
                remaining = tuple(n for n in locs if n != node_id)
                self.block_registry[digest] = remaining
            self.quarantined.setdefault(digest, set()).add(node_id)
            listeners = list(self._quarantine_listeners)
        node = self.nodes[node_id]
        if not node.failed:
            node.taint(digest)
        for cb in listeners:
            try:
                cb(digest, node_id, remaining)
            except Exception:
                pass
        return remaining

    def is_quarantined(self, digest: bytes, node_id: int) -> bool:
        with self._lock:
            return node_id in self.quarantined.get(digest, ())

    def clear_quarantine(self, digest: bytes, node_id: int):
        """A verified fresh copy landed on ``node_id`` (repair)."""
        with self._lock:
            nodes = self.quarantined.get(digest)
            if nodes is not None:
                nodes.discard(node_id)
                if not nodes:
                    self.quarantined.pop(digest, None)

    def add_quarantine_listener(self, cb: Callable):
        """cb(digest, node_id, remaining_locations) on quarantine."""
        with self._lock:
            self._quarantine_listeners.append(cb)

    def remove_quarantine_listener(self, cb: Callable):
        """Unsubscribe (no-op if absent) — closed SAIs/runtimes must
        not leak into a long-lived manager's listener list."""
        with self._lock:
            try:
                self._quarantine_listeners.remove(cb)
            except ValueError:
                pass

    # -- failure handling ----------------------------------------------------
    def handle_node_failure(self, node_id: int) -> int:
        """Re-replicate blocks that lost a replica.  Returns blocks moved."""
        self.nodes[node_id].fail()
        moved = 0
        for digest, locs in list(self.block_registry.items()):
            live = [n for n in locs
                    if n != node_id and not self.nodes[n].failed]
            if len(live) >= self.replication:
                self.block_registry[digest] = tuple(live)
                continue
            if not live:
                continue                    # data loss (r=1): detected on read
            data = self.nodes[live[0]].get(digest)
            candidates = [n.node_id for n in self.nodes
                          if not n.failed and n.node_id not in live]
            for target in candidates[:self.replication - len(live)]:
                self.nodes[target].put(digest, data)
                live.append(target)
                moved += 1
            self.block_registry[digest] = tuple(sorted(live))
        return moved

    def gc_collect(self, digests=None) -> int:
        """Reclaim orphaned blocks.  ``digests`` restricts the sweep to
        known candidates (retire-event orphans); default scans every
        registered digest with refcount zero.  A digest is reclaimed
        only if it is unreferenced, unpinned, AND unclaimed — a block a
        concurrent writer has claimed (or dedup-hit and pinned) is never
        collected, even at refcount zero.  Returns node-block copies
        freed (quarantined copies included)."""
        with self._lock:
            if digests is None:
                cands = [d for d in self.block_registry
                         if self.block_refs.get(d, 0) <= 0]
            else:
                cands = list(digests)
            victims = []
            for d in cands:
                if (self.block_refs.get(d, 0) > 0 or d in self._pins
                        or d in self._claims):
                    continue
                locs = set(self.block_registry.pop(d, ()))
                locs |= self.quarantined.pop(d, set())
                self.block_refs.pop(d, None)
                victims.append((d, locs))
        removed = 0
        for d, locs in victims:
            for nid in locs:
                node = self.nodes[nid]
                if not node.failed and node.drop(d):
                    removed += 1
        return removed

    def resync_refcounts(self):
        """Recount block refcounts from the committed block-maps — the
        authoritative source.  Recovers from out-of-band mutation of
        ``files`` (tests / administrative surgery)."""
        with self._lock:
            refs: Dict[bytes, int] = {}
            for versions in self.files.values():
                for v in versions:
                    for b in v.blocks:
                        refs[b.digest] = refs.get(b.digest, 0) + 1
            self.block_refs = refs

    def gc_unreferenced(self) -> int:
        """Full-scan GC: resync refcounts from the committed block-maps,
        then reclaim every orphan (refcount-zero registered digest)."""
        self.resync_refcounts()
        return self.gc_collect()

    def stats(self) -> dict:
        return {
            "files": len(self.files),
            "unique_blocks": len(self.block_registry),
            "stored_bytes": sum(n.used_bytes() for n in self.nodes
                                if not n.failed),
            "live_nodes": sum(not n.failed for n in self.nodes),
            "quarantined": sum(len(v) for v in self.quarantined.values()),
            "pinned": len(self._pins),
        }


def make_store(n_nodes: int = 4,
               replication: int = 1) -> Tuple[MetadataManager,
                                              List[StorageNode]]:
    nodes = [StorageNode(i) for i in range(n_nodes)]
    return MetadataManager(nodes, replication=replication), nodes
