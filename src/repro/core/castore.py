"""Content-addressable distributed storage substrate (MosaStore analog).

Object-based architecture mirroring the paper's Figure 2: a centralized
metadata manager holding per-file block-maps (block hash, length, replica
locations), N storage nodes holding blocks keyed by content hash, and
client-side striping over nodes.  Replication + node-failure handling +
re-replication give the fault-tolerance substrate the training framework's
checkpoint layer builds on.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class NodeFailure(RuntimeError):
    pass


class StorageNode:
    """One storage node: content-hash -> block bytes."""

    def __init__(self, node_id: int):
        self.node_id = node_id
        self.blocks: Dict[bytes, bytes] = {}
        self.failed = False
        self._lock = threading.Lock()
        self.put_count = 0
        self.get_count = 0

    def put(self, digest: bytes, data: bytes):
        if self.failed:
            raise NodeFailure(f"node {self.node_id} down")
        with self._lock:
            self.blocks[digest] = data
            self.put_count += 1

    def get(self, digest: bytes) -> bytes:
        if self.failed:
            raise NodeFailure(f"node {self.node_id} down")
        with self._lock:
            self.get_count += 1
            if digest not in self.blocks:
                raise KeyError(digest.hex())
            return self.blocks[digest]

    def has(self, digest: bytes) -> bool:
        return not self.failed and digest in self.blocks

    def used_bytes(self) -> int:
        return sum(len(v) for v in self.blocks.values())

    def fail(self):
        self.failed = True

    def recover_empty(self):
        self.failed = False
        self.blocks.clear()


@dataclass
class BlockMeta:
    digest: bytes
    length: int
    nodes: Tuple[int, ...]            # replica locations


@dataclass
class FileVersion:
    blocks: List[BlockMeta]
    total_len: int
    timestamp: float = field(default_factory=time.time)


class MetadataManager:
    """Centralized manager: file -> versioned block-maps + block registry."""

    def __init__(self, nodes: Sequence[StorageNode], replication: int = 1):
        self.nodes = list(nodes)
        self.replication = max(1, replication)
        self.files: Dict[str, List[FileVersion]] = {}
        self.block_registry: Dict[bytes, Tuple[int, ...]] = {}
        self._claims: Dict[bytes, threading.Event] = {}
        self._rr = 0
        self._lock = threading.Lock()

    # -- placement ---------------------------------------------------------
    def place(self, digest: bytes) -> Tuple[int, ...]:
        """Round-robin striping over live nodes with r replicas."""
        with self._lock:
            if digest in self.block_registry:
                locs = [n for n in self.block_registry[digest]
                        if not self.nodes[n].failed]
                if locs:
                    return tuple(locs)
            live = [n.node_id for n in self.nodes if not n.failed]
            if len(live) < self.replication:
                raise NodeFailure("not enough live nodes for replication")
            start = self._rr
            self._rr += 1
            return tuple(live[(start + k) % len(live)]
                         for k in range(self.replication))

    def register_block(self, digest: bytes, nodes: Tuple[int, ...]):
        with self._lock:
            prev = set(self.block_registry.get(digest, ()))
            self.block_registry[digest] = tuple(sorted(prev | set(nodes)))

    def lookup_block(self, digest: bytes) -> Tuple[int, ...]:
        with self._lock:
            return self.block_registry.get(digest, ())

    def lookup_blocks(self, digests) -> Dict[bytes, Tuple[int, ...]]:
        """Indexed digest->locations lookup for a whole write's digests
        under a single lock acquisition (the dedup fast path)."""
        with self._lock:
            reg = self.block_registry
            return {d: reg[d] for d in digests if d in reg}

    def claim_blocks(self, digests):
        """Atomic dedup decision for a whole write's digests under one
        lock: returns (locmap, claimed, waits) where ``locmap`` maps
        already-stored digests to locations, ``claimed`` is the set of
        digests this caller won the right (and duty) to store — it MUST
        call ``finish_claim`` for each, even on failure — and ``waits``
        maps digests being stored right now by a concurrent writer to
        events that fire when that store completes or aborts.  Prevents
        the check-then-act race where two store lanes both see a digest
        as absent and double-store the block."""
        locmap: Dict[bytes, Tuple[int, ...]] = {}
        claimed = set()
        waits: Dict[bytes, threading.Event] = {}
        with self._lock:
            reg = self.block_registry
            for d in digests:
                if d in locmap or d in claimed or d in waits:
                    continue
                locs = reg.get(d)
                if locs:
                    locmap[d] = locs
                elif d in self._claims:
                    waits[d] = self._claims[d]
                else:
                    self._claims[d] = threading.Event()
                    claimed.add(d)
        return locmap, claimed, waits

    def finish_claim(self, digest: bytes,
                     nodes: Optional[Tuple[int, ...]] = None):
        """Complete (``nodes`` given: register the block) or abort
        (``nodes=None``) a claim from ``claim_blocks``, waking waiters
        either way."""
        with self._lock:
            if nodes:
                prev = set(self.block_registry.get(digest, ()))
                self.block_registry[digest] = tuple(sorted(prev
                                                           | set(nodes)))
            ev = self._claims.pop(digest, None)
        if ev is not None:
            ev.set()

    # -- block-maps ----------------------------------------------------------
    def commit_blockmap(self, path: str, blocks: List[BlockMeta],
                        total_len: int):
        with self._lock:
            self.files.setdefault(path, []).append(
                FileVersion(blocks=blocks, total_len=total_len))

    def get_blockmap(self, path: str,
                     version: int = -1) -> Optional[FileVersion]:
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None
            return versions[version]

    def get_read_plan(self, path: str, version: int = -1):
        """Block-map plus current replica locations for every block of a
        file version under ONE lock acquisition (the read fast path —
        the fetch stage avoids per-block ``lookup_block`` lock churn).
        Returns (FileVersion | None, {digest: locations})."""
        with self._lock:
            versions = self.files.get(path)
            if not versions:
                return None, {}
            fv = versions[version]
            reg = self.block_registry
            return fv, {b.digest: reg[b.digest]
                        for b in fv.blocks if b.digest in reg}

    def num_versions(self, path: str) -> int:
        with self._lock:
            return len(self.files.get(path, ()))

    def list_files(self) -> List[str]:
        with self._lock:
            return sorted(self.files)

    # -- failure handling ----------------------------------------------------
    def handle_node_failure(self, node_id: int) -> int:
        """Re-replicate blocks that lost a replica.  Returns blocks moved."""
        self.nodes[node_id].fail()
        moved = 0
        for digest, locs in list(self.block_registry.items()):
            live = [n for n in locs
                    if n != node_id and not self.nodes[n].failed]
            if len(live) >= self.replication:
                self.block_registry[digest] = tuple(live)
                continue
            if not live:
                continue                    # data loss (r=1): detected on read
            data = self.nodes[live[0]].get(digest)
            candidates = [n.node_id for n in self.nodes
                          if not n.failed and n.node_id not in live]
            for target in candidates[:self.replication - len(live)]:
                self.nodes[target].put(digest, data)
                live.append(target)
                moved += 1
            self.block_registry[digest] = tuple(sorted(live))
        return moved

    def gc_unreferenced(self) -> int:
        """Delete blocks not referenced by any committed block-map."""
        referenced = set()
        for versions in self.files.values():
            for v in versions:
                for b in v.blocks:
                    referenced.add(b.digest)
        removed = 0
        for digest in list(self.block_registry):
            if digest in referenced:
                continue
            for nid in self.block_registry[digest]:
                node = self.nodes[nid]
                if not node.failed:
                    node.blocks.pop(digest, None)
                    removed += 1
            del self.block_registry[digest]
        return removed

    def stats(self) -> dict:
        return {
            "files": len(self.files),
            "unique_blocks": len(self.block_registry),
            "stored_bytes": sum(n.used_bytes() for n in self.nodes
                                if not n.failed),
            "live_nodes": sum(not n.failed for n in self.nodes),
        }


def make_store(n_nodes: int = 4,
               replication: int = 1) -> Tuple[MetadataManager,
                                              List[StorageNode]]:
    nodes = [StorageNode(i) for i in range(n_nodes)]
    return MetadataManager(nodes, replication=replication), nodes
