"""Core transformer layers: RMSNorm, RoPE, GQA attention, MLPs.

All functions are pure; parameters are plain pytrees (dicts of arrays).
Attention is implemented *blocked* (scan over query blocks with an
in-block causal mask) so the S x S score tensor is never materialised at
32k context — the memory roofline term reflects O(S * block) residency
rather than O(S^2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# Query-block length for blocked attention.  4096-token training shapes use
# a single block; 32k prefill scans 8 blocks of 4k.
DEFAULT_Q_BLOCK = 2048


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # [hd/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                    # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def _attend_block(qh, kh, vh, q_pos, k_pos, swa_window, softcap,
                  score_dtype=jnp.float32):
    """Softmax attention for one query block against a KV prefix.

    Head-major layout (§Perf B3): the score dot emits [B,K,G,T,S]
    directly in its consumer's layout, so no S-by-S-sized transpose
    copies materialize (the token-major form cost 3 score-sized layout
    copies per block on the lowered pipeline).
    qh: [B, K, G, Tq, hd]; kh, vh: [B, K, Tk, hd]
    q_pos: [Tq], k_pos: [Tk] absolute positions (causal / SWA mask)
    ``score_dtype``: storage dtype of the scores (bf16 halves score HBM
    traffic, §Perf B2); softmax still computes in fp32.
    returns [B, K, G, Tq, hd]
    """
    scale = qh.shape[-1] ** -0.5
    scores = jnp.einsum("bkgth,bksh->bkgts", qh, kh,
                        preferred_element_type=score_dtype)
    scores = (scores * jnp.asarray(scale, score_dtype))
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    mask = k_pos[None, :] <= q_pos[:, None]                # causal  [Tq, Tk]
    if swa_window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < swa_window
    scores = jnp.where(mask[None, None, None], scores,
                       jnp.asarray(-1e30, score_dtype))
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                           ).astype(qh.dtype)
    return jnp.einsum("bkgts,bksh->bkgth", probs, vh)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_positions: jax.Array, k_positions: jax.Array,
                  swa_window: int = 0, softcap: float = 0.0,
                  q_block: int = DEFAULT_Q_BLOCK,
                  score_dtype=jnp.float32) -> jax.Array:
    """Blocked causal GQA attention.

    q: [B, S, H, hd]; k, v: [B, Sk, K, hd] with H = K * G.
    Inputs transpose once to head-major (O(S*d), negligible vs scores),
    then a scan over query blocks computes softmax against the full
    (masked) KV — O(S * q_block) live memory instead of O(S^2).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qh = q.reshape(B, S, K, G, hd).transpose(0, 2, 3, 1, 4)  # [B,K,G,S,hd]
    kh = k.transpose(0, 2, 1, 3)                             # [B,K,S,hd]
    vh = v.transpose(0, 2, 1, 3)
    if S <= q_block:
        out = _attend_block(qh, kh, vh, q_positions, k_positions,
                            swa_window, softcap, score_dtype)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)

    assert S % q_block == 0, (S, q_block)
    n_blocks = S // q_block
    qs = qh.reshape(B, K, G, n_blocks, q_block, hd).transpose(
        3, 0, 1, 2, 4, 5)                                    # [nb,B,K,G,qb,hd]
    qp = q_positions.reshape(n_blocks, q_block)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(_, blk):
        qb, qpb = blk
        ob = _attend_block(qb, kh, vh, qpb, k_positions, swa_window,
                           softcap, score_dtype)
        return None, ob

    _, out = jax.lax.scan(body, None, (qs, qp))              # [nb,B,K,G,qb,hd]
    out = out.transpose(1, 0, 4, 2, 3, 5)                    # [B,nb,qb,K,G,hd]
    return out.reshape(B, S, H, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_positions: jax.Array, cur_pos: jax.Array,
                     softcap: float = 0.0) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q: [B, 1, H, hd]; caches: [B, C, K, hd]; slot_positions: [C] or
    [B, C] absolute position held by each cache slot (-1 or > cur_pos =>
    masked out); cur_pos: scalar or [B] (ragged continuous batching).
    """
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    sp = slot_positions if slot_positions.ndim == 2 \
        else slot_positions[None, :]                       # [B or 1, C]
    cp = cur_pos if jnp.ndim(cur_pos) else cur_pos[None]
    cp = jnp.reshape(cp, (-1, 1))                          # [B or 1, 1]
    valid = (sp >= 0) & (sp <= cp)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache)
    return out.reshape(B, 1, H, hd)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp(x: jax.Array, p: dict, mlp_type: str) -> jax.Array:
    dtype = x.dtype
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(dtype)) * (x @ p["w3"].astype(dtype))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ p["w1"].astype(dtype))
    else:
        raise ValueError(mlp_type)
    return h @ p["w2"].astype(dtype)


def mlp_param_shapes(d: int, f: int, mlp_type: str) -> dict:
    shapes = {"w1": (d, f), "w2": (f, d)}
    if mlp_type == "swiglu":
        shapes["w3"] = (d, f)
    return shapes
