"""Partition-spec rules: map parameter paths to PartitionSpecs.

Baseline layout (recorded in EXPERIMENTS.md §Roofline as *baseline*):
  * megatron-style tensor parallelism on the 'model' axis: attention heads,
    FFN hidden dim, MoE expert dim (or expert-FFN dim when E < axis), SSM
    head channels, vocab dim of embed/head;
  * pure data parallelism over the ('pod', 'data') axes for the batch;
  * a dim is sharded only when divisible by the model-axis size (small KV
    heads / odd vocab sizes are replicated — noted per arch).

ZeRO-1 optimizer-state sharding is layered on top by
``zero1_spec`` (a §Perf hillclimb lever).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...]       # ('data',) or ('pod', 'data')
    model_axis: str = "model"
    # §Perf lever: shard head/ffn dims on the model axis even when not
    # divisible (GSPMD pads) — e.g. minicpm's 36 heads over 16 devices.
    # Baseline False: replicate instead (megatron convention).
    uneven: bool = False

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def named(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def constrain(x, ctx: Optional[ShardCtx], *spec):
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.named(*spec))


def _div(n: int, k: int) -> bool:
    return n % k == 0


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ArchConfig, model_size: int,
               uneven: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    ``path`` is the tuple of dict keys; stacked block leaves have a
    leading n_superblock dim which is never sharded.
    """
    name = path[-1]
    m = "model"
    stacked = path[0] == "blocks"

    def wrap(spec_tail: tuple) -> P:
        if stacked:
            return P(None, *spec_tail)
        return P(*spec_tail)

    dims = shape[1:] if stacked else shape

    if name == "embed":
        return P(m, None) if _div(shape[0], model_size) else P(None, None)
    if name == "head":
        return P(None, m) if _div(shape[1], model_size) else P(None, None)
    if name in ("final_norm", "norm1", "norm2", "gate_norm_scale"):
        return wrap((None,) * len(dims))

    # attention.  With `uneven`, head dims shard with GSPMD padding
    # whenever there are at least model_size heads (hillclimb B1).
    def head_ok(n):
        return _div(n, model_size) or (uneven and n >= model_size)

    if name == "wq":
        return wrap((None, m if head_ok(dims[1]) else None, None))
    if name in ("wk", "wv"):
        return wrap((None, m if head_ok(dims[1]) else None, None))
    if name == "wo":
        return wrap((m if head_ok(dims[0]) else None, None, None))

    # dense / shared-expert MLP
    if name in ("w1", "w3", "shared_w1", "shared_w3") and len(dims) == 2:
        return wrap((None, m if _div(dims[1], model_size) else None))
    if name in ("w2", "shared_w2") and len(dims) == 2:
        return wrap((m if _div(dims[0], model_size) else None, None))

    # MoE expert-stacked tensors [E, d, f] / [E, f, d]
    if name in ("w1", "w3") and len(dims) == 3:
        if cfg.moe and cfg.moe.shard_mode == "expert" \
                and _div(dims[0], model_size):
            return wrap((m, None, None))
        return wrap((None, None, m if _div(dims[2], model_size) else None))
    if name == "w2" and len(dims) == 3:
        if cfg.moe and cfg.moe.shard_mode == "expert" \
                and _div(dims[0], model_size):
            return wrap((m, None, None))
        return wrap((None, m if _div(dims[1], model_size) else None, None))
    if name == "router":
        return wrap((None, None))

    # SSM
    if name in ("z_proj", "x_proj", "dt_proj"):
        return wrap((None, m if _div(dims[1], model_size) else None))
    if name == "out_proj":
        return wrap((m if _div(dims[0], model_size) else None, None))
    if name in ("B_proj", "C_proj"):
        return wrap((None, None))
    if name in ("conv_x_w",):
        return wrap((None, m if _div(dims[1], model_size) else None))
    if name in ("conv_x_b", "gate_norm", "A_log", "D", "dt_bias"):
        return wrap((m if _div(dims[0], model_size) else None,))
    if name in ("conv_B_w", "conv_C_w"):
        return wrap((None, None))
    if name in ("conv_B_b", "conv_C_b"):
        return wrap((None,))

    # default: replicate
    return wrap((None,) * len(dims))


def param_specs(cfg: ArchConfig, shapes_tree, ctx: ShardCtx):
    """Tree of PartitionSpec matching a tree of ShapeDtypeStruct."""
    def fn(path, leaf):
        keys = tuple(
            k.key if hasattr(k, "key") else str(k) for k in path)
        return param_spec(keys, leaf.shape, cfg, ctx.model_size,
                          uneven=ctx.uneven)
    return jax.tree_util.tree_map_with_path(fn, shapes_tree)


def zero1_spec(spec: P, shape: Tuple[int, ...], dp_axes: Tuple[str, ...],
               dp_size: int) -> P:
    """Extend a param spec by sharding the first free divisible dim over
    the data axes (ZeRO-1 optimizer-state sharding)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (p, n) in enumerate(zip(parts, shape)):
        if p is None and n % dp_size == 0 and n >= dp_size:
            parts[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            return P(*parts)
    return spec


def cache_spec(kind: str, ctx: ShardCtx, batch: int) -> P:
    """Decode-cache sharding.  KV caches shard batch over dp and the
    sequence (slot) dim over the model axis (flash-decoding layout —
    robust to tiny GQA head counts); SSM states shard heads on model."""
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    if kind == "kv":          # [B, C, K, hd]
        return P(dp, ctx.model_axis, None, None) if batch > 1 \
            else P(None, ctx.model_axis, None, None)
    if kind == "ssm":         # [B, h, n, p]
        return P(dp, ctx.model_axis, None, None) if batch > 1 \
            else P(None, ctx.model_axis, None, None)
    if kind == "conv":        # [B, cw-1, C]
        return P(dp, None, None) if batch > 1 else P(None, None, None)
    raise ValueError(kind)
