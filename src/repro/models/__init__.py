from repro.models.model import LMModel, build_model  # noqa: F401
