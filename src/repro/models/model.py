"""Composable decoder-LM covering all 10 assigned architectures.

The layer stack is expressed as a repeating *pattern* of ``period`` sub-
layers (period 1 for homogeneous archs, 8 for Jamba's 1:7 attention:mamba
interleave).  Parameters of each pattern position are stacked over
``n_super = L / period`` superblocks and the stack is applied with
``lax.scan`` — the compiled HLO contains one superblock body regardless of
depth, which keeps 61-layer x 384-expert dry-run compiles tractable and is
the production pattern (MaxText-style scanned layers).

Remat: the superblock body is wrapped in ``jax.checkpoint``; the policy is
configurable (baseline ``nothing_saveable`` = full remat; §Perf iterates).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax

from repro import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.sharding import ShardCtx, cache_spec, constrain, param_specs

Pytree = Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class LMModel:
    def __init__(self, cfg: ArchConfig, ctx: Optional[ShardCtx] = None,
                 remat_policy: str = "nothing_saveable",
                 attn_score_dtype: str = "float32"):
        self.cfg = cfg
        self.ctx = ctx
        self.remat_policy = remat_policy
        self.score_dtype = _dtype(attn_score_dtype)
        # §Perf B1 (head padding): MHA head counts that do not divide the
        # model axis (minicpm 36H, musicgen 24H) replicate attention under
        # the baseline rules.  With ctx.uneven, pad H (and K, MHA only) to
        # the next axis multiple: +pad/H attention compute for axis-wide
        # TP.  jax rejects non-divisible input shardings, so padding is
        # done in the parameter shapes themselves.
        ms = ctx.model_size if ctx is not None else 1
        H, K = cfg.num_heads, cfg.kv_heads
        if ctx is not None and getattr(ctx, "uneven", False) and H \
                and H == K and H % ms:
            H = K = -(-H // ms) * ms
        self.n_heads = H
        self.n_kv = K
        period = cfg.hybrid_period
        if not period:
            period = 2 if (cfg.moe and cfg.moe.layer_pattern == "every_2") \
                else 1
        assert cfg.num_layers % period == 0, (cfg.num_layers, period)
        self.period = period
        self.n_super = cfg.num_layers // period
        self.kinds = []
        for i in range(period):
            mixer = "attn" if cfg._layer_is_attn(i) else "ssm"
            if cfg.moe is not None and cfg._layer_is_moe(i):
                ffn = "moe"
            elif cfg.d_ff > 0:
                ffn = "dense"
            else:
                ffn = None
            self.kinds.append((mixer, ffn))
        self.pdt = _dtype(cfg.param_dtype)
        self.cdt = _dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------
    # parameter shapes / init / sharding
    # ------------------------------------------------------------------
    def _sublayer_shapes(self, mixer: str, ffn: Optional[str]) -> dict:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        out: Dict[str, Any] = {"norm1": (d,)}
        if mixer == "attn":
            out["attn"] = {
                "wq": (d, self.n_heads, hd),
                "wk": (d, self.n_kv, hd),
                "wv": (d, self.n_kv, hd),
                "wo": (self.n_heads, hd, d),
            }
        else:
            out["ssm"] = ssm_lib.ssm_param_shapes(d, cfg.ssm)
        if ffn == "dense":
            out["norm2"] = (d,)
            out["mlp"] = L.mlp_param_shapes(d, cfg.d_ff, cfg.mlp_type)
        elif ffn == "moe":
            out["norm2"] = (d,)
            out["moe"] = moe_lib.moe_param_shapes(d, cfg.moe, cfg.mlp_type)
        return out

    def param_shapes(self) -> Pytree:
        cfg = self.cfg
        shapes: Dict[str, Any] = {
            "embed": (cfg.vocab_size, cfg.d_model),
            "final_norm": (cfg.d_model,),
            "blocks": {},
        }
        if not cfg.tie_embeddings:
            shapes["head"] = (cfg.d_model, cfg.vocab_size)
        for i, (mixer, ffn) in enumerate(self.kinds):
            sub = self._sublayer_shapes(mixer, ffn)
            stacked = compat.tree_map(lambda s: (self.n_super, *s), sub,
                                   is_leaf=lambda s: isinstance(s, tuple))
            shapes["blocks"][f"pos{i}"] = stacked
        return compat.tree_map(
            lambda s: jax.ShapeDtypeStruct(s, self.pdt), shapes,
            is_leaf=lambda s: isinstance(s, tuple))

    def param_pspecs(self) -> Pytree:
        assert self.ctx is not None
        return param_specs(self.cfg, self.param_shapes(), self.ctx)

    def init(self, rng: jax.Array) -> Pytree:
        shapes = self.param_shapes()
        leaves, treedef = compat.tree_flatten_with_path(shapes)
        keys = jax.random.split(rng, len(leaves))
        d = self.cfg.d_model

        def init_leaf(path, sds, key):
            name = path[-1].key
            shape, dtype = sds.shape, sds.dtype
            if name in ("norm1", "norm2", "final_norm", "gate_norm", "D"):
                return jnp.ones(shape, dtype)
            if name in ("conv_x_b", "conv_B_b", "conv_C_b"):
                return jnp.zeros(shape, dtype)
            if name == "A_log":
                u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
                return jnp.log(u).astype(dtype)
            if name == "dt_bias":
                u = jax.random.uniform(key, shape, jnp.float32,
                                       math.log(1e-3), math.log(1e-1))
                dt = jnp.exp(u)
                return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
            scale = 0.02 if name in ("embed", "head") else 1.0 / math.sqrt(d)
            return (jax.random.normal(key, shape, jnp.float32)
                    * scale).astype(dtype)

        out = [init_leaf(p, s, k) for (p, s), k in zip(leaves, keys)]
        return compat.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    # forward components
    # ------------------------------------------------------------------
    def _attention_full(self, p: dict, x: jax.Array, positions: jax.Array,
                        want_cache: bool, capacity: int = 0):
        cfg = self.cfg
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dke->bske", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dke->bske", x, p["wv"].astype(x.dtype))
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.gqa_attention(q, k, v, positions, positions,
                            swa_window=cfg.swa_window,
                            softcap=cfg.attn_logit_softcap,
                            score_dtype=self.score_dtype)
        out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
        if not want_cache:
            return out, None
        S = x.shape[1]
        C = capacity
        if C <= S:                       # ring (SWA) or exact-fit cache
            k_c = jnp.roll(k[:, S - C:], S % C, axis=1)
            v_c = jnp.roll(v[:, S - C:], S % C, axis=1)
        else:
            pad = [(0, 0), (0, C - S), (0, 0), (0, 0)]
            k_c, v_c = jnp.pad(k, pad), jnp.pad(v, pad)
        return out, {"k": k_c, "v": v_c}

    def _attention_decode(self, p: dict, x: jax.Array, cache: dict,
                          pos: jax.Array):
        """pos: scalar, or [B] vector for ragged continuous batching
        (per-slot positions; vector path uses one-hot masked writes)."""
        cfg = self.cfg
        ctx = self.ctx
        ragged = jnp.ndim(pos) == 1
        C = cache["k"].shape[1]
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dke->bske", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dke->bske", x, p["wv"].astype(x.dtype))
        posv = pos[:, None] if ragged else jnp.full((1,), pos, jnp.int32)
        q = L.apply_rope(q, posv, cfg.rope_theta)
        k = L.apply_rope(k, posv, cfg.rope_theta)
        slots = jnp.arange(C, dtype=jnp.int32)
        if ragged:
            slot = (pos % C).astype(jnp.int32)               # [B]
            hit = slots[None, :] == slot[:, None]            # [B, C]
            k_c = jnp.where(hit[:, :, None, None],
                            k.astype(cache["k"].dtype), cache["k"])
            v_c = jnp.where(hit[:, :, None, None],
                            v.astype(cache["v"].dtype), cache["v"])
        else:
            slot = (pos % C).astype(jnp.int32)
            k_c = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        if ctx is not None:
            B = k_c.shape[0]
            k_c = constrain(k_c, ctx, *cache_spec("kv", ctx, B))
            v_c = constrain(v_c, ctx, *cache_spec("kv", ctx, B))
        if cfg.swa_window and cfg.swa_window == C:
            p_ = pos[:, None] if ragged else pos
            slot_pos = p_ - ((p_ - slots) % C)
        else:
            slot_pos = slots
        o = L.decode_attention(q, k_c, v_c, slot_pos, pos,
                               softcap=cfg.attn_logit_softcap)
        out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
        return out, {"k": k_c, "v": v_c}

    def _sublayer(self, p: dict, x: jax.Array, kind, positions,
                  mode: str, cache=None, pos=None, capacity: int = 0):
        cfg = self.cfg
        mixer, ffn = kind
        h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
        new_cache = None
        if mixer == "attn":
            if mode == "decode":
                a, new_cache = self._attention_decode(p["attn"], h, cache,
                                                      pos)
            else:
                a, new_cache = self._attention_full(
                    p["attn"], h, positions, want_cache=(mode == "prefill"),
                    capacity=capacity)
        else:
            if mode == "decode":
                a, new_cache = ssm_lib.ssm_decode_step(h, cache, p["ssm"],
                                                       cfg.d_model, cfg.ssm)
            elif mode == "prefill":
                a, new_cache = ssm_lib.ssm_forward(h, p["ssm"], cfg.d_model,
                                                   cfg.ssm,
                                                   return_state=True)
            else:
                a = ssm_lib.ssm_forward(h, p["ssm"], cfg.d_model, cfg.ssm)
        x = x + cfg.residual_scale * a
        aux = jnp.zeros((), jnp.float32)
        if ffn is not None:
            h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            if ffn == "moe":
                y, aux = moe_lib.moe_mlp(h, p["moe"], cfg.moe, cfg.mlp_type)
            else:
                y = L.mlp(h, p["mlp"], cfg.mlp_type)
            x = x + cfg.residual_scale * y
        return x, aux, new_cache

    def _embed(self, params, tokens, embeds):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.cdt)
        x = x * cfg.embed_scale
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(self.cdt), x], axis=1)
        return x

    def _unembed(self, params, x):
        cfg = self.cfg
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        return logits * cfg.logit_scale

    def _dp_spec(self):
        ctx = self.ctx
        dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
        return dp

    def _constrain_act(self, x):
        if self.ctx is None or x.shape[0] == 1:
            return x
        return constrain(x, self.ctx, self._dp_spec(), None, None)

    # ------------------------------------------------------------------
    # full-sequence forward (training)
    # ------------------------------------------------------------------
    def forward(self, params: Pytree, tokens: jax.Array,
                embeds: Optional[jax.Array] = None,
                return_hidden: bool = False):
        """tokens: [B, S_text]; embeds: [B, F, d] (VLM stub) or None.
        Returns (logits [B, S, V] fp32, aux_loss scalar); with
        ``return_hidden`` returns the final-normed hidden states instead of
        logits (the train step computes a blocked cross-entropy that never
        materialises the [B, S, V] fp32 logits)."""
        x = self._embed(params, tokens, embeds)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)

        def superblock(carry, blk):
            x, aux = carry
            x = self._constrain_act(x)
            for i, kind in enumerate(self.kinds):
                x, a, _ = self._sublayer(blk[f"pos{i}"], x, kind, positions,
                                         mode="train")
                aux = aux + a
            return (x, aux), None

        policy = getattr(jax.checkpoint_policies, self.remat_policy)
        body = jax.checkpoint(superblock, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        if return_hidden:
            return x, aux
        return self._unembed(params, x), aux

    def unembed_matrix(self, params: Pytree) -> jax.Array:
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        return head

    # ------------------------------------------------------------------
    # prefill / decode (serving)
    # ------------------------------------------------------------------
    def capacity_for(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.swa_window:
            return min(cfg.swa_window, seq_len)
        return seq_len

    def prefill(self, params: Pytree, tokens: jax.Array,
                embeds: Optional[jax.Array] = None,
                capacity: Optional[int] = None):
        """Returns (cache pytree, last-position logits [B, V])."""
        x = self._embed(params, tokens, embeds)
        S = x.shape[1]
        capacity = capacity or self.capacity_for(S)
        positions = jnp.arange(S, dtype=jnp.int32)

        def superblock(carry, blk):
            x = carry
            x = self._constrain_act(x)
            caches = {}
            for i, kind in enumerate(self.kinds):
                x, _, c = self._sublayer(blk[f"pos{i}"], x, kind, positions,
                                         mode="prefill", capacity=capacity)
                caches[f"pos{i}"] = c
            return x, caches

        policy = getattr(jax.checkpoint_policies, self.remat_policy)
        body = jax.checkpoint(superblock, policy=policy)
        x, caches = jax.lax.scan(body, x, params["blocks"])
        x = L.rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = self._unembed(params, x)[:, 0]
        return caches, logits

    def decode_step(self, params: Pytree, cache: Pytree, tokens: jax.Array,
                    pos: jax.Array):
        """tokens: [B, 1]; pos: scalar int32 (absolute position of the new
        token).  Returns (new cache, logits [B, V])."""
        x = self._embed(params, tokens, None)

        def superblock(x, blk_and_cache):
            blk, cch = blk_and_cache
            new_caches = {}
            for i, kind in enumerate(self.kinds):
                x, _, c = self._sublayer(blk[f"pos{i}"], x, kind, None,
                                         mode="decode", cache=cch[f"pos{i}"],
                                         pos=pos)
                new_caches[f"pos{i}"] = c
            return x, new_caches

        x, new_cache = jax.lax.scan(superblock, x,
                                    (params["blocks"], cache))
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = self._unembed(params, x)[:, 0]
        return new_cache, logits

    # ------------------------------------------------------------------
    # cache specs (for dry-run input construction)
    # ------------------------------------------------------------------
    def cache_shapes(self, batch: int, seq_len: int) -> Pytree:
        cfg = self.cfg
        capacity = self.capacity_for(seq_len)
        hd = cfg.resolved_head_dim
        out = {}
        for i, (mixer, _) in enumerate(self.kinds):
            if mixer == "attn":
                kv = jax.ShapeDtypeStruct(
                    (self.n_super, batch, capacity, self.n_kv, hd),
                    jnp.bfloat16)
                out[f"pos{i}"] = {"k": kv, "v": kv}
            else:
                st = ssm_lib.ssm_state_shapes(batch, cfg.d_model, cfg.ssm)
                out[f"pos{i}"] = {
                    k: jax.ShapeDtypeStruct((self.n_super, *shape), dt)
                    for k, (shape, dt) in st.items()}
        return out

    def cache_pspecs(self, batch: int) -> Pytree:
        ctx = self.ctx
        assert ctx is not None

        def stack(spec: P) -> P:
            return P(None, *spec)

        out = {}
        for i, (mixer, _) in enumerate(self.kinds):
            if mixer == "attn":
                s = stack(cache_spec("kv", ctx, batch))
                out[f"pos{i}"] = {"k": s, "v": s}
            else:
                out[f"pos{i}"] = {
                    "ssm": stack(cache_spec("ssm", ctx, batch)),
                    "conv_x": stack(cache_spec("conv", ctx, batch)),
                    "conv_B": stack(cache_spec("conv", ctx, batch)),
                    "conv_C": stack(cache_spec("conv", ctx, batch)),
                }
        return out


def build_model(cfg: ArchConfig, ctx: Optional[ShardCtx] = None,
                remat_policy: str = "nothing_saveable",
                attn_score_dtype: str = "float32") -> LMModel:
    return LMModel(cfg, ctx, remat_policy, attn_score_dtype)
