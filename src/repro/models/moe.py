"""Mixture-of-Experts layer (GShard/GSPMD-style capacity dispatch).

Design notes (TPU adaptation):
  * Tokens are processed in *groups* (contiguous spans of the sequence).
    Dispatch/combine are one-hot einsums — MXU-friendly matmuls, the
    canonical TPU MoE formulation (GShard, Switch, GLaM).
  * Dispatch FLOPs per token are 2 * group_size * top_k * capacity_factor
    * d_model, independent of the expert count, so a 384-expert layer
    (kimi-k2) pays the same dispatch overhead as an 8-expert one.
  * The stacked expert tensors [E, d, f] shard either on E ('expert' mode,
    E >= model-axis) or on f ('ffn' mode, E < model-axis, e.g. Mixtral).
  * Capacity overflow drops tokens (residual passes through), standard for
    capacity-based TPU MoE training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig

# tokens per dispatch group; small groups bound the one-hot dispatch cost.
GROUP_SIZE = 512


def moe_param_shapes(d: int, cfg: MoEConfig, mlp_type: str) -> dict:
    f = cfg.d_ff_expert
    n_mats = {"w1": (cfg.num_experts, d, f), "w2": (cfg.num_experts, f, d)}
    if mlp_type == "swiglu":
        n_mats["w3"] = (cfg.num_experts, d, f)
    shapes = {"router": (d, cfg.num_experts), **n_mats}
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        shapes["shared_w1"] = (d, fs)
        shapes["shared_w2"] = (fs, d)
        if mlp_type == "swiglu":
            shapes["shared_w3"] = (d, fs)
    return shapes


def _capacity(group: int, cfg: MoEConfig) -> int:
    cap = int(group * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    cap = max(cap, cfg.top_k)
    return min(cap, group)


def moe_mlp(x: jax.Array, p: dict, cfg: MoEConfig, mlp_type: str) -> tuple:
    """x: [B, S, d] -> ([B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    dtype = x.dtype
    group = min(GROUP_SIZE, S)
    assert S % group == 0, (S, group)
    G = B * (S // group)
    xg = x.reshape(G, group, d)

    router = p["router"].astype(jnp.float32)
    logits = xg.astype(jnp.float32) @ router               # [G, g, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)  # [G, g, k]
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    E = cfg.num_experts
    C = _capacity(group, cfg)

    # position-in-expert via cumulative sum over the k one-hots in sequence
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # [G, g, k, E]
    flat = onehot.reshape(G, group * cfg.top_k, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat          # [G, g*k, E]
    pos_in_expert = jnp.sum(pos_in_expert * flat, axis=-1)   # [G, g*k]
    pos_in_expert = pos_in_expert.reshape(G, group, cfg.top_k)
    keep = pos_in_expert < C

    # dispatch tensor [G, g, E, C]
    cap_onehot = jax.nn.one_hot(pos_in_expert, C, dtype=dtype)  # [G,g,k,C]
    disp = jnp.einsum("sgke,sgkc->sgec",
                      onehot.astype(dtype) * keep[..., None].astype(dtype),
                      cap_onehot)
    comb = jnp.einsum("sgk,sgke,sgkc->sgec", gate_vals.astype(dtype),
                      onehot.astype(dtype) * keep[..., None].astype(dtype),
                      cap_onehot)

    xe = jnp.einsum("sgec,sgd->secd", disp, xg)              # [G, E, C, d]
    w1 = p["w1"].astype(dtype)
    w2 = p["w2"].astype(dtype)
    if mlp_type == "swiglu":
        w3 = p["w3"].astype(dtype)
        h = jax.nn.silu(jnp.einsum("secd,edf->secf", xe, w1)) \
            * jnp.einsum("secd,edf->secf", xe, w3)
    else:
        h = jax.nn.gelu(jnp.einsum("secd,edf->secf", xe, w1))
    ye = jnp.einsum("secf,efd->secd", h, w2)                 # [G, E, C, d]
    y = jnp.einsum("sgec,secd->sgd", comb, ye)               # [G, g, d]
    y = y.reshape(B, S, d)

    if cfg.num_shared_experts:
        sh = {"w1": p["shared_w1"], "w2": p["shared_w2"]}
        if mlp_type == "swiglu":
            sh["w3"] = p["shared_w3"]
        from repro.models.layers import mlp
        y = y + mlp(x, sh, mlp_type)

    # load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(onehot[..., 0, :].astype(jnp.float32), axis=1)  # [G,E]
    density_prob = jnp.mean(probs, axis=1)                             # [G,E]
    aux = jnp.mean(jnp.sum(density * density_prob, axis=-1)) * E
    return y, aux
