"""Mamba-2 (SSD, state-space duality) mixer in pure JAX.

Implements the chunked SSD algorithm [arXiv:2405.21060]: the sequence is
split into chunks; within a chunk the quadratic (attention-dual) form is
used, across chunks a linear recurrence on the [heads, state, head_dim]
SSM state is carried by ``lax.scan``.  Decode is an O(1) state update —
this is what makes ``long_500k`` tractable for SSM/hybrid archs.

TP layout note: the original Mamba-2 uses one fused ``in_proj`` producing
the concatenated (z, x, B, C, dt).  Here the projection (and the depthwise
conv, which factors exactly across channel groups) is split per component
so each piece shards cleanly on the model axis: z/x/dt project onto
head-sharded channels; the small B/C (state) projections are replicated.
This is mathematically identical to the fused form.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig


def ssm_dims(d_model: int, cfg: SSMConfig) -> dict:
    d_in = cfg.expand * d_model
    nheads = d_in // cfg.head_dim
    gn = cfg.ngroups * cfg.state_dim
    return dict(d_in=d_in, nheads=nheads, gn=gn)


def ssm_param_shapes(d_model: int, cfg: SSMConfig) -> dict:
    dims = ssm_dims(d_model, cfg)
    d_in, nheads, gn = dims["d_in"], dims["nheads"], dims["gn"]
    cw = cfg.conv_width
    return {
        "z_proj": (d_model, d_in),
        "x_proj": (d_model, d_in),
        "B_proj": (d_model, gn),
        "C_proj": (d_model, gn),
        "dt_proj": (d_model, nheads),
        "conv_x_w": (cw, d_in), "conv_x_b": (d_in,),
        "conv_B_w": (cw, gn), "conv_B_b": (gn,),
        "conv_C_w": (cw, gn), "conv_C_b": (gn,),
        "A_log": (nheads,),
        "D": (nheads,),
        "dt_bias": (nheads,),
        "gate_norm": (d_in,),
        "out_proj": (d_in, d_model),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  x: [B,S,C]; w: [cw,C]."""
    cw = w.shape[0]
    out = jnp.zeros(x.shape, dtype=jnp.float32)
    for i in range(cw):
        shift = cw - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _conv_step(tail: jax.Array, x_new: jax.Array, w: jax.Array,
               b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token depthwise conv.  tail: [B,cw-1,C]; x_new: [B,1,C]."""
    window = jnp.concatenate([tail, x_new], axis=1)          # [B,cw,C]
    out = jnp.sum(window.astype(jnp.float32)
                  * w.astype(jnp.float32)[None], axis=1, keepdims=True)
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(x_new.dtype)
    return out, window[:, 1:]


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    dtype = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(dtype)


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                 h0=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [B,S,h,p]; dt: [B,S,h] (post-softplus); A: [h] (negative);
    Bm, Cm: [B,S,g,n].  Returns (y [B,S,h,p], final_state [B,h,n,p]).
    """
    B_, S, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hpg = h // g
    if S % chunk:
        chunk = S                                            # tiny shapes
    nc = S // chunk

    dA = dt * A[None, None, :]                               # [B,S,h] <= 0

    def resh(t):
        return t.reshape(B_, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs, dts = resh(x), resh(dt)
    dAs, Bs, Cs = resh(dA), resh(Bm), resh(Cm)
    if h0 is None:
        h0 = jnp.zeros((B_, h, n, p), dtype=jnp.float32)

    def body(h_state, inp):
        xc, dtc, dAc, Bc, Cc = inp                           # [B,l,...]
        lq = xc.shape[1]
        cum = jnp.cumsum(dAc.astype(jnp.float32), axis=1)    # [B,l,h]
        # intra-chunk (quadratic dual form)
        CB = jnp.einsum("bign,bjgn->bgij", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))              # [B,g,l,l]
        CB = jnp.repeat(CB, hpg, axis=1)                     # [B,h,l,l]
        li = cum.swapaxes(1, 2)                              # [B,h,l]
        L = jnp.exp(jnp.clip(li[:, :, :, None] - li[:, :, None, :],
                             -60.0, 0.0))
        mask = jnp.tril(jnp.ones((lq, lq), bool))
        W = jnp.where(mask[None, None], CB * L, 0.0)
        W = W * dtc.astype(jnp.float32).swapaxes(1, 2)[:, :, None, :]
        y_diag = jnp.einsum("bhij,bjhp->bihp", W, xc.astype(jnp.float32))
        # inter-chunk contribution from the incoming state
        decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))        # [B,l,h]
        Ch = jnp.repeat(Cc.astype(jnp.float32), hpg, axis=2)  # [B,l,h,n]
        y_off = jnp.einsum("blhn,bhnp->blhp", Ch, h_state) \
            * decay_in[..., None]
        # state update
        decay_last = jnp.exp(jnp.clip(cum[:, -1], -60.0, 0.0))   # [B,h]
        decay_state = jnp.exp(jnp.clip(cum[:, -1:, :] - cum, -60.0, 0.0))
        Bh = jnp.repeat(Bc.astype(jnp.float32), hpg, axis=2)     # [B,l,h,n]
        contrib = jnp.einsum("blhn,blh,blhp->bhnp", Bh,
                             decay_state * dtc.astype(jnp.float32),
                             xc.astype(jnp.float32))
        h_new = decay_last[:, :, None, None] * h_state + contrib
        return h_new, (y_diag + y_off).astype(x.dtype)

    h_final, ys = jax.lax.scan(body, h0, (xs, dts, dAs, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(B_, S, h, p)
    return y, h_final


def _project(x: jax.Array, p: dict):
    dtype = x.dtype
    z = x @ p["z_proj"].astype(dtype)
    xr = x @ p["x_proj"].astype(dtype)
    Br = x @ p["B_proj"].astype(dtype)
    Cr = x @ p["C_proj"].astype(dtype)
    dt = x @ p["dt_proj"].astype(dtype)
    return z, xr, Br, Cr, dt


def ssm_forward(x: jax.Array, p: dict, d_model: int, cfg: SSMConfig,
                return_state: bool = False):
    """Full-sequence Mamba-2 mixer.  x: [B,S,d]."""
    dims = ssm_dims(d_model, cfg)
    d_in, nheads = dims["d_in"], dims["nheads"]
    z, xr, Br, Cr, dt = _project(x, p)
    x_c = _causal_conv(xr, p["conv_x_w"], p["conv_x_b"])
    B_c = _causal_conv(Br, p["conv_B_w"], p["conv_B_b"])
    C_c = _causal_conv(Cr, p["conv_C_w"], p["conv_C_b"])
    B_, S, _ = x.shape
    x_h = x_c.reshape(B_, S, nheads, cfg.head_dim)
    Bm = B_c.reshape(B_, S, cfg.ngroups, cfg.state_dim)
    Cm = C_c.reshape(B_, S, cfg.ngroups, cfg.state_dim)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_final = _ssd_chunked(x_h, dt_f, A, Bm, Cm, cfg.chunk_size)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * x_h
    y = y.reshape(B_, S, d_in)
    y = _gated_norm(y, z, p["gate_norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        cw = cfg.conv_width
        state = {
            "ssm": h_final,
            "conv_x": xr[:, S - (cw - 1):],
            "conv_B": Br[:, S - (cw - 1):],
            "conv_C": Cr[:, S - (cw - 1):],
        }
        return out, state
    return out


def ssm_decode_step(x: jax.Array, state: dict, p: dict, d_model: int,
                    cfg: SSMConfig):
    """One-token decode.  x: [B,1,d] -> (y [B,1,d], new_state)."""
    dims = ssm_dims(d_model, cfg)
    d_in, nheads = dims["d_in"], dims["nheads"]
    z, xr, Br, Cr, dt = _project(x, p)
    x_c, conv_x = _conv_step(state["conv_x"], xr, p["conv_x_w"],
                             p["conv_x_b"])
    B_c, conv_B = _conv_step(state["conv_B"], Br, p["conv_B_w"],
                             p["conv_B_b"])
    C_c, conv_C = _conv_step(state["conv_C"], Cr, p["conv_C_w"],
                             p["conv_C_b"])
    B_ = x.shape[0]
    x_h = x_c.reshape(B_, nheads, cfg.head_dim)
    Bm = B_c.reshape(B_, cfg.ngroups, cfg.state_dim)
    Cm = C_c.reshape(B_, cfg.ngroups, cfg.state_dim)
    dt_f = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))   # [B,h]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(jnp.clip(dt_f * A[None], -60.0, 0.0))           # [B,h]
    hpg = nheads // cfg.ngroups
    Bh = jnp.repeat(Bm.astype(jnp.float32), hpg, axis=1)         # [B,h,n]
    Ch = jnp.repeat(Cm.astype(jnp.float32), hpg, axis=1)
    h_new = dA[:, :, None, None] * state["ssm"] \
        + jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt_f,
                     x_h.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h_new)
    y = y + p["D"].astype(jnp.float32)[None, :, None] \
        * x_h.astype(jnp.float32)
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    y = _gated_norm(y, z, p["gate_norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = {"ssm": h_new, "conv_x": conv_x, "conv_B": conv_B,
                 "conv_C": conv_C}
    return out, new_state


def ssm_state_shapes(batch: int, d_model: int, cfg: SSMConfig,
                     dtype=jnp.bfloat16) -> dict:
    dims = ssm_dims(d_model, cfg)
    cw = cfg.conv_width
    return {
        "ssm": ((batch, dims["nheads"], cfg.state_dim, cfg.head_dim),
                jnp.float32),
        "conv_x": ((batch, cw - 1, dims["d_in"]), dtype),
        "conv_B": ((batch, cw - 1, dims["gn"]), dtype),
        "conv_C": ((batch, cw - 1, dims["gn"]), dtype),
    }
