"""End-to-end training driver.

Examples:
  # ~65M-param llama3-family model, 200 steps, CA-checkpointing every 50
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --preset 100m --steps 200 --batch 8 --seq 256

  # tiny smoke for any assigned arch
  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
      --preset smoke --steps 20

The driver wires every substrate together: config -> model -> optimizer ->
deterministic data pipeline -> jit'd train step -> TrainSupervisor (fault
tolerance + stragglers) -> content-addressable checkpointing with
accelerator-offloaded hashing (the paper's technique).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import CrystalTPU, SAI, SAIConfig, make_store
from repro.data import make_pipeline
from repro.models.model import build_model
from repro.optim import make_optimizer, make_schedule
from repro.train.checkpoint import CACheckpointer
from repro.train.fault import TrainSupervisor
from repro.train.trainstep import make_train_step


def preset_config(arch: str, preset: str):
    if preset == "full":
        return get_config(arch)
    if preset == "smoke":
        return get_smoke_config(arch)
    if preset == "100m":
        cfg = get_config(arch)
        moe = cfg.moe
        if moe is not None:
            moe = dataclasses.replace(moe, num_experts=min(
                8, moe.num_experts), top_k=2, d_ff_expert=512)
        ssm = cfg.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, state_dim=64, head_dim=32)
        period = cfg.hybrid_period or 1
        return dataclasses.replace(
            cfg, num_layers=max(16 // period, 1) * period, d_model=512,
            num_heads=8 if cfg.num_heads else 0,
            kv_heads=min(cfg.kv_heads, 4) if cfg.num_heads else 0,
            head_dim=64 if cfg.num_heads else 0,
            d_ff=2048 if cfg.d_ff else 0,
            vocab_size=32768, moe=moe, ssm=ssm,
            frontend_embeds=min(cfg.frontend_embeds, 16),
            param_dtype="float32", compute_dtype="float32")
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--preset", default="100m",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-chunking", default="cdc-gear",
                    choices=["fixed", "cdc", "cdc-gear"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject one failure at this step (fault demo)")
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    model = build_model(cfg)
    print(f"arch={cfg.name} preset={args.preset} "
          f"params={cfg.param_count()/1e6:.1f}M")

    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    lr_fn = make_schedule(cfg.lr_schedule, args.lr, args.steps)
    opt = make_optimizer(cfg.optimizer, lr_fn)
    opt_state = opt.init(params)

    pipeline = make_pipeline(cfg, args.seq, args.batch, seed=args.seed)
    step_fn = jax.jit(make_train_step(model, opt,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    # content-addressable checkpoint store (the paper's technique)
    mgr, _ = make_store(n_nodes=4, replication=2)
    crystal = CrystalTPU()
    sai = SAI(mgr, SAIConfig(ca=args.ckpt_chunking, avg_chunk=256 << 10,
                             min_chunk=64 << 10, max_chunk=1 << 20,
                             hasher="tpu"), crystal)
    ckpt = CACheckpointer(sai)

    fail = {args.fail_at: 1} if args.fail_at >= 0 else None
    sup = TrainSupervisor(step_fn, pipeline, ckpt,
                          ckpt_every=args.ckpt_every,
                          fail_at_steps=fail)
    t0 = time.time()
    params, opt_state = sup.run(params, opt_state, 0, args.steps)
    wall = time.time() - t0

    losses = [r["loss"] for r in sup.log]
    print(f"steps={len(sup.log)} wall={wall:.1f}s "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f}")
    print(f"restarts={sup.restarts} stragglers={len(sup.stragglers)}")
    tok_s = args.batch * args.seq * len(sup.log) / wall
    print(f"throughput={tok_s:.0f} tok/s (CPU container)")
    for rec in ckpt.history:
        print(f"  ckpt step={rec['step']:4d} total={rec['total_bytes']/1e6:.1f}MB "
              f"new={rec['new_bytes']/1e6:.1f}MB "
              f"dedup={100*rec['dedup_ratio']:.1f}% "
              f"wall={rec['wall_s']:.2f}s")
    print("store:", json.dumps(mgr.stats()))
    crystal.shutdown()
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
