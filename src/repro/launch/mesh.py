"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run driver
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import to obtain placeholder devices.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.models.sharding import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_shard_ctx(mesh) -> ShardCtx:
    axes = mesh.axis_names
    dp_axes: Tuple[str, ...] = tuple(a for a in axes if a != "model")
    return ShardCtx(mesh=mesh, dp_axes=dp_axes, model_axis="model")


def make_host_mesh():
    """Single-process mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
