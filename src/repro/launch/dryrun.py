import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: jax locks the device count on first init.

_DOC = """Multi-pod dry-run driver.

For every (architecture x input shape) cell this lowers + compiles the
appropriate step (train / prefill / decode) against the production mesh —
16x16 single-pod and 2x16x16 multi-pod — using ShapeDtypeStruct stand-ins
(no device allocation), then records:

  * ``compiled.memory_analysis()``  (per-device bytes: proves it fits)
  * ``compiled.cost_analysis()``    (HLO FLOPs / bytes for §Roofline)
  * collective byte totals parsed from ``compiled.as_text()`` (while-loop
    bodies scaled by trip count)

Results are written to ``results/dryrun/<arch>__<shape>__<mesh>.json`` so
the roofline analysis and EXPERIMENTS.md tables are reproducible.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro import compat
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_shape
from repro.launch.mesh import make_production_mesh, make_shard_ctx
from repro.models.model import build_model
from repro.models.sharding import zero1_spec
from repro.optim import make_optimizer, make_schedule
from repro.roofline.hlo_analysis import analyze_hlo
from repro.train.trainstep import make_train_step
from repro.serve.servestep import make_decode_step, make_prefill_step

from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


def input_specs(cfg, shape, ctx):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    model = build_model(cfg, ctx)
    B, S = shape.global_batch, shape.seq_len
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    batch_spec = P(dp, None) if B > 1 else P(None, None)
    if shape.kind in ("train", "prefill"):
        F = cfg.frontend_embeds
        tokens = jax.ShapeDtypeStruct((B, S - F), jnp.int32)
        specs = {"tokens": tokens}
        shardings = {"tokens": NamedSharding(ctx.mesh, batch_spec)}
        if F:
            specs["embeds"] = jax.ShapeDtypeStruct(
                (B, F, cfg.d_model), jnp.bfloat16)
            eb = P(dp, None, None) if B > 1 else P(None, None, None)
            shardings["embeds"] = NamedSharding(ctx.mesh, eb)
        return specs, shardings
    # decode: one token against a cache of S
    cache = model.cache_shapes(B, S)
    cache_sh = jax.tree.map(lambda s: NamedSharding(ctx.mesh, s),
                            model.cache_pspecs(B),
                            is_leaf=lambda x: isinstance(x, P))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return ({"tokens": tokens, "cache": cache},
            {"tokens": NamedSharding(ctx.mesh, batch_spec),
             "cache": cache_sh})


def _named(ctx, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s),
                        tree_of_specs, is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               zero1: bool = False, remat: str = "nothing_saveable",
               dp: int = 0, tp: int = 0, uneven: bool = False,
               score_dtype: str = "float32", microbatches: int = 1):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if dp and tp:
        # §Perf axis-rebalance variant: same chip count, different split
        if mesh_kind == "multi":
            mesh = jax.make_mesh((2, dp, tp), ("pod", "data", "model"))
        else:
            mesh = jax.make_mesh((dp, tp), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ctx = make_shard_ctx(mesh)
    if uneven:
        import dataclasses as _dc
        ctx = _dc.replace(ctx, uneven=True)
    model = build_model(cfg, ctx, remat_policy=remat,
                        attn_score_dtype=score_dtype)
    pspecs = model.param_pspecs()
    psh = _named(ctx, pspecs)
    params_sds = model.param_shapes()

    specs, shardings = input_specs(cfg, shape, ctx)

    with mesh:
        if shape.kind == "train":
            lr_fn = make_schedule(cfg.lr_schedule, 3e-4, 10000)
            opt = make_optimizer(cfg.optimizer, lr_fn)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            ospec = opt.state_spec_like(pspecs)
            if zero1:
                dp_size = 1
                for a in ctx.dp_axes:
                    dp_size *= mesh.shape[a]
                ospec = jax.tree.map(
                    lambda sp, sd: zero1_spec(sp, sd.shape, ctx.dp_axes,
                                              dp_size),
                    ospec, jax.eval_shape(opt.init, params_sds),
                    is_leaf=lambda x: isinstance(x, P))
            osh = _named(ctx, ospec)
            step_fn = make_train_step(model, opt,
                                      microbatches=microbatches)
            step_sds = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                step_fn,
                in_shardings=(psh, osh, shardings, None),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, specs, step_sds)
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(model)
            args = [params_sds, specs["tokens"]]
            in_sh = [psh, shardings["tokens"]]
            if "embeds" in specs:
                args.append(specs["embeds"])
                in_sh.append(shardings["embeds"])
            jitted = jax.jit(step_fn, in_shardings=tuple(in_sh))
            lowered = jitted.lower(*args)
        else:  # decode
            step_fn = make_decode_step(model)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                step_fn,
                in_shardings=(psh, shardings["cache"], shardings["tokens"],
                              None),
                donate_argnums=(1,))
            lowered = jitted.lower(params_sds, specs["cache"],
                                   specs["tokens"], pos)
    return cfg, shape, mesh, lowered


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             zero1: bool = False, remat: str = "nothing_saveable",
             tag: str = "", dp: int = 0, tp: int = 0, uneven: bool = False,
             score_dtype: str = "float32", microbatches: int = 1) -> dict:
    t0 = time.time()
    cfg, shape, mesh, lowered = lower_cell(arch, shape_name, mesh_kind,
                                           zero1=zero1, remat=remat,
                                           dp=dp, tp=tp, uneven=uneven,
                                           score_dtype=score_dtype,
                                           microbatches=microbatches)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
    cost = compat.cost_analysis(compiled)
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and k in
              ("flops", "bytes accessed", "transcendentals",
               "optimal_seconds")}
    hlo = compiled.as_text()
    an = analyze_hlo(hlo)
    coll = {"wire_bytes": an["wire_bytes"], "op_counts": an["op_counts"],
            "total_wire_bytes": an["total_wire_bytes"]}
    n_dev = mesh.devices.size

    rec = dict(
        arch=arch, shape=shape_name, mesh=mesh_kind, zero1=zero1,
        remat=remat, kind=shape.kind, n_devices=int(n_dev),
        seq_len=shape.seq_len, global_batch=shape.global_batch,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem_d, cost=cost_d, collectives=coll,
        flops_scaled=an["flops"], bytes_scaled=an["bytes_accessed"],
        bytes_upper=an["bytes_upper"],
        top_collectives=an["top_collectives"], top_bytes=an["top_bytes"],
        params=cfg.param_count(), active_params=cfg.active_param_count(),
        hlo_bytes=len(hlo),
    )
    # persist the HLO so analyzer improvements can re-derive terms without
    # recompiling
    hlo_dir = os.path.join(RESULTS_DIR, "..", "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    import gzip
    with gzip.open(os.path.join(
            hlo_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.hlo.gz"),
            "wt") as f:
        f.write(hlo)
    return rec


def save(rec: dict, tag: str = ""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        RESULTS_DIR,
        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def all_cells():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            for mesh_kind in ("single", "multi"):
                yield arch, shape.name, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--remat", default="nothing_saveable")
    ap.add_argument("--tag", default="")
    ap.add_argument("--dp", type=int, default=0)
    ap.add_argument("--tp", type=int, default=0)
    ap.add_argument("--uneven-heads", action="store_true")
    ap.add_argument("--score-dtype", default="float32")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else \
        [(args.arch, args.shape, args.mesh)]
    failures = 0
    for arch, shape, mesh_kind in cells:
        suffix = f"__{args.tag}" if args.tag else ""
        out = os.path.join(RESULTS_DIR,
                           f"{arch}__{shape}__{mesh_kind}{suffix}.json")
        if args.skip_existing and os.path.exists(out):
            print(f"[skip] {arch} {shape} {mesh_kind}")
            continue
        try:
            rec = run_cell(arch, shape, mesh_kind, zero1=args.zero1,
                           remat=args.remat, tag=args.tag, dp=args.dp,
                           tp=args.tp, uneven=args.uneven_heads,
                           score_dtype=args.score_dtype,
                           microbatches=args.microbatches)
            path = save(rec, args.tag)
            print(f"[ok] {arch} {shape} {mesh_kind} "
                  f"compile={rec['compile_s']}s flops={rec['cost'].get('flops')}"
                  f" -> {path}", flush=True)
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} {shape} {mesh_kind}", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
