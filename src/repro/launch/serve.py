"""Batched serving driver: prefill a batch of prompts, decode new tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --preset 100m --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.launch.train import preset_config
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--preset", default="100m",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)

    capacity = model.capacity_for(S + args.new_tokens)
    prefill = jax.jit(lambda p, t: model.prefill(p, t, capacity=capacity))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    jax.block_until_ready(prefill(params, prompts))     # compile warmup
    t0 = time.time()
    cache, logits = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.asarray(S + i, jnp.int32)
        cache, logits = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    toks = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} new={args.new_tokens}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({B*(args.new_tokens-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample continuation:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
