"""LR schedules: cosine and WSD (Warmup-Stable-Decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, base_lr: float, total_steps: int,
                  warmup_steps: int = 0, decay_frac: float = 0.1,
                  final_lr_frac: float = 0.1):
    warmup_steps = warmup_steps or max(1, total_steps // 100)

    if kind == "cosine":
        def fn(step):
            step = jnp.asarray(step, jnp.float32)
            warm = step / warmup_steps
            prog = jnp.clip((step - warmup_steps)
                            / jnp.maximum(1, total_steps - warmup_steps),
                            0.0, 1.0)
            cos = final_lr_frac + (1 - final_lr_frac) \
                * 0.5 * (1 + jnp.cos(jnp.pi * prog))
            return base_lr * jnp.where(step < warmup_steps, warm, cos)
        return fn

    if kind == "wsd":
        # MiniCPM: linear warmup, long stable plateau, short exponential-ish
        # decay over the final ``decay_frac`` of training.
        decay_start = int(total_steps * (1.0 - decay_frac))

        def fn(step):
            step = jnp.asarray(step, jnp.float32)
            warm = step / warmup_steps
            stable = jnp.ones(())
            prog = jnp.clip((step - decay_start)
                            / jnp.maximum(1, total_steps - decay_start),
                            0.0, 1.0)
            decay = jnp.power(10.0, -prog) * (1 - prog) + final_lr_frac * prog
            val = jnp.where(step < warmup_steps, warm,
                            jnp.where(step < decay_start, stable, decay))
            return base_lr * val
        return fn

    raise ValueError(f"unknown schedule {kind!r}")
