"""AdamW with decoupled weight decay.  State kept in fp32."""
from __future__ import annotations

import jax
import jax.numpy as jnp


class AdamW:
    def __init__(self, lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
        self.lr_fn = lr_fn
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def state_spec_like(self, param_specs):
        """Optimizer-state PartitionSpecs mirror the parameter specs."""
        return {"mu": param_specs, "nu": param_specs}

    def update(self, grads, state, params, step):
        b1, b2 = self.b1, self.b2
        t = (step + 1).astype(jnp.float32)
        lr = self.lr_fn(step)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            mu_hat = mu / (1 - b1 ** t)
            nu_hat = nu / (1 - b2 ** t)
            delta = mu_hat / (jnp.sqrt(nu_hat) + self.eps)
            if p.ndim >= 2:                      # no decay on norms/biases
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, mu, nu

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda o: isinstance(o, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda o: isinstance(o, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out,
                              is_leaf=lambda o: isinstance(o, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu}
