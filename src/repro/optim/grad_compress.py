"""Cross-pod gradient compression (hierarchical reduction).

On a multi-pod mesh the inter-pod links are the scarcest bandwidth.  The
standard production trick is hierarchical gradient reduction: full-
precision all-reduce *within* a pod (fast ICI), compressed all-reduce
*across* pods (slow DCI/optical links).  This module implements the
cross-pod stage as an int8 quantized psum with error feedback (the
residual of quantization is carried into the next step, preserving
convergence — 1-bit/low-bit SGD literature).

Wire effect: the cross-pod gradient traffic drops 4x (fp32 -> int8 +
one fp32 scale per tensor).  The dry-run records the reduction in the
'pod'-axis collective bytes (§Perf, multi-pod hillclimb).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g: jax.Array, err: jax.Array,
                         axis: str) -> Tuple[jax.Array, jax.Array]:
    """int8-quantized psum with error feedback for one gradient leaf.
    Executed inside shard_map; g is this pod's partial gradient."""
    g = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g)
    deq_local = dequantize_int8(q, scale)
    new_err = g - deq_local                      # error feedback residual
    # the wire payload is (q int8, scale fp32); the psum itself must
    # accumulate in >=i32 to avoid overflow across pods
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    scale_sum = jax.lax.psum(scale, axis)        # conservative shared scale
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    out = summed.astype(jnp.float32) * (scale_sum / n) / n
    return out, new_err


def make_cross_pod_sync(mesh, param_specs, pod_axis: str = "pod"):
    """Returns sync(grads, err_state) -> (synced_grads, new_err_state).

    grads are assumed already reduced within the pod (the jit backward
    does that); this applies the compressed mean across pods.
    param_specs: pytree of PartitionSpec for the gradient leaves (model-
    axis sharding); the pod axis must be unsharded in them.
    """
    def one(spec):
        def fn(g, e):
            return compressed_psum_leaf(g, e, pod_axis)
        return shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec))

    def sync(grads, err_state):
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = tdef.flatten_up_to(err_state)
        flat_s = tdef.flatten_up_to(param_specs)
        outs = [one(s)(g, e) for g, e, s in zip(flat_g, flat_e, flat_s)]
        new_g = tdef.unflatten([o[0] for o in outs])
        new_e = tdef.unflatten([o[1] for o in outs])
        return new_g, new_e

    return sync


def init_error_state(grads_shape_tree):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape_tree)
