from repro.optim.adamw import AdamW  # noqa: F401
from repro.optim.adafactor import Adafactor  # noqa: F401
from repro.optim.schedule import make_schedule  # noqa: F401


def make_optimizer(name: str, lr_fn, weight_decay: float = 0.1):
    if name == "adamw":
        return AdamW(lr_fn, weight_decay=weight_decay)
    if name == "adafactor":
        return Adafactor(lr_fn, weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")
