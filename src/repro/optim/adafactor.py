"""Adafactor: factored second moments — the memory-lean optimizer used for
the 398B (jamba) and 1T (kimi-k2) archs, where AdamW fp32 state (12.5 TB
for 1.04T params) exceeds a 512-chip v5e slice's 8 TB HBM.

For a [.., r, c] tensor the second moment is factored into row/col means
(O(r+c) state); 0/1-D tensors keep the full accumulator.  First moment is
omitted (beta1=0, the standard memory-lean setting).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Adafactor:
    def __init__(self, lr_fn, decay=0.8, eps=1e-30, clip_threshold=1.0,
                 weight_decay=0.0):
        self.lr_fn = lr_fn
        self.decay = decay
        self.eps = eps
        self.clip = clip_threshold
        self.weight_decay = weight_decay

    @staticmethod
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(self, params):
        def vr(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return {"v_row": jax.tree.map(vr, params),
                "v_col": jax.tree.map(vc, params)}

    def state_spec_like(self, param_specs):
        def row(spec):
            parts = list(spec)
            return P(*parts[:-1]) if len(parts) >= 2 else spec

        def col(spec):
            parts = list(spec)
            if len(parts) >= 2:
                return P(*(parts[:-2] + parts[-1:]))
            return P(None)

        return {"v_row": jax.tree.map(row, param_specs),
                "v_col": jax.tree.map(col, param_specs)}

    def update(self, grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - jnp.power(t, -self.decay)
        lr = self.lr_fn(step)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if self._factored(p):
                vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                row_mean = jnp.mean(vr, axis=-1, keepdims=True)
                u = g * jax.lax.rsqrt(vr / jnp.maximum(row_mean, self.eps)
                                      )[..., None] \
                    * jax.lax.rsqrt(vc)[..., None, :]
            else:
                vr = beta2 * vr + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(vr)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip)
            if p.ndim >= 2 and self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, vr, vc

        out = jax.tree.map(upd, grads, state["v_row"], state["v_col"],
                           params)
        pick = lambda i: jax.tree.map(
            lambda o: o[i], out, is_leaf=lambda o: isinstance(o, tuple))
        return pick(0), {"v_row": pick(1), "v_col": pick(2)}
