"""Content-addressable checkpointing — the paper's technique as a
first-class training-framework feature.

This is exactly the paper's *checkpoint workload* (§4.3, Figure 11: 100
successive BLCR checkpoint images, 76-90% CDC similarity) turned into the
framework's checkpoint subsystem: every parameter/optimizer leaf is
serialized and written through the SAI into the content-addressable store
with accelerator-offloaded hashing.  Successive checkpoints of a slowly-
moving training state dedup against each other, so incremental checkpoint
cost is proportional to *changed* bytes, not model size; restore verifies
content hashes (integrity) and survives storage-node failures via
replication.

``save`` streams every leaf through the SAI's async write pipeline in one
burst: all leaves are submitted up front (chunk/hash of leaf i+1 overlaps
the store of leaf i) and the offload engine coalesces the per-leaf hash
requests into fused batch kernel launches — one batched hash submission
instead of N synchronous per-leaf writes.

``async_save`` additionally offloads the whole save to a background
thread (the training loop keeps stepping), mirroring the paper's
observation that offloading frees the host CPU for the application.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro import compat
from repro.core.sai import SAI, WriteStats


def _flatten(tree) -> List[Tuple[str, np.ndarray]]:
    leaves = compat.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class CACheckpointer:
    def __init__(self, sai: SAI, prefix: str = "ckpt"):
        self.sai = sai
        self.prefix = prefix
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    def save(self, step: int, params, opt_state=None,
             extra: Optional[dict] = None) -> dict:
        t0 = time.perf_counter()
        state = {"params": params}
        if opt_state is not None:
            state["opt"] = opt_state
        leaves = _flatten(state)
        # submit the whole burst before gathering: the engine fuses the
        # queued per-leaf hash requests into batched launches, and the
        # pipeline overlaps chunk/hash of leaf i+1 with store of leaf i
        futs = [(key, arr, f"{self.prefix}/{key}",
                 self.sai.write_async(f"{self.prefix}/{key}",
                                      arr.tobytes()))
                for key, arr in leaves]
        manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
        totals = WriteStats()
        for key, arr, path, fut in futs:
            st = fut.result()
            manifest["leaves"].append(
                {"key": key, "shape": list(arr.shape),
                 "dtype": str(arr.dtype),
                 "version": self.sai.manager.num_versions(path) - 1})
            totals.total_bytes += st.total_bytes
            totals.new_bytes += st.new_bytes
            totals.new_blocks += st.new_blocks
            totals.dup_blocks += st.dup_blocks
        mpath = f"{self.prefix}/MANIFEST"
        self.sai.write(mpath, json.dumps(manifest).encode())
        rec = {
            "step": int(step),
            "total_bytes": totals.total_bytes,
            "new_bytes": totals.new_bytes,
            "dedup_ratio": 1.0 - totals.new_bytes
            / max(totals.total_bytes, 1),
            "wall_s": time.perf_counter() - t0,
        }
        with self._lock:
            self.history.append(rec)
        return rec

    def async_save(self, step: int, params, opt_state=None,
                   extra: Optional[dict] = None) -> threading.Thread:
        """Non-blocking save: snapshot to host, hash+store in background."""
        snap_p = compat.tree_map(np.asarray, params)
        snap_o = compat.tree_map(np.asarray, opt_state) \
            if opt_state is not None else None
        self.wait()
        t = threading.Thread(
            target=self.save, args=(step, snap_p, snap_o, extra),
            daemon=True, name=f"ca-ckpt-{step}")
        t.start()
        self._pending = t
        return t

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ------------------------------------------------------------------
    def restore(self, version: int = -1):
        """Returns (step, state dict) for the requested manifest version.

        Every leaf is read through the SAI's pipelined ``read_async``:
        all reads are submitted up front, so the verify stage of leaf i
        (one fused engine hash request per leaf) overlaps the fetch of
        leaf i+1 and the per-leaf verify requests coalesce into batched
        kernel launches — the read-side mirror of ``save``'s burst."""
        raw = self.sai.read(f"{self.prefix}/MANIFEST", version=version)
        manifest = json.loads(raw.decode())
        futs = [(leaf, self.sai.read_async(f"{self.prefix}/{leaf['key']}",
                                           version=leaf["version"]))
                for leaf in manifest["leaves"]]
        flat: Dict[str, np.ndarray] = {}
        for leaf, fut in futs:
            arr = np.frombuffer(fut.result(),
                                dtype=leaf["dtype"]).reshape(leaf["shape"])
            flat[leaf["key"]] = arr
        return manifest["step"], _unflatten(flat), manifest["extra"]


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, arr in flat.items():
        parts = key.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = arr
    return root
