"""Fault tolerance + straggler mitigation for the training loop.

``TrainSupervisor`` wraps the step loop with:
  * periodic content-addressable checkpointing (sync or async);
  * automatic restart-from-checkpoint on step failure (node crash is
    simulated by exceptions — on a real slice this is the coordinator
    restarting the job on respawned workers);
  * elastic batch resharding: on restart with a different data-parallel
    world size the same global batch is re-split (the deterministic
    pipeline regenerates the identical token stream for any shard count);
  * straggler monitoring: steps slower than ``straggler_factor`` x the
    trailing median are logged (on multi-host, the mitigation is the async
    checkpoint path plus the synchronous collective barrier already
    bounding skew).
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List, Optional

import jax

from repro import compat
import numpy as np


class InjectedFailure(RuntimeError):
    """Simulated worker failure (tests inject via fail_at_steps)."""


class TrainSupervisor:
    def __init__(self, train_step: Callable, pipeline, checkpointer=None,
                 ckpt_every: int = 50, async_ckpt: bool = True,
                 max_restarts: int = 3, straggler_factor: float = 2.0,
                 fail_at_steps: Optional[Dict[int, int]] = None):
        self.train_step = train_step
        self.pipeline = pipeline
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.async_ckpt = async_ckpt
        self.max_restarts = max_restarts
        self.straggler_factor = straggler_factor
        self.fail_at_steps = dict(fail_at_steps or {})
        self.step_times: List[float] = []
        self.stragglers: List[int] = []
        self.restarts = 0
        self.log: List[dict] = []

    def run(self, params, opt_state, start_step: int, num_steps: int):
        step = start_step
        while step < start_step + num_steps:
            try:
                t0 = time.perf_counter()
                if self.fail_at_steps.get(step, 0) > 0:
                    self.fail_at_steps[step] -= 1
                    raise InjectedFailure(f"simulated failure at {step}")
                batch = {k: np.asarray(v)
                         for k, v in self.pipeline.batch(step).items()}
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch,
                    np.int32(step))
                dt = time.perf_counter() - t0
                self._track_time(step, dt)
                self.log.append({"step": step,
                                 "loss": float(metrics["loss"]),
                                 "time_s": dt})
                step += 1
                if self.ckpt is not None and step % self.ckpt_every == 0:
                    if self.async_ckpt:
                        self.ckpt.async_save(step, params, opt_state)
                    else:
                        self.ckpt.save(step, params, opt_state)
            except InjectedFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if self.ckpt is None:
                    raise
                self.ckpt.wait()
                rstep, state, _ = self.ckpt.restore()
                params = _cast_like(params, state["params"])
                opt_state = _cast_like(opt_state, state["opt"])
                step = rstep
        if self.ckpt is not None:
            self.ckpt.wait()
        return params, opt_state

    def _track_time(self, step: int, dt: float):
        self.step_times.append(dt)
        hist = self.step_times[-20:]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.straggler_factor * med:
                self.stragglers.append(step)


def _cast_like(template, restored):
    """Restore numpy state into the template pytree's dtypes/devices."""
    return compat.tree_map(
        lambda t, r: jax.numpy.asarray(r, dtype=t.dtype), template, restored)


def elastic_reshard(pipeline, new_num_shards: int):
    """Rebuild the pipeline for a different dp world size; the token
    stream for a given global step is unchanged (determinism by step)."""
    import dataclasses
    return dataclasses.replace(pipeline, num_shards=new_num_shards,
                               shard=min(pipeline.shard,
                                         new_num_shards - 1))
