from repro.train.trainstep import make_train_step, blocked_cross_entropy  # noqa: F401
