"""Training step: blocked cross-entropy + grad + optimizer update.

Memory design notes:
  * Cross-entropy is computed *blocked over the sequence* with a
    rematerialised chunk body, so the fp32 [B, S, V] logits tensor is
    never resident (for llama3 train_4k that tensor would be ~33 GB per
    device).  Each chunk computes logits -> CE -> discards; backward
    recomputes the chunk logits.
  * Optional microbatching (gradient accumulation) splits the batch and
    accumulates grads in fp32 — the standard large-scale trick when the
    per-step activation footprint exceeds HBM.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax

from repro import compat
import jax.numpy as jnp

CE_CHUNK = 512


def _ce_chunk(x, head, labels, mask, logit_scale):
    """x: [B, c, d]; head: [d, V]; labels/mask: [B, c] -> (sum_nll, count)."""
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32) * logit_scale
    lse = jax.nn.logsumexp(logits, axis=-1)                   # [B, c]
    onehot = jax.nn.one_hot(labels, logits.shape[-1],
                            dtype=logits.dtype)               # fused by XLA
    picked = jnp.sum(logits * onehot, axis=-1)                # [B, c]
    nll = (lse - picked) * mask
    return jnp.sum(nll), jnp.sum(mask)


def blocked_cross_entropy(x, head, labels, mask, logit_scale=1.0,
                          chunk: int = CE_CHUNK):
    """Sequence-blocked CE.  x: [B, S, d]; labels/mask: [B, S]."""
    B, S, d = x.shape
    if S % chunk or S <= chunk:
        return _ce_chunk(x, head, labels, mask, logit_scale)
    n = S // chunk
    xs = x.reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, n, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        s, c = _ce_chunk(xc, head, lc, mc, logit_scale)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls, ms))
    return tot, cnt


def make_loss_fn(model, aux_weight: float = 0.01):
    cfg = model.cfg
    F = cfg.frontend_embeds

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        embeds = batch.get("embeds")
        x, aux = model.forward(params, tokens, embeds, return_hidden=True)
        head = model.unembed_matrix(params)
        if F and embeds is not None:
            # frontend positions prepended: prediction for text token j
            # comes from hidden position F - 1 + j.
            x_pred = x[:, F - 1:-1]
            labels = tokens
            mask = jnp.ones(labels.shape, jnp.float32)
        else:
            x_pred = x[:, :-1]
            labels = tokens[:, 1:]
            mask = jnp.ones(labels.shape, jnp.float32)
        tot, cnt = blocked_cross_entropy(x_pred, head, labels, mask,
                                         cfg.logit_scale)
        ce = tot / jnp.maximum(cnt, 1.0)
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(model, optimizer, microbatches: int = 1,
                    aux_weight: float = 0.01):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(model, aux_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                B = x.shape[0]
                return x.reshape(microbatches, B // microbatches,
                                 *x.shape[1:])
            mb = compat.tree_map(split, batch)

            def body(carry, mbatch):
                gsum, lsum = carry
                (loss, _), g = grad_fn(params, mbatch)
                gsum = compat.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            gzero = compat.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(
                body, (gzero, jnp.zeros((), jnp.float32)), mb)
            grads = compat.tree_map(lambda g: g / microbatches, grads)
            loss = lsum / microbatches
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in compat.tree_leaves(grads)))
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr=optimizer.lr_fn(step))
        return params, opt_state, metrics

    return train_step
