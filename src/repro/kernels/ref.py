"""Pure-jnp oracles for the hashing kernels.

These are the correctness references for the Pallas kernels (which are
additionally anchored to ``hashlib.md5`` ground truth in tests).

Alignment convention (TPU adaptation, documented in DESIGN.md): all hashed
segments are 4-byte (word) aligned — the storage layer aligns chunk
boundaries to 4 B, which costs nothing in dedup quality and lets every
kernel operate on uint32 words (the natural VPU element).  MD5 padding for
word-aligned messages occupies whole words: 0x00000080 then zeros then the
64-bit little-endian bit length.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# MD5 constants
# --------------------------------------------------------------------------
MD5_K = tuple(int(abs(math.sin(i + 1)) * 2 ** 32) & 0xFFFFFFFF
              for i in range(64))
MD5_S = (7, 12, 17, 22) * 4 + (5, 9, 14, 20) * 4 + (4, 11, 16, 23) * 4 \
    + (6, 10, 15, 21) * 4
MD5_INIT = (0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476)


def md5_g(i: int) -> int:
    if i < 16:
        return i
    if i < 32:
        return (5 * i + 1) % 16
    if i < 48:
        return (3 * i + 5) % 16
    return (7 * i) % 16


def _rotl(x, s):
    return (x << jnp.uint32(s)) | (x >> jnp.uint32(32 - s))


def md5_chunk_update(a, b, c, d, M):
    """One 64-round MD5 chunk update.  a..d: uint32 arrays; M: [16, ...]."""
    a0, b0, c0, d0 = a, b, c, d
    for i in range(64):
        if i < 16:
            f = (b & c) | (~b & d)
        elif i < 32:
            f = (d & b) | (~d & c)
        elif i < 48:
            f = b ^ c ^ d
        else:
            f = c ^ (b | ~d)
        f = f + a + jnp.uint32(MD5_K[i]) + M[md5_g(i)]
        a = d
        d = c
        c = b
        b = b + _rotl(f, MD5_S[i])
    return a0 + a, b0 + b, c0 + c, d0 + d


def md5_words_ref(data: jax.Array, lens_w: jax.Array) -> jax.Array:
    """MD5 of N word-aligned messages.

    data: [N, max_words] uint32 (little-endian words of the message,
    zero-padded); lens_w: [N] int32 message lengths in words.
    Returns [N, 4] uint32 (a, b, c, d) — the standard digest read as four
    little-endian words.
    """
    data = data.astype(jnp.uint32)
    N, max_words = data.shape
    max_chunks = (max_words + 3 + 15) // 16
    nchunks = (lens_w + 3 + 15) // 16                       # [N]
    bits_lo = (lens_w.astype(jnp.uint32) << jnp.uint32(5))
    bits_hi = (lens_w.astype(jnp.uint32) >> jnp.uint32(27))

    a = jnp.full((N,), MD5_INIT[0], jnp.uint32)
    b = jnp.full((N,), MD5_INIT[1], jnp.uint32)
    c = jnp.full((N,), MD5_INIT[2], jnp.uint32)
    d = jnp.full((N,), MD5_INIT[3], jnp.uint32)

    def padded_word(chunk_idx, j):
        w = chunk_idx * 16 + j                               # global word idx
        raw = data[:, w] if w < max_words else jnp.zeros((N,), jnp.uint32)
        is_data = w < lens_w
        is_pad80 = w == lens_w
        is_blo = w == (nchunks * 16 - 2)
        is_bhi = w == (nchunks * 16 - 1)
        out = jnp.where(is_data, raw, jnp.uint32(0))
        out = jnp.where(is_pad80 & ~is_data, jnp.uint32(0x80), out)
        out = jnp.where(is_blo & ~is_data & ~is_pad80, bits_lo, out)
        out = jnp.where(is_bhi & ~is_data & ~is_pad80, bits_hi, out)
        return out

    for chunk in range(max_chunks):
        M = [padded_word(chunk, j) for j in range(16)]
        na, nb, nc_, nd = md5_chunk_update(a, b, c, d, M)
        active = chunk < nchunks
        a = jnp.where(active, na, a)
        b = jnp.where(active, nb, b)
        c = jnp.where(active, nc_, c)
        d = jnp.where(active, nd, d)
    return jnp.stack([a, b, c, d], axis=1)


# --------------------------------------------------------------------------
# helpers to go between bytes and word arrays
# --------------------------------------------------------------------------
def bytes_to_words(buf: bytes) -> np.ndarray:
    assert len(buf) % 4 == 0, "word-aligned input required"
    return np.frombuffer(buf, dtype="<u4").copy()


def digest_words_to_bytes(dig: np.ndarray) -> bytes:
    return np.asarray(dig, dtype="<u4").tobytes()


def md5_hex_ref(buf: bytes) -> str:
    """MD5 hex digest of a word-aligned byte string (matches hashlib)."""
    w = bytes_to_words(buf)
    data = jnp.asarray(w)[None, :] if len(w) else \
        jnp.zeros((1, 1), jnp.uint32)
    lens = jnp.asarray([len(w)], jnp.int32)
    dig = md5_words_ref(data, lens)
    return digest_words_to_bytes(np.asarray(dig[0])).hex()


# --------------------------------------------------------------------------
# sliding-window MD5 (content-based chunking, paper-faithful primitive)
# --------------------------------------------------------------------------
def sliding_md5_ref(data_bytes: jax.Array, window: int,
                    stride: int = 1) -> jax.Array:
    """MD5 digest word 'a' of every window of ``window`` bytes.

    data_bytes: [L] uint8; window must be a multiple of 4 and <= 52 so the
    padded message fits one MD5 chunk.  Returns [n_off] uint32 where
    n_off = (L - window)//stride + 1.
    """
    assert window % 4 == 0 and window <= 52
    L = data_bytes.shape[0]
    n_off = (L - window) // stride + 1
    offs = jnp.arange(n_off, dtype=jnp.int32) * stride      # [n_off]
    idx = offs[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    wins = data_bytes[idx].astype(jnp.uint32)               # [n_off, window]
    # pack LE words
    wins = wins.reshape(n_off, window // 4, 4)
    words = (wins[..., 0] | (wins[..., 1] << 8) | (wins[..., 2] << 16)
             | (wins[..., 3] << 24))                        # [n_off, w/4]
    lens = jnp.full((n_off,), window // 4, jnp.int32)
    dig = md5_words_ref(words, lens)
    return dig[:, 0]


# --------------------------------------------------------------------------
# gear rolling hash (beyond-paper TPU-native CDC primitive)
# --------------------------------------------------------------------------
GEAR_WINDOW = 32


def mix32(x: jax.Array) -> jax.Array:
    """murmur3 fmix32 — table-free 'gear' function of a byte value."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def gear_ref(data_bytes: jax.Array) -> jax.Array:
    """Windowed gear hash at every byte position.

    h_i = sum_{j=0}^{31} mix32(b_{i-j}) << j   (b_{<0} treated as 0)
    data_bytes: [L] uint8 -> [L] uint32.  Identical chunking behaviour to
    the sequential FastCDC gear recurrence h = (h << 1) + gear[b] (bits
    shifted out beyond 32 drop in both forms).
    """
    g = mix32(data_bytes + jnp.uint32(1))                   # avoid mix(0)=0
    L = g.shape[0]
    h = jnp.zeros((L,), jnp.uint32)
    for j in range(GEAR_WINDOW):
        shifted = jnp.pad(g, (j, 0))[:L] << jnp.uint32(j)
        h = h + shifted
    return h
