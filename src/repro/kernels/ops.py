"""jit'd host-facing wrappers around the Pallas hashing kernels.

All APIs take/return numpy-friendly arrays; padding, word packing, byte-
phase strip construction and output interleaving live here so the kernels
stay shape-regular.  ``interpret=True`` (the CPU default here) executes
the kernel bodies in Python via the Pallas interpreter; on TPU the same
calls lower to Mosaic.

Two layers are exposed:
  * convenience wrappers (``direct_hash``, ``sliding_window_hash``,
    ``gear_hash``) that take host arrays and do prep + launch + finish;
  * device-resident entry points (``direct_hash_device``,
    ``sliding_hash_device``, ``gear_hash_device``) plus host-side finish
    helpers (``digest_bytes``, ``sliding_finish``, ``gear_finish``) used
    by the CrystalTPU offload engine, which manages its own staging
    buffers and ``device_put`` so data stays on the accelerator from
    transfer through kernel with no host round-trip.

Stream batching: ``sliding_hash_batch_device`` / ``gear_hash_batch_device``
take a padded [B, L] word matrix (B independent buffers) and execute the
whole batch as ONE kernel launch — the engine fuses bursts of same-config
stream jobs through these, then slices each job's rows out of the fused
phase-matrix output host-side (``sliding_finish`` / ``gear_finish`` per
row).  Rows are zero-padded to the widest buffer in the batch; window
hashes only ever read bytes inside their own job's valid prefix, so
padding never changes a returned hash.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gear as gear_k
from repro.kernels import md5 as md5_k
from repro.kernels import sliding_md5 as slide_k

# --------------------------------------------------------------------------
# direct hashing
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def _direct_hash_words(data: jax.Array, lens_w: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """data: [N, W] uint32; lens_w: [N] int32 -> [N, 4] uint32 digests."""
    N, W = data.shape
    n_pad = (-N) % md5_k.TILE_N
    # bound the chunk grid to ~32 steps for long segments (grid dispatch
    # dominates on the interpreter; on TPU this is simply a larger VMEM
    # message block, capped at 16*128*TILE_N words ~ 1 MB)
    n_chunks = (W + 3 + 15) // 16
    chunk_tile = min(512, max(md5_k.CHUNK_TILE, -(-n_chunks // 8)))
    w_pad = (-(W + 3)) % (16 * chunk_tile) + 3
    data = jnp.pad(data, ((0, n_pad), (0, w_pad)))
    lens = jnp.pad(lens_w.astype(jnp.int32), (0, n_pad))
    dig = md5_k.md5_pallas(data.T, lens, interpret=interpret,
                           chunk_tile=chunk_tile)              # [4, Npad]
    return dig.T[:N]


def direct_hash_device(words: jax.Array, lens_w: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """Device-resident direct hashing: ``words`` [N, W] uint32 already on
    the target device, ``lens_w`` [N] int32 word lengths.  Returns the
    [N, 4] uint32 digest array *on device* (callers pull it with
    ``digest_bytes`` — 16 B/row, the only host transfer)."""
    return _direct_hash_words(words, lens_w, interpret=interpret)


def digest_bytes(dig) -> np.ndarray:
    """[N, 4] uint32 digests (device or host) -> [N, 16] uint8 host."""
    dig = np.asarray(dig)
    return dig.astype("<u4").view(np.uint8).reshape(dig.shape[0], 16)


def direct_hash(segments: np.ndarray, lens_bytes=None,
                interpret: bool = True) -> np.ndarray:
    """MD5 digests of N word-aligned segments.

    segments: [N, seg_bytes/4] uint32 (or uint8 [N, seg_bytes]);
    lens_bytes: optional [N] actual byte lengths (multiples of 4).
    Returns [N, 16] uint8 digests (hashlib-identical).
    """
    segments = np.asarray(segments)
    if segments.dtype == np.uint8:
        assert segments.shape[1] % 4 == 0
        segments = segments.view("<u4") if segments.flags.c_contiguous \
            else np.ascontiguousarray(segments).view("<u4")
    N, W = segments.shape
    if lens_bytes is None:
        lens_w = np.full((N,), W, np.int32)
    else:
        lens_bytes = np.asarray(lens_bytes)
        assert np.all(lens_bytes % 4 == 0)
        lens_w = (lens_bytes // 4).astype(np.int32)
    dig = direct_hash_device(jnp.asarray(segments), jnp.asarray(lens_w),
                             interpret=interpret)
    return digest_bytes(dig)


def hash_blocks(data: bytes, block_bytes: int,
                interpret: bool = True) -> Tuple[np.ndarray, bytes]:
    """Fixed-size-block direct hashing of a buffer (paper's fixed-block
    content addressability).  Returns ([n_blocks, 16] digests, final
    digest bytes = md5 over the concatenated digests, computed host-side
    exactly like the paper's CPU post-processing stage)."""
    import hashlib
    n = (len(data) + block_bytes - 1) // block_bytes
    padded = data + b"\x00" * (n * block_bytes - len(data))
    arr = np.frombuffer(padded, np.uint8).reshape(n, block_bytes)
    lens = np.full((n,), block_bytes, np.int64)
    lens[-1] = len(data) - (n - 1) * block_bytes
    lens = ((lens + 3) // 4 * 4)                  # word-align tail
    digs = direct_hash(arr, lens, interpret=interpret)
    final = hashlib.md5(digs.tobytes()).digest()
    return digs, final


# --------------------------------------------------------------------------
# sliding-window MD5 (paper-faithful CDC)
# --------------------------------------------------------------------------
def _pick_tile(L: int, base: int) -> int:
    """Tile width bounding grid steps to ~64 (VMEM stays < ~0.5 MB/input
    block; interpret mode traces the grid as a Python loop, so step count
    dominates trace time on CPU)."""
    t = base
    while L // t > 64 and t < (1 << 15):
        t *= 2
    return t


def sliding_hash_device(words: jax.Array, w_words: int,
                        phases: Tuple[int, ...],
                        interpret: bool = True) -> jax.Array:
    """Device-resident sliding-window hashing: ``words`` [L] uint32 on
    the target device.  Returns the [R, Wc] uint32 per-phase hash matrix
    on device; ``sliding_finish`` interleaves it host-side.  (The B=1
    case of the batched path — one strip builder and one jit cache.)"""
    return _sliding_hash_words_batch(words[None], w_words, phases,
                                     interpret=interpret)[0]


def sliding_finish(out: np.ndarray, phases: Tuple[int, ...],
                   n_off: int) -> np.ndarray:
    """Interleave phase rows: offset o = 4q + phases[r] -> out[r, q]."""
    if n_off <= 0:                 # input shorter than one window
        return np.empty((0,), np.uint32)
    R, Wc = out.shape
    inter = np.empty((Wc * R,), np.uint32)
    for i, r in enumerate(phases):
        inter[i::R] = out[i]
    return inter[:n_off]


def _byte_phase_strips_batch(words: jax.Array, phases: Tuple[int, ...],
                             pad_words: int) -> jax.Array:
    """Batched strip construction: rows are independent buffers, so the
    cross-word carry shifts stay within each row."""
    B = words.shape[0]
    nxt = jnp.concatenate([words[:, 1:], jnp.zeros((B, 1), jnp.uint32)],
                          axis=1)
    strips = []
    for r in phases:
        if r == 0:
            s = words
        else:
            s = (words >> jnp.uint32(8 * r)) | (nxt << jnp.uint32(32 - 8 * r))
        strips.append(jnp.pad(s, ((0, 0), (0, pad_words))))
    return jnp.stack(strips, axis=1)                     # [B, R, L+pad]


@functools.partial(jax.jit,
                   static_argnames=("w_words", "phases", "interpret"))
def _sliding_hash_words_batch(words: jax.Array, w_words: int,
                              phases: Tuple[int, ...],
                              interpret: bool = True) -> jax.Array:
    B, L = words.shape
    T = _pick_tile(L, slide_k.TILE_W)
    w_cap = ((L + T - 1) // T) * T
    pad = w_cap - L + T
    strips = _byte_phase_strips_batch(words, phases, pad)  # [B,R,w_cap+T]
    R = len(phases)
    out = slide_k.sliding_md5_pallas(
        strips.reshape(B * R, w_cap + T), w_words,
        interpret=interpret, tile=T)                       # [B*R, 4, w_cap]
    return out[:, 0, :].reshape(B, R, w_cap)


def sliding_hash_batch_device(words: jax.Array, w_words: int,
                              phases: Tuple[int, ...],
                              interpret: bool = True) -> jax.Array:
    """Fused multi-buffer sliding-window hashing: ``words`` [B, L] uint32
    on the target device, one row per job (rows zero-padded to the batch
    width).  ONE kernel launch covers all B*R strips; returns the
    [B, R, Wc] uint32 per-job/per-phase hash matrix on device — callers
    slice row b and run ``sliding_finish`` with that job's own offset
    count."""
    return _sliding_hash_words_batch(words, w_words, phases,
                                     interpret=interpret)


def sliding_window_hash(data: bytes | np.ndarray, window: int = 48,
                        stride: int = 1,
                        interpret: bool = True) -> np.ndarray:
    """MD5 (digest word 'a') of every ``window``-byte window at byte
    offsets 0, stride, 2*stride, ...  window % 4 == 0, window <= 52;
    stride in {1, 2, 4}.  Returns [n_off] uint32."""
    assert window % 4 == 0 and window <= 52 and stride in (1, 2, 4)
    buf = np.frombuffer(data, np.uint8) if isinstance(data, (bytes,
                                                             bytearray)) \
        else np.asarray(data, np.uint8)
    L = len(buf)
    if L < window:                 # no complete window: empty hash array
        return np.empty((0,), np.uint32)
    n_off = (L - window) // stride + 1
    pad = (-L) % 4
    words = jnp.asarray(np.pad(buf, (0, pad)).view("<u4"))
    phases = tuple(range(0, 4, stride))
    out = np.asarray(sliding_hash_device(words, window // 4, phases,
                                         interpret=interpret))  # [R, Wc]
    return sliding_finish(out, phases, n_off)


# --------------------------------------------------------------------------
# gear rolling hash (beyond-paper CDC)
# --------------------------------------------------------------------------
def gear_hash_device(words: jax.Array, interpret: bool = True,
                     version: int = 1) -> jax.Array:
    """Device-resident gear hashing: ``words`` [L] uint32 on the target
    device.  Returns the [4, w_cap] uint32 phase matrix on device;
    ``gear_finish`` flattens it host-side.  (The B=1 case of the
    batched path — one pad/launch wrapper and one jit cache.)"""
    return _gear_hash_words_batch(words[None], interpret=interpret,
                                  version=version)[0]


@functools.partial(jax.jit, static_argnames=("interpret", "version"))
def _gear_hash_words_batch(words: jax.Array, interpret: bool = True,
                           version: int = 1) -> jax.Array:
    B, L = words.shape
    T = _pick_tile(L, gear_k.TILE_W)
    w_cap = ((L + T - 1) // T) * T
    strip = jnp.pad(words, ((0, 0), (T, w_cap - L)))   # per-row history 0s
    return gear_k.gear_pallas(strip, interpret=interpret,
                              version=version, tile=T)       # [B, 4, w_cap]


def gear_hash_batch_device(words: jax.Array, interpret: bool = True,
                           version: int = 1) -> jax.Array:
    """Fused multi-buffer gear hashing: ``words`` [B, L] uint32 on the
    target device, one row per job (rows zero-padded to the batch width).
    ONE kernel launch covers the whole batch; returns the [B, 4, Wc]
    uint32 phase matrices on device — callers slice row b and flatten it
    with ``gear_finish`` using that job's own byte length."""
    return _gear_hash_words_batch(words, interpret=interpret,
                                  version=version)


def gear_finish(out: np.ndarray, n_bytes: int) -> np.ndarray:
    """Flatten [4, w_cap] phase matrix to per-byte order (4q + r)."""
    return out.T.reshape(-1)[:n_bytes]


def gear_hash(data: bytes | np.ndarray, interpret: bool = True,
              version: int = 1) -> np.ndarray:
    """Windowed gear hash at every byte position.  Returns [L] uint32.
    Positions < 32 differ from ref (zero-history convention) — chunking
    never places boundaries inside the minimum chunk size anyway.
    ``version=2`` selects the log-doubling kernel (§Perf C2) — identical
    outputs, ~3x fewer VPU ops."""
    buf = np.frombuffer(data, np.uint8) if isinstance(data, (bytes,
                                                             bytearray)) \
        else np.asarray(data, np.uint8)
    L = len(buf)
    pad = (-L) % 4
    words = jnp.asarray(np.pad(buf, (0, pad)).view("<u4"))
    out = np.asarray(gear_hash_device(words, interpret=interpret,
                                      version=version))
    return gear_finish(out, L)


# ----------------------------------------------------------------------
# whale-job shard planning (host-side helpers for the engine mesh)
# ----------------------------------------------------------------------
# the gear hash at byte p is a 32-tap window over x[p-31..p] (each tap
# shifts out of the 32-bit accumulator after 32 doublings), so a shard
# that carries 32 bytes of left context reproduces the full-buffer
# output from its first owned byte onward
GEAR_HISTORY_BYTES = 32


def shard_row_ranges(n_rows: int, n_shards: int):
    """Balanced contiguous ``[start, stop)`` row ranges covering
    ``n_rows`` — the per-device sub-launch split of a whale direct-hash
    job (row digests are independent, so any row partition reassembles
    by concatenation in range order)."""
    k = max(1, min(int(n_shards), int(n_rows)))
    base, rem = divmod(int(n_rows), k)
    ranges = []
    start = 0
    for i in range(k):
        stop = start + base + (1 if i < rem else 0)
        ranges.append((start, stop))
        start = stop
    return ranges


def stream_shard_plan(n_bytes: int, kind: str, n_shards: int,
                      window: int = 48, stride: int = 4):
    """Byte-slice plan ``[(start, stop, n_drop), ...]`` splitting one
    stream buffer into sub-launches whose outputs — after dropping the
    first ``n_drop`` values of each shard — concatenate to exactly the
    unsharded kernel output.

    sliding: the offset grid ``o = f * stride`` partitions across
    shards; each shard's slice starts at its first owned offset (start
    is stride-aligned) and extends through the last owned window, so
    every window a shard owns lies fully inside its slice and nothing
    is dropped.

    gear: each shard k > 0 takes ``GEAR_HISTORY_BYTES`` of left
    context and drops that many leading outputs (they belong to the
    previous shard); the kernel's zero-history warm-up therefore only
    ever affects positions the previous shard already produced.

    Returns None when the buffer is too small to shard meaningfully.
    """
    n_bytes, k = int(n_bytes), int(n_shards)
    if kind == "sliding":
        n_off = (n_bytes - window) // stride + 1
        k = min(k, max(n_off // 2, 0))
        if k < 2:
            return None
        base, rem = divmod(n_off, k)
        plan = []
        f = 0
        for i in range(k):
            c = base + (1 if i < rem else 0)
            start = f * stride
            stop = min((f + c - 1) * stride + window, n_bytes)
            plan.append((start, stop, 0))
            f += c
        return plan
    if kind == "gear":
        h = GEAR_HISTORY_BYTES
        k = min(k, n_bytes // (4 * h))
        if k < 2:
            return None
        bounds = [n_bytes * i // k for i in range(k + 1)]
        plan = [(0, bounds[1], 0)]
        for i in range(1, k):
            plan.append((bounds[i] - h, bounds[i + 1], h))
        return plan
    return None
