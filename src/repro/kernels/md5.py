"""Pallas TPU kernel: parallel Merkle-Damgard MD5 (direct hashing).

TPU adaptation of HashGPU's direct-hashing module (the paper's GPU design
assigns one *thread* per segment; here one *VPU lane* per segment):

  * layout is word-major — ``data[word, segment]`` — so each MD5 round is
    a fully vectorized uint32 op across TILE_N segment lanes (8x128 VREG
    tiling), and the per-chunk message words are contiguous sublane rows;
  * the grid is (segment_tiles, chunk_tiles) with the chunk dimension
    innermost and 'arbitrary' (sequential): the digest state accumulates
    in the output block across chunk steps — the canonical Pallas
    reduction pattern — so VMEM holds only CHUNK_TILE * 16 message rows,
    never the whole segment (streaming HBM->VMEM like the paper's staged
    global->shared-memory pipeline);
  * MD5 padding (word-aligned messages) is generated in-register via
    vector selects, so lanes with different message lengths coexist in a
    tile (the GPU version's per-thread bounds checks, adapted to selects).

Hashing is integer-ALU work: it runs on the VPU (8x128 int32 ops/cycle),
not the MXU — the roofline for this kernel is VPU-issue-bound, which is
exactly the paper's 'compute-intensive primitive' premise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import MD5_INIT, md5_chunk_update

TILE_N = 128           # segments per tile (lane dim)
CHUNK_TILE = 4         # 64-byte chunks per grid step (16 words each)


def _md5_kernel(lens_ref, data_ref, out_ref, *, chunk_tile: int):
    """Chunks iterate via fori_loop (one 64-round body in the trace/IR
    regardless of segment length); rounds stay unrolled so message-word
    indices are static."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        for r, v in enumerate(MD5_INIT):
            out_ref[r, :] = jnp.full_like(out_ref[r, :], jnp.uint32(v))

    lens = lens_ref[:].astype(jnp.int32)                    # words per lane
    nchunks = (lens + 18) // 16
    bits_lo = lens.astype(jnp.uint32) << jnp.uint32(5)
    bits_hi = lens.astype(jnp.uint32) >> jnp.uint32(27)
    blk = data_ref[...]                                     # [16*ct, TILE_N]
    zero = jnp.zeros_like(blk[0])

    def body(cc, state):
        a, b, c, d = state
        chunk = j * chunk_tile + cc
        rows = jax.lax.dynamic_slice_in_dim(blk, cc * 16, 16, axis=0)
        M = []
        for jj in range(16):
            w = chunk * 16 + jj                             # global word
            raw = rows[jj]
            is_data = w < lens
            m = jnp.where(is_data, raw, zero)
            m = jnp.where((w == lens), jnp.uint32(0x80), m)
            m = jnp.where((w == nchunks * 16 - 2) & ~is_data & (w != lens),
                          bits_lo, m)
            m = jnp.where((w == nchunks * 16 - 1) & ~is_data & (w != lens),
                          bits_hi, m)
            M.append(m)
        na, nb, nc_, nd = md5_chunk_update(a, b, c, d, M)
        active = chunk < nchunks
        return (jnp.where(active, na, a), jnp.where(active, nb, b),
                jnp.where(active, nc_, c), jnp.where(active, nd, d))

    state = (out_ref[0, :], out_ref[1, :], out_ref[2, :], out_ref[3, :])
    a, b, c, d = jax.lax.fori_loop(0, chunk_tile, body, state)
    out_ref[0, :] = a
    out_ref[1, :] = b
    out_ref[2, :] = c
    out_ref[3, :] = d


def md5_pallas(data_T: jax.Array, lens_w: jax.Array,
               interpret: bool = True,
               chunk_tile: int = CHUNK_TILE) -> jax.Array:
    """MD5 of N word-aligned messages.

    data_T: [max_words_padded, N] uint32 (word-major!), N % TILE_N == 0,
    max_words_padded % (16 * chunk_tile) == 0; lens_w: [N] int32.
    ``chunk_tile`` = 64-byte chunks per grid step (VMEM block is
    16 * chunk_tile * TILE_N words; the wrapper sizes it to bound grid
    steps for long segments).
    Returns [4, N] uint32 digest words.
    """
    W, N = data_T.shape
    assert N % TILE_N == 0, N
    assert W % (16 * chunk_tile) == 0, (W, chunk_tile)
    n_seg_tiles = N // TILE_N
    n_chunk_tiles = W // (16 * chunk_tile)

    kernel = functools.partial(_md5_kernel, chunk_tile=chunk_tile)
    grid = (n_seg_tiles, n_chunk_tiles)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N,), lambda i, j: (i,)),
            pl.BlockSpec((16 * chunk_tile, TILE_N), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((4, TILE_N), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((4, N), jnp.uint32),
        interpret=interpret,
    )(lens_w, data_T)
