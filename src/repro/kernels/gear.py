"""Pallas TPU kernel: windowed gear rolling hash (beyond-paper CDC).

The paper's sliding-window MD5 costs 64 rounds (~10 uint32 ops each) per
byte offset ~= 640 ops/byte.  For *boundary detection* a cryptographic
hash is unnecessary — production dedup (FastCDC, Shredder's successor
designs) uses a gear hash.  The sequential gear recurrence
``h = (h << 1) + gear[b]`` looks serial, but because bits shift out after
32 steps it is exactly a 32-tap windowed weighted sum:

    h_p = sum_{j=0}^{31} gear(b_{p-j}) << j

i.e. a convolution — computable as 32 shifted vector adds, fully parallel
across lanes.  ~35 ops/byte: an ~18x arithmetic-intensity reduction over
sliding MD5 at equal chunking quality (§Perf hillclimb #3).

TPU-native details:
  * the gear function is table-free (murmur3 fmix32 of the byte) — a VMEM
    table gather would serialize on the VPU; 5 int ops beat a gather;
  * input is packed uint32 words; the 4 byte phases r in {0,1,2,3} are
    extracted in-register and each output stream h_r is assembled from
    cross-phase shifted slices (tap j of phase r reads phase (r-j) mod 4
    at word offset -((j - r + (r-j)%4)/4));
  * block overlap (31 bytes of history) uses the pass-the-strip-twice
    trick: index maps (i) and (i+1) give the kernel a 2*TILE window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import GEAR_WINDOW

TILE_W = 512           # words per tile


def _mix32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _gear_kernel(prev_ref, cur_ref, out_ref):
    full = jnp.concatenate([prev_ref[0, :], cur_ref[0, :]])  # [2T] words
    T = cur_ref.shape[1]
    # byte phases: g[r][k] = gear(byte at position 4k + r) over the 2T words
    g = []
    for r in range(4):
        byte = (full >> jnp.uint32(8 * r)) & jnp.uint32(0xFF)
        g.append(_mix32(byte + jnp.uint32(1)))
    # output streams: h_r[q] for word q in the current block
    for r in range(4):
        h = jnp.zeros((T,), jnp.uint32)
        for j in range(GEAR_WINDOW):
            rp = (r - j) % 4
            a = (j - r + rp) // 4
            # g_{rp}[q - a] for q in [0, T): slice full-phase at T - a
            src = jax.lax.dynamic_slice(g[rp], (T - a,), (T,))
            h = h + (src << jnp.uint32(j))
        out_ref[0, r, :] = h


def _gear_kernel_doubling(prev_ref, cur_ref, out_ref):
    """§Perf C2: log-doubling construction of the 32-tap windowed sum.

    S_0(p) = g_p;  S_{k+1}(p) = S_k(p) + (S_k(p - 2^k) << 2^k)
    After 5 levels S_5 equals the full 32-tap sum — 5 shifted adds per
    byte instead of 32 (napkin: ~2.8x fewer VPU ops than the direct
    kernel; measured via cost_analysis in benchmarks/kernel_roofline).

    Byte shifts of 1 and 2 cross the 4 byte phases; shifts 4/8/16 are
    whole words (phase-preserving rolls).  Rolled-in garbage only touches
    positions that the final [T, 2T) output window never depends on
    (31 bytes of real history < T pad words).
    """
    full = jnp.concatenate([prev_ref[0, :], cur_ref[0, :]])  # [2T] words
    T = cur_ref.shape[1]
    s_cur = []
    for r in range(4):
        byte = (full >> jnp.uint32(8 * r)) & jnp.uint32(0xFF)
        s_cur.append(_mix32(byte + jnp.uint32(1)))

    for k in range(5):                                       # shifts 1..16
        s = 1 << k
        nxt = []
        for r in range(4):
            rp = (r - s) % 4
            a = (s - r + rp) // 4
            src = jnp.roll(s_cur[rp], a) if a else s_cur[rp]
            nxt.append(s_cur[r] + (src << jnp.uint32(s)))
        s_cur = nxt

    for r in range(4):
        out_ref[0, r, :] = jax.lax.dynamic_slice(s_cur[r], (T,), (T,))


def _gear_kernel_hybrid(prev_ref, cur_ref, out_ref):
    """§Perf C3: depth-1 doubling then 16 direct taps.

    S1(p) = g_p + (g_{p-1} << 1) computed once over the halo window; the
    32-tap sum becomes 16 taps of S1 at even byte offsets:
    h_p = sum_{m=0}^{15} S1(p - 2m) << 2m.  Napkin: ~52 VPU ops/byte vs
    the direct kernel's ~85 (taps halve; the one doubling level touches
    the halo window only once)."""
    full = jnp.concatenate([prev_ref[0, :], cur_ref[0, :]])  # [2T] words
    T = cur_ref.shape[1]
    g = []
    for r in range(4):
        byte = (full >> jnp.uint32(8 * r)) & jnp.uint32(0xFF)
        g.append(_mix32(byte + jnp.uint32(1)))
    # depth-1 pair sums on the full window
    s1 = []
    for r in range(4):
        rp = (r - 1) % 4
        a = (1 - r + rp) // 4
        src = jnp.roll(g[rp], a) if a else g[rp]
        s1.append(g[r] + (src << jnp.uint32(1)))
    # 16 taps of S1 at even byte offsets
    for r in range(4):
        h = jnp.zeros((T,), jnp.uint32)
        for m in range(16):
            j = 2 * m
            rp = (r - j) % 4
            a = (j - r + rp) // 4
            src = jax.lax.dynamic_slice(s1[rp], (T - a,), (T,))
            h = h + (src << jnp.uint32(j))
        out_ref[0, r, :] = h


def gear_pallas(strip: jax.Array, interpret: bool = True,
                version: int = 1, tile: int = TILE_W) -> jax.Array:
    """Windowed gear hash of every byte position over B parallel strips.

    strip: [B, tile + W] uint32 packed little-endian bytes, each row with
    ``tile`` leading pad words (history; zeros at stream start) — W data
    words.  Rows are independent streams (the offload engine fuses a
    burst of gear jobs into one launch by stacking them here); the grid
    runs (row, tile) so a single launch covers the whole batch.
    ``tile`` is the BlockSpec width: larger tiles = fewer grid steps
    (VMEM cost 3 * tile words; bounded by the wrapper).
    Returns [B, 4, W] uint32: h for row b's byte position 4q + r at
    [b, r, q].
    """
    B, Wp = strip.shape
    W = Wp - tile
    assert W % tile == 0, (W, tile)
    n_tiles = W // tile
    kernel = {1: _gear_kernel, 2: _gear_kernel_doubling,
              3: _gear_kernel_hybrid}[version]
    out = pl.pallas_call(
        kernel,
        grid=(B, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile), lambda b, i: (b, i)),
            pl.BlockSpec((1, tile), lambda b, i: (b, i + 1)),
        ],
        out_specs=pl.BlockSpec((1, 4, tile), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((B, 4, W), jnp.uint32),
        interpret=interpret,
    )(strip, strip)
    return out
