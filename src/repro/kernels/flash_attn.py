"""Pallas TPU kernel: forward-only flash attention (prefill path).

§Perf B-cell follow-up: after head padding + head-major layout, the 32k
prefill memory term is pure score-tensor traffic (~2·B·H·S²·bytes).  A
flash kernel keeps the S×S scores in VMEM: HBM traffic drops to
Q+K+V+O (O(S·d)), removing the term entirely.

Design (standard online-softmax flash forward, TPU-tiled):
  * grid = (BH, nQ, nK), K-block dim innermost ('arbitrary'): the
    running max m, normalizer l, and unnormalized accumulator acc for
    one (batch·head, q-block) live in the output blocks across K steps
    (the Pallas accumulation pattern — same as the MD5 kernel's digest).
  * causal masking per (q-block, k-block) pair; fully-masked blocks
    short-circuit via pl.when (upper triangle costs control flow only).
  * block sizes (BQ × BK) are VMEM-budget parameters: defaults
    128×512×hd fit comfortably (q 128·hd + kv 2·512·hd + acc 128·hd
    floats ≈ < 1 MB at hd=128).

Forward-only: serving prefill needs no gradients; training keeps the
rematerialized blocked-softmax path (layers.gqa_attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 512
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  bq: int, bk: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: k-block start must not exceed q-block end
    @pl.when(kj * bk <= qi * bq + bq - 1)
    def _work():
        q = q_ref[0, :, :]                                   # [bq, hd]
        k = k_ref[0, :, :]                                   # [bk, hd]
        v = v_ref[0, :, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bk]
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG)

        m_prev = m_ref[0, :]                                 # [bq]
        l_prev = l_ref[0, :]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])                      # [bq, bk]
        correction = jnp.exp(m_prev - m_new)                 # [bq]
        l_new = l_prev * correction + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # [bq, hd]
        o_ref[0, :, :] = o_ref[0, :, :] * correction[:, None] + pv
        m_ref[0, :] = m_new
        l_ref[0, :] = l_new


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        bq: int = BQ, bk: int = BK,
                        interpret: bool = True) -> jax.Array:
    """Causal flash attention forward.

    q: [BH, S, hd]; k, v: [BH, Sk, hd] (GQA pre-broadcast of kv heads is
    the caller's choice — pass q grouped per kv head with repeated k/v
    refs to avoid materializing the broadcast).
    Returns [BH, S, hd] (same dtype as q).
    """
    BH, S, hd = q.shape
    Sk = k.shape[1]
    assert S % bq == 0 and Sk % bk == 0, (S, Sk, bq, bk)
    scale = hd ** -0.5
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale)
    grid = (BH, S // bq, Sk // bk)
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return (o / l[..., None]).astype(q.dtype)
