"""Pallas TPU kernel: sliding-window MD5 (paper-faithful CDC primitive).

HashGPU's content-based-chunking module hashes EVERY overlapping window of
the stream (LBFS-style) — the most compute-intensive primitive in the
paper (7-51 MB/s on a 2008 CPU; the GPU offload wins up to 190x).

TPU adaptation: one VPU lane per window offset.  A window of <= 52 bytes
pads to a single 64-byte MD5 chunk, so each offset costs exactly 64
vectorized rounds.  Overlapping windows cannot be expressed as disjoint
BlockSpec tiles, so the strip is passed TWICE with index maps (i) and
(i+1); the kernel concatenates the two TILE-word blocks and takes the 12
(window/4) shifted slices — the TPU analogue of HashGPU's shared-memory
workspace holding the window neighbourhood.

Byte-granularity offsets (stride 1, as in LBFS/the paper) are handled in
ops.py by hashing 4 byte-rotated word streams — each stream is
word-strided, which keeps every lane's message word-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import MD5_INIT, md5_chunk_update

TILE_W = 512           # window offsets per tile (lane dim)


def _sliding_kernel(cur_ref, nxt_ref, out_ref, *, w_words: int):
    full = jnp.concatenate([cur_ref[0, :], nxt_ref[0, :]])   # [2*TILE]
    T = cur_ref.shape[1]
    M = []
    for jj in range(16):
        if jj < w_words:
            M.append(jax.lax.dynamic_slice(full, (jj,), (T,)))
        elif jj == w_words:
            M.append(jnp.full((T,), 0x80, jnp.uint32))
        elif jj == 14:
            M.append(jnp.full((T,), w_words * 32, jnp.uint32))
        else:
            M.append(jnp.zeros((T,), jnp.uint32))
    a = jnp.full((T,), MD5_INIT[0], jnp.uint32)
    b = jnp.full((T,), MD5_INIT[1], jnp.uint32)
    c = jnp.full((T,), MD5_INIT[2], jnp.uint32)
    d = jnp.full((T,), MD5_INIT[3], jnp.uint32)
    a, b, c, d = md5_chunk_update(a, b, c, d, M)
    out_ref[0, 0, :] = a
    out_ref[0, 1, :] = b
    out_ref[0, 2, :] = c
    out_ref[0, 3, :] = d


def sliding_md5_pallas(strips: jax.Array, w_words: int,
                       interpret: bool = True,
                       tile: int = TILE_W) -> jax.Array:
    """MD5 of every word-offset window over R parallel strips.

    strips: [R, W + TILE_W] uint32 — R independent word streams, each
    padded with TILE_W trailing words; window is ``w_words`` words
    (w_words <= 13 so the padded message is a single chunk).
    Returns [R, 4, W] uint32: digest words for the window starting at each
    word offset of each strip.
    """
    R, Wp = strips.shape
    W = Wp - tile
    assert W % tile == 0, (W, tile)
    assert 0 < w_words <= 13
    n_tiles = W // tile
    kernel = functools.partial(_sliding_kernel, w_words=w_words)
    out = pl.pallas_call(
        kernel,
        grid=(R, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile), lambda r, i: (r, i)),
            pl.BlockSpec((1, tile), lambda r, i: (r, i + 1)),
        ],
        out_specs=pl.BlockSpec((1, 4, tile), lambda r, i: (r, 0, i)),
        out_shape=jax.ShapeDtypeStruct((R, 4, W), jnp.uint32),
        interpret=interpret,
    )(strips, strips)
    return out
