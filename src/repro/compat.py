"""Version-compat shims for JAX APIs that moved between releases.

The ``jax.tree`` namespace (``jax.tree.map`` etc.) was introduced in
newer JAX releases, and individual functions landed at different
versions — e.g. ``jax.tree.flatten_with_path`` is missing from installs
that already have ``jax.tree.map``.  Every function here prefers the
``jax.tree`` spelling and falls back to the long-stable
``jax.tree_util.tree_*`` equivalent, so models/train/serve code runs
unmodified across the JAX versions we see in CI and dev machines.
"""
from __future__ import annotations

import jax
import jax.tree_util as _tu


def _resolve(name: str):
    tree_ns = getattr(jax, "tree", None)
    fn = getattr(tree_ns, name, None) if tree_ns is not None else None
    if fn is not None:
        return fn
    return getattr(_tu, "tree_" + name)


tree_map = _resolve("map")
tree_leaves = _resolve("leaves")
tree_flatten = _resolve("flatten")
tree_unflatten = _resolve("unflatten")
tree_structure = _resolve("structure")
tree_flatten_with_path = _resolve("flatten_with_path")


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` returns a dict in newer JAX releases
    and a per-device list of dicts in older ones; normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
