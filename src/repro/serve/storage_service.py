"""Multi-tenant storage gateway: the serving front end for the store.

The paper evaluates its GPU-offloaded storage prototype under competing
concurrent applications (§V, Figures 12-17) and argues the offload layer
can be shared transparently.  This module is the serving subsystem that
makes that sharing real for *many clients of the storage system itself*:
instead of every client owning an :class:`repro.core.sai.SAI`, clients
open sessions against one :class:`StorageGateway` and submit framed
``write`` / ``read`` / ``delete`` / ``stat`` requests.

Layering:

  wire codec        — every request/response crosses the transport as a
                      framed byte string (``encode_request`` /
                      ``decode_response`` ...).  The bundled transport is
                      in-process (``GatewayChannel.request(frame) ->
                      ReplyFuture``), but the contract is exactly what a
                      socket transport would implement, so one is a
                      drop-in follow-up.
  admission control — per-tenant outstanding-request and queued-byte
                      budgets.  Over budget => an ``ST_RETRY`` response
                      (client-side :class:`~repro.serve.storage_client.
                      RetryLater`) instead of unbounded queueing: a
                      flooding tenant gets backpressure, not a growing
                      queue.
  fair-share        — weighted deficit round-robin over per-tenant
    scheduler         queues: each round a tenant's deficit grows by
                      ``quantum_bytes * weight`` and it may dispatch
                      requests whose byte cost fits the deficit, so
                      equal-weight tenants get equal *bytes* of service
                      regardless of how unequal their offered load is.
                      ``max_inflight`` bounds per-tenant dispatched
                      concurrency so the scheduler — not arrival order —
                      decides who runs next.
  cross-client      — every tenant's SAI shares the gateway's offload
    coalescing        engine, so hash requests from *different clients*
                      fuse into common batch launches.  The signature is
                      ``engine launches < total client requests`` for a
                      concurrent burst (``snapshot_stats()['launches'] <
                      ...['jobs']``) — the ROADMAP's "cross-process
                      (serve-side) coalescing" open item.
  QoS classes       — ``interactive`` / ``batch`` / ``scrub`` map onto
                      the engine's priority lanes (``fg`` > ``batch`` >
                      ``scrub``), so a batch tenant's hashing yields to
                      interactive tenants at the device queue too.
  gateway-owned     — ``GatewayConfig(scrub=True)`` makes the gateway
    cluster runtime   own a :class:`repro.core.noderuntime.
                      ClusterRuntime` (integrity scrubbing, repair, GC)
                      on the same engine, started and stopped with the
                      gateway.

``snapshot_stats()`` publishes per-tenant throughput/queue/rejection
counters plus the engine's fused-launch counters; the
``benchmarks/gateway_saturation.py`` run consumes it.
"""
from __future__ import annotations

import dataclasses
import json
import math
import queue
import struct
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.core import crystal as crystal_mod
from repro.core.castore import MetadataManager, open_durable_store
from repro.core.crystal import CrystalTPU
from repro.core.noderuntime import ClusterRuntime, NodeRuntimeConfig
from repro.core.sai import SAI, SAIConfig
from repro.obs import (HealthConfig, HealthEngine, HealthHTTPServer,
                       HeartbeatBoard, MetricsRegistry, MetricsSampler,
                       Trace, Tracer, truncate_tree)
from repro.serve.auth import AuthError, TokenAuthenticator

# ----------------------------------------------------------------------
# wire-format codec: framed requests/responses (transport-independent)
# ----------------------------------------------------------------------
(OP_OPEN, OP_WRITE, OP_READ, OP_DELETE, OP_STAT, OP_CLOSE, OP_STATS,
 OP_HEALTH) = range(8)
ST_OK, ST_RETRY, ST_ERROR = range(3)

# Default cap on a single codec frame.  The socket transport refuses to
# allocate a receive buffer past this from a wire length prefix, and
# ``decode_request`` enforces it again at the codec layer so a hostile
# peer can't push an oversized frame through any transport.
MAX_FRAME_BYTES = 64 << 20

OP_NAMES = {OP_OPEN: "open", OP_WRITE: "write", OP_READ: "read",
            OP_DELETE: "delete", OP_STAT: "stat", OP_CLOSE: "close",
            OP_STATS: "stats", OP_HEALTH: "health"}

# QoS class -> engine priority lane (repro.core.crystal.LANES order)
QOS_LANES = {"interactive": "fg", "batch": "batch", "scrub": "scrub"}

# Every request header carries a trace id (0 = untraced): clients mint
# one per request and the gateway records per-stage spans against it.
_REQ_HDR = struct.Struct("!BIQQ")      # op, session, rid, trace
_RSP_HDR = struct.Struct("!BBQ")       # status, op, rid
_U16 = struct.Struct("!H")
_U32 = struct.Struct("!I")
_I32 = struct.Struct("!i")
_U64 = struct.Struct("!Q")
_F64 = struct.Struct("!d")


class CodecError(ValueError):
    pass


def _pack_str(s: str) -> bytes:
    b = s.encode("utf-8")
    if len(b) > 0xFFFF:
        raise CodecError("string field too long")
    return _U16.pack(len(b)) + b


def _pack_bytes(data) -> bytes:
    # the length check runs BEFORE struct packs it: data >= 4 GiB must
    # raise CodecError, not leak struct.error out of the codec
    if len(data) > 0xFFFFFFFF:
        raise CodecError(
            f"payload too large for u32 length ({len(data)} bytes)")
    return _U32.pack(len(data)) + data


def _pack_bytes16(data: bytes) -> bytes:
    if len(data) > 0xFFFF:
        raise CodecError(f"short byte field too long ({len(data)})")
    return _U16.pack(len(data)) + data


def _take_bytes16(buf: bytes, off: int) -> Tuple[bytes, int]:
    (n,), off = _take(buf, off, _U16)
    if off + n > len(buf):
        raise CodecError("truncated short byte field")
    return bytes(buf[off:off + n]), off + n


def _take(buf: bytes, off: int, st: struct.Struct):
    if off + st.size > len(buf):
        raise CodecError("truncated frame")
    return st.unpack_from(buf, off), off + st.size


def _take_str(buf: bytes, off: int) -> Tuple[str, int]:
    (n,), off = _take(buf, off, _U16)
    if off + n > len(buf):
        raise CodecError("truncated string")
    try:
        s = buf[off:off + n].decode("utf-8")
    except UnicodeDecodeError as e:
        # wire bytes are untrusted: decode failures are codec errors,
        # same contract as truncation
        raise CodecError(f"invalid utf-8 in string field: {e}") from None
    return s, off + n


def _take_bytes(buf: bytes, off: int) -> Tuple[bytes, int]:
    (n,), off = _take(buf, off, _U32)
    if off + n > len(buf):
        raise CodecError("truncated payload")
    return bytes(buf[off:off + n]), off + n


def encode_request(op: int, session: int, rid: int, **f: Any) -> bytes:
    head = _REQ_HDR.pack(op, session, rid, int(f.get("trace", 0)))
    if op == OP_OPEN:
        return head + _pack_str(f["tenant"]) + _pack_str(f["qos"]) \
            + _F64.pack(float(f.get("weight", 1.0))) \
            + _pack_bytes16(f.get("token", b""))
    if op == OP_WRITE:
        return head + _pack_str(f["path"]) + _pack_bytes(f["data"])
    if op == OP_READ:
        return head + _pack_str(f["path"]) \
            + _I32.pack(int(f.get("version", -1))) \
            + struct.pack("!B", 1 if f.get("verify", True) else 0)
    if op in (OP_DELETE, OP_STAT):
        return head + _pack_str(f["path"])
    if op in (OP_CLOSE, OP_STATS, OP_HEALTH):
        return head
    raise CodecError(f"unknown opcode {op}")


def decode_request(frame: bytes,
                   max_frame_bytes: Optional[int] = MAX_FRAME_BYTES):
    """-> (op, session, rid, fields).

    ``max_frame_bytes`` bounds the whole frame (pass ``None`` to
    disable): the socket transport already refuses oversized length
    prefixes, but enforcing the cap here too means no transport can
    hand the gateway an unbounded buffer."""
    if max_frame_bytes is not None and len(frame) > max_frame_bytes:
        raise CodecError(
            f"frame of {len(frame)} bytes exceeds max_frame_bytes "
            f"({max_frame_bytes})")
    (op, session, rid, trace), off = _take(frame, 0, _REQ_HDR)
    f: Dict[str, Any] = {}
    if trace:
        # omitted when 0 so encode(**decode(frame)) round-trips for
        # untraced frames
        f["trace"] = trace
    if op == OP_OPEN:
        f["tenant"], off = _take_str(frame, off)
        f["qos"], off = _take_str(frame, off)
        (f["weight"],), off = _take(frame, off, _F64)
        f["token"], off = _take_bytes16(frame, off)
    elif op == OP_WRITE:
        f["path"], off = _take_str(frame, off)
        f["data"], off = _take_bytes(frame, off)
    elif op == OP_READ:
        f["path"], off = _take_str(frame, off)
        (f["version"],), off = _take(frame, off, _I32)
        (v,), off = _take(frame, off, struct.Struct("!B"))
        f["verify"] = bool(v)
    elif op in (OP_DELETE, OP_STAT):
        f["path"], off = _take_str(frame, off)
    elif op in (OP_CLOSE, OP_STATS, OP_HEALTH):
        pass
    else:
        raise CodecError(f"unknown opcode {op}")
    if off != len(frame):
        raise CodecError("trailing bytes in request frame")
    return op, session, rid, f


def encode_response(status: int, op: int, rid: int, **f: Any) -> bytes:
    head = _RSP_HDR.pack(status, op, rid)
    if status == ST_RETRY:
        return head + _pack_str(f.get("reason", "over budget"))
    if status == ST_ERROR:
        return head + _pack_str(f["errtype"]) + _pack_str(f.get("msg", ""))
    if op == OP_OPEN:
        return head + _U32.pack(f["session"])
    if op == OP_WRITE:
        return head + _U64.pack(f["total_bytes"]) \
            + _U64.pack(f["new_bytes"]) + _U32.pack(f["new_blocks"]) \
            + _U32.pack(f["dup_blocks"])
    if op == OP_READ:
        return head + _pack_bytes(f["data"])
    if op == OP_DELETE:
        return head + _U32.pack(f["orphans"])
    if op == OP_STAT:
        return head + _U32.pack(f["versions"]) + _U64.pack(f["total_len"]) \
            + _U32.pack(f["blocks"])
    if op in (OP_STATS, OP_HEALTH):
        # JSON snapshot/report rides as an opaque length-prefixed payload
        return head + _pack_bytes(f["data"])
    if op == OP_CLOSE:
        return head
    raise CodecError(f"unknown opcode {op}")


def decode_response(frame: bytes):
    """-> (status, op, rid, fields)."""
    (status, op, rid), off = _take(frame, 0, _RSP_HDR)
    f: Dict[str, Any] = {}
    if status == ST_RETRY:
        f["reason"], off = _take_str(frame, off)
    elif status == ST_ERROR:
        f["errtype"], off = _take_str(frame, off)
        f["msg"], off = _take_str(frame, off)
    elif op == OP_OPEN:
        (f["session"],), off = _take(frame, off, _U32)
    elif op == OP_WRITE:
        (f["total_bytes"],), off = _take(frame, off, _U64)
        (f["new_bytes"],), off = _take(frame, off, _U64)
        (f["new_blocks"],), off = _take(frame, off, _U32)
        (f["dup_blocks"],), off = _take(frame, off, _U32)
    elif op == OP_READ:
        f["data"], off = _take_bytes(frame, off)
    elif op == OP_DELETE:
        (f["orphans"],), off = _take(frame, off, _U32)
    elif op == OP_STAT:
        (f["versions"],), off = _take(frame, off, _U32)
        (f["total_len"],), off = _take(frame, off, _U64)
        (f["blocks"],), off = _take(frame, off, _U32)
    elif op in (OP_STATS, OP_HEALTH):
        f["data"], off = _take_bytes(frame, off)
    elif op == OP_CLOSE:
        pass
    else:
        raise CodecError(f"unknown opcode {op}")
    if off != len(frame):
        raise CodecError("trailing bytes in response frame")
    return status, op, rid, f


# ----------------------------------------------------------------------
# transport
# ----------------------------------------------------------------------
class ReplyFuture:
    """Resolves to a raw response frame (bytes)."""

    def __init__(self):
        self._done = threading.Event()
        self._frame: Optional[bytes] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> bytes:
        if not self._done.wait(timeout):
            raise TimeoutError("gateway reply still in flight")
        return self._frame

    def _resolve(self, frame: bytes):
        self._frame = frame
        self._done.set()


class GatewayChannel:
    """In-process client endpoint: ``request`` takes a request frame and
    returns a :class:`ReplyFuture` resolving to a response frame — the
    exact contract a socket transport would implement, so the framed
    codec is exercised end-to-end even in-process."""

    def __init__(self, gateway: "StorageGateway"):
        self._gateway = gateway

    def request(self, frame: bytes) -> ReplyFuture:
        # owner=None: in-process callers are trusted and share one
        # session namespace (sessions bound by OP_OPEN, not channels)
        return self._gateway.handle_frame(frame)

    def close(self):
        """No connection to tear down in-process; present so clients
        can close any channel (socket or not) uniformly."""


# ----------------------------------------------------------------------
# gateway
# ----------------------------------------------------------------------
@dataclasses.dataclass
class GatewayConfig:
    quantum_bytes: int = 256 << 10    # WDRR service quantum per weight
    max_inflight: int = 4             # per-tenant dispatched concurrency
    max_outstanding: int = 32         # per-tenant inflight + queued cap
    max_queued_bytes: int = 8 << 20   # per-tenant queued byte budget
    sai: Optional[SAIConfig] = None   # per-tenant SAI template (lane is
    #                                   overridden by the tenant's QoS)
    scrub: bool = False               # own + run a ClusterRuntime
    runtime: Optional[NodeRuntimeConfig] = None
    idle_poll_s: float = 0.05         # scheduler idle wakeup
    auth: Optional[TokenAuthenticator] = None  # None = trusted (e.g.
    #                                   in-process); set => OP_OPEN must
    #                                   carry a valid signed token and
    #                                   the session binds to the token's
    #                                   tenant, not the claimed name
    max_frame_bytes: int = MAX_FRAME_BYTES
    adaptive_fusion: bool = True      # when the gateway resolves the
    #                                   process-default engine itself,
    #                                   turn measured fusion-cap tuning
    #                                   on (an explicitly passed engine
    #                                   is never touched — its owner
    #                                   decides)
    data_dir: Optional[str] = None    # durable mode: open a WAL-backed
    #                                   store here instead of taking a
    #                                   caller-owned manager; the
    #                                   gateway owns its lifecycle
    #                                   (recovery at start, close on
    #                                   shutdown) and hands recovery
    #                                   suspects to the scrub runtime
    n_nodes: int = 4                  # durable-mode store shape
    replication: int = 1
    trace_ring: int = 256             # completed-trace ring capacity
    slow_request_s: float = 1.0       # traces at/over this land in the
    #                                   slow-request log with full span
    #                                   trees
    health: bool = False              # run the continuous health plane
    #                                   (background MetricsSampler +
    #                                   HealthEngine re-evaluated every
    #                                   tick); OP_HEALTH works without it
    #                                   by sampling on demand
    metrics_port: Optional[int] = None  # HTTP scrape endpoint serving
    #                                   /metrics, /health, /slowlog on
    #                                   127.0.0.1 (0 = ephemeral port,
    #                                   exposed as gateway.http.port);
    #                                   setting it implies health=True
    sample_interval_s: float = 0.25   # sampler tick
    sample_capacity: int = 240        # sampler ring entries
    sample_window_s: float = 5.0      # rate/delta lookback window
    health_config: Optional[HealthConfig] = None  # verdict rule knobs


@dataclasses.dataclass
class _Work:
    op: int
    rid: int
    fields: Dict[str, Any]
    cost: int
    reply: ReplyFuture
    trace: Optional[Trace] = None
    t_admit: float = 0.0


class _Tenant:
    def __init__(self, name: str, weight: float, qos: str, sai: SAI,
                 registry: MetricsRegistry):
        self.name = name
        self.weight = max(float(weight), 1e-6)
        self.qos = qos
        self.sai = sai
        self.queue: Deque[_Work] = deque()
        self.queued_bytes = 0
        self.inflight = 0
        self.deficit = 0.0
        self.completion_q: "queue.Queue" = queue.Queue()
        self.completer: Optional[threading.Thread] = None
        # atomic counters (completer/scheduler/handler threads all
        # bump); still reads like the old plain dict
        self.stats = registry.group(
            ("submitted", "completed", "rejected", "errors",
             "bytes_in", "bytes_out"), prefix=f"tenant/{name}/")


class StorageGateway:
    """Fronts one :class:`MetadataManager` + shared offload engine for
    many concurrent client sessions (see module docstring).

    Sessions are opened by an ``OP_OPEN`` frame naming a tenant, weight,
    and QoS class; any number of sessions may join the same tenant (its
    weight/QoS are fixed by the first open).  Each tenant gets its own
    :class:`SAI` — its ``write_async`` / ``read_async`` pipelines are
    reused verbatim — but every SAI shares the gateway's engine, which
    is what fuses different clients' hash bursts into common launches.
    """

    def __init__(self, manager: Optional[MetadataManager] = None,
                 engine: Optional[CrystalTPU] = None,
                 config: Optional[GatewayConfig] = None):
        self.cfg = config or GatewayConfig()
        self.recovery_report = None
        self._owns_store = False
        if manager is None:
            if self.cfg.data_dir is None:
                raise ValueError(
                    "StorageGateway needs a manager or "
                    "GatewayConfig(data_dir=...)")
            manager, _, self.recovery_report = open_durable_store(
                self.cfg.data_dir, n_nodes=self.cfg.n_nodes,
                replication=self.cfg.replication)
            self._owns_store = True
        elif self.cfg.data_dir is not None:
            raise ValueError("pass a manager OR data_dir, not both")
        self.manager = manager
        self._engine = engine

        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._tenants: Dict[str, _Tenant] = {}  # guarded by self._cv
        self._order: List[_Tenant] = []  # WDRR visit order; guarded by self._cv
        # session id -> (tenant, owner).  ``owner`` is the opaque
        # transport identity that opened the session (the socket
        # connection object; None for trusted in-process callers) —
        # every later frame must come from the SAME owner, so a TCP
        # client can't act on a session id it merely guessed.
        self._sessions: Dict[int, Tuple[_Tenant, Any]] = {}  # guarded by self._cv
        self._next_session = 1  # guarded by self._cv
        self._rr = 0  # guarded by self._cv
        self._closed = False  # guarded by self._cv
        self._stop = threading.Event()
        self.metrics = MetricsRegistry()
        self.stats = self.metrics.group(
            ("frames", "dispatched", "admission_rejections",
             "stats_truncated"))
        self.tracer = Tracer(capacity=self.cfg.trace_ring,
                             slow_threshold_s=self.cfg.slow_request_s)
        # request latency (admission -> reply) per data verb, plus WDRR
        # queue wait (admission -> dispatch)
        self._hist_write = self.metrics.histogram("request_s/write")
        self._hist_read = self.metrics.histogram("request_s/read")
        self._hist_queue = self.metrics.histogram("queue_wait_s")
        # per-QoS-class latency (raw buckets ride the snapshot so the
        # health plane can compute windowed SLO violation rates)
        self._hist_qos = {q: self.metrics.histogram(f"qos_s/{q}")
                          for q in QOS_LANES}
        self.metrics.gauge(
            "sessions",
            # ra: disable=RA01(len() on a dict is atomic in CPython; advisory gauge)
            fn=lambda: len(self._sessions))
        self.heartbeats = HeartbeatBoard()
        self.runtime: Optional[ClusterRuntime] = None
        if self.cfg.scrub:
            self.runtime = ClusterRuntime(manager, engine=self.engine,
                                          config=self.cfg.runtime)
            if self.recovery_report is not None \
                    and self.recovery_report.suspects:
                # recovery IS a scrub workload: engine-verify the
                # trailing blocks the crash left unproven before
                # background sweeps resume
                self.runtime.scrub_suspects(self.recovery_report.suspects)
            self.runtime.start()
        self._scheduler = threading.Thread(target=self._scheduler_loop,
                                           daemon=True,
                                           name="gateway-sched")
        self._scheduler.start()
        # continuous health plane: the sampler snapshots the BASE tree
        # (no timeseries/health blocks — those derive from the ring, so
        # sampling the full tree would be self-referential), the health
        # engine re-evaluates after every tick, and the optional HTTP
        # endpoint serves scrapes without a wire session
        self.sampler = MetricsSampler(
            self._base_stats, interval_s=self.cfg.sample_interval_s,
            capacity=self.cfg.sample_capacity,
            window_s=self.cfg.sample_window_s)
        self.health = HealthEngine(self.sampler,
                                   self.cfg.health_config)
        self.http: Optional[HealthHTTPServer] = None
        if self.cfg.health or self.cfg.metrics_port is not None:
            self.sampler.add_listener(self.health.evaluate)
            self.sampler.start()
        if self.cfg.metrics_port is not None:
            self.http = HealthHTTPServer(
                stats_fn=self.snapshot_stats,
                health_fn=self.health_report,
                slowlog_fn=self.tracer.slow_entries,
                port=self.cfg.metrics_port)

    # -- plumbing ------------------------------------------------------
    @property
    def engine(self) -> CrystalTPU:
        """The engine every tenant SAI shares.  Resolved to the
        process-wide default only when none was supplied; a dead engine
        is NOT silently replaced — existing tenants hold it, and a new
        one would split coalescing (and stats) across two engines.
        Submitting to a shut-down engine fails loudly instead."""
        if self._engine is None:
            self._engine = crystal_mod.default_engine()
            if self.cfg.adaptive_fusion:
                # gateway default (ROADMAP item 3 follow-on): measured
                # fusion caps on for the shared engine we resolved
                self._engine.policy.adaptive = True
        return self._engine

    def connect(self) -> GatewayChannel:
        """Open a transport endpoint (the in-process analog of a TCP
        connect; sessions are bound by OP_OPEN frames, not channels)."""
        return GatewayChannel(self)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- frame entry point ---------------------------------------------
    def handle_frame(self, frame: bytes,
                     owner: Any = None) -> ReplyFuture:
        """Serve one request frame.  ``owner`` is the transport identity
        the frame arrived on (the socket transport passes its connection
        object; in-process callers pass nothing).  Sessions are bound to
        the owner that opened them — frames naming another owner's
        session are answered exactly like an unknown session, so session
        ids carry no authority across connections."""
        t_rx = time.perf_counter()
        reply = ReplyFuture()
        try:
            op, session, rid, f = decode_request(
                frame, max_frame_bytes=self.cfg.max_frame_bytes)
        except Exception as e:
            # salvage op/rid from the fixed header when present: over a
            # socket the rid is the reply routing key, and a rid=0 error
            # would be undeliverable — the client would time out instead
            # of seeing the CodecError
            op = rid = 0
            if len(frame) >= _REQ_HDR.size:
                op, _session, rid, _trace = _REQ_HDR.unpack_from(frame)
            reply._resolve(encode_response(ST_ERROR, op, rid,
                                           errtype="CodecError",
                                           msg=str(e)))
            return reply
        # (trace_id, rx timestamp, decoded timestamp): becomes the
        # request's root Trace if it survives admission
        trace_id = f.pop("trace", 0)
        tctx = (trace_id, t_rx, time.perf_counter()) if trace_id else None
        try:
            self._handle(op, session, rid, f, reply, owner, tctx)
        except BaseException as e:
            reply._resolve(encode_response(ST_ERROR, op, rid,
                                           errtype=type(e).__name__,
                                           msg=str(e)))
        return reply

    def _handle(self, op: int, session: int, rid: int,
                f: Dict[str, Any], reply: ReplyFuture, owner: Any,
                tctx: Optional[Tuple[int, float, float]] = None):
        self.stats.inc("frames")
        if op == OP_OPEN:
            return self._open_session(rid, f, reply, owner)
        with self._cv:
            entry = self._sessions.get(session)
        # a foreign-owner session gets the SAME reply as a nonexistent
        # one: a probing connection learns nothing about which small
        # integer ids happen to be other clients' live sessions
        if entry is None or entry[1] is not owner:
            reply._resolve(encode_response(
                ST_ERROR, op, rid, errtype="UnknownSession",
                msg=f"session {session} is not open"))
            return
        tenant = entry[0]
        if op == OP_CLOSE:
            with self._cv:
                self._sessions.pop(session, None)
            reply._resolve(encode_response(ST_OK, OP_CLOSE, rid))
            return
        if op == OP_STAT:
            return self._stat(tenant, rid, f, reply)
        if op == OP_STATS:
            return self._stats_op(tenant, rid, reply)
        if op == OP_HEALTH:
            return self._health_op(tenant, rid, reply)
        if op == OP_DELETE:
            return self._delete(tenant, rid, f, reply)
        if op in (OP_WRITE, OP_READ):
            return self._admit(tenant, op, rid, f, reply, tctx)
        reply._resolve(encode_response(ST_ERROR, op, rid,
                                       errtype="CodecError",
                                       msg=f"unhandled opcode {op}"))

    def _open_session(self, rid: int, f: Dict[str, Any],
                      reply: ReplyFuture, owner: Any):
        if self.cfg.auth is not None:
            # authenticate BEFORE anything else: the session's tenant is
            # whatever the verified token says, never the claimed field
            try:
                f["tenant"] = self.cfg.auth.verify(
                    f.get("token", b""), claimed=f["tenant"])
            except AuthError as e:
                reply._resolve(encode_response(
                    ST_ERROR, OP_OPEN, rid, errtype="AuthError",
                    msg=str(e)))
                return
        qos = f["qos"]
        if qos not in QOS_LANES:
            reply._resolve(encode_response(
                ST_ERROR, OP_OPEN, rid, errtype="ValueError",
                msg=f"unknown qos {qos!r}"))
            return
        weight = f["weight"]
        # a wire frame can carry weight=0, negative, or NaN; any of
        # those zeroes (or poisons) quantum_bytes * weight and the
        # tenant's WDRR deficit never grows — it would starve forever
        if not math.isfinite(weight) or weight <= 0.0:
            reply._resolve(encode_response(
                ST_ERROR, OP_OPEN, rid, errtype="ValueError",
                msg=f"tenant weight must be finite and > 0, "
                    f"got {weight!r}"))
            return
        with self._cv:
            if self._closed:
                reply._resolve(encode_response(
                    ST_ERROR, OP_OPEN, rid, errtype="RuntimeError",
                    msg="gateway is closed"))
                return
            tenant = self._tenants.get(f["tenant"])
            if tenant is None:
                sai_cfg = dataclasses.replace(
                    self.cfg.sai or SAIConfig(), lane=QOS_LANES[qos])
                tenant = _Tenant(f["tenant"], f["weight"], qos,
                                 SAI(self.manager, sai_cfg,
                                     crystal=self.engine),
                                 self.metrics)
                tenant.completer = threading.Thread(
                    target=self._completer_loop, args=(tenant,),
                    daemon=True, name=f"gateway-done-{tenant.name}")
                tenant.completer.start()
                self._tenants[tenant.name] = tenant
                self._order.append(tenant)
            sid = self._next_session
            self._next_session += 1
            self._sessions[sid] = (tenant, owner)
        reply._resolve(encode_response(ST_OK, OP_OPEN, rid, session=sid))

    def drop_sessions(self, owner: Any) -> int:
        """Close every session bound to ``owner`` (a disconnecting
        transport connection): its ids must not stay live — or leak —
        after the connection that authenticated them is gone.  Returns
        the number dropped.  In-flight work already dispatched for the
        tenant completes normally."""
        with self._cv:
            dead = [sid for sid, (_t, own) in self._sessions.items()
                    if own is owner]
            for sid in dead:
                del self._sessions[sid]
        return len(dead)

    # -- metadata ops (cheap: served inline, no queueing) --------------
    def _stat(self, tenant: _Tenant, rid: int, f: Dict[str, Any],
              reply: ReplyFuture):
        st = self.manager.stat_file(f["path"])
        if st is None:
            reply._resolve(encode_response(
                ST_ERROR, OP_STAT, rid, errtype="FileNotFoundError",
                msg=f["path"]))
            return
        tenant.stats.inc("submitted")
        tenant.stats.inc("completed")
        reply._resolve(encode_response(ST_OK, OP_STAT, rid, **st))

    def _bounded_json(self, tree: Dict[str, Any]) -> bytes:
        """Serialize a stats/health tree, truncating it (deepest
        subtrees first) when the JSON would overflow the response frame
        cap — an overgrown tree must degrade, not kill the connection
        with an undecodable oversized frame."""
        payload = json.dumps(tree, sort_keys=True).encode("utf-8")
        # headroom for the response header + payload length prefix
        budget = max(1024, self.cfg.max_frame_bytes - 256)
        if len(payload) > budget:
            tree, _dropped = truncate_tree(tree, budget)
            self.stats.inc("stats_truncated")
            payload = json.dumps(tree, sort_keys=True).encode("utf-8")
        return payload

    def _stats_op(self, tenant: _Tenant, rid: int, reply: ReplyFuture):
        """OP_STATS admin verb: the live ``snapshot_stats()`` tree as a
        JSON payload.  Session-gated like every non-OPEN op, so with
        ``GatewayConfig(auth=...)`` set it requires an authenticated
        session."""
        tenant.stats.inc("submitted")
        payload = self._bounded_json(self.snapshot_stats())
        tenant.stats.inc("completed")
        reply._resolve(encode_response(ST_OK, OP_STATS, rid,
                                       data=payload))

    def _health_op(self, tenant: _Tenant, rid: int, reply: ReplyFuture):
        """OP_HEALTH admin verb: the health report as a JSON payload
        (same shape the ``/health`` HTTP route serves), session-gated
        like OP_STATS."""
        tenant.stats.inc("submitted")
        payload = self._bounded_json(self.health_report())
        tenant.stats.inc("completed")
        reply._resolve(encode_response(ST_OK, OP_HEALTH, rid,
                                       data=payload))

    def _delete(self, tenant: _Tenant, rid: int, f: Dict[str, Any],
                reply: ReplyFuture):
        orphans = self.manager.delete_file(f["path"])
        tenant.stats.inc("submitted")
        tenant.stats.inc("completed")
        reply._resolve(encode_response(ST_OK, OP_DELETE, rid,
                                       orphans=len(orphans)))

    # -- admission control ---------------------------------------------
    def _cost_of(self, op: int, f: Dict[str, Any]) -> int:
        if op == OP_WRITE:
            return max(len(f["data"]), 1)
        st = self.manager.stat_file(f["path"], f.get("version", -1))
        return max(st["total_len"], 1) if st else 1

    def _admit(self, tenant: _Tenant, op: int, rid: int,
               f: Dict[str, Any], reply: ReplyFuture,
               tctx: Optional[Tuple[int, float, float]] = None):
        cost = self._cost_of(op, f)
        cfg = self.cfg
        with self._cv:
            if self._closed:
                reply._resolve(encode_response(
                    ST_RETRY, op, rid, reason="gateway closing"))
                return
            outstanding = tenant.inflight + len(tenant.queue)
            # an oversized request is admissible when the tenant queue
            # is empty (it can always make progress alone); otherwise
            # the byte budget bounds queue growth
            over_bytes = tenant.queue and \
                tenant.queued_bytes + cost > cfg.max_queued_bytes
            if outstanding >= cfg.max_outstanding or over_bytes:
                tenant.stats.inc("rejected")
                self.stats.inc("admission_rejections")
                reply._resolve(encode_response(
                    ST_RETRY, op, rid,
                    reason=f"tenant {tenant.name} over budget "
                           f"({outstanding} outstanding, "
                           f"{tenant.queued_bytes} B queued)"))
                return
            trace = None
            if tctx is not None:
                # root spans from frame arrival so every child span
                # nests inside [trace.t0, trace.t1]
                trace = self.tracer.start(tctx[0], OP_NAMES[op],
                                          t0=tctx[1],
                                          tenant=tenant.name)
                trace.add_span("transport/decode", tctx[1], tctx[2])
            tenant.queue.append(_Work(op, rid, f, cost, reply,
                                      trace=trace,
                                      t_admit=time.perf_counter()))
            tenant.queued_bytes += cost
            tenant.stats.inc("submitted")
            self._cv.notify_all()

    # -- fair-share scheduler (weighted deficit round-robin) -----------
    def _eligible_locked(self) -> bool:
        return any(t.queue and t.inflight < self.cfg.max_inflight
                   for t in self._order)

    def _drained_locked(self) -> bool:
        return all(not t.queue and t.inflight == 0 for t in self._order)

    def _pick_locked(self) -> List[Tuple[_Tenant, _Work]]:
        """One WDRR round: visit every tenant once in rotating order,
        top its deficit up by ``quantum_bytes * weight``, and dispatch
        head-of-queue requests while their byte cost fits the deficit
        (and the tenant's inflight cap allows).  Idle tenants' deficits
        reset so service credit never accumulates while unused."""
        cfg = self.cfg
        picks: List[Tuple[_Tenant, _Work]] = []
        n = len(self._order)
        for k in range(n):
            t = self._order[(self._rr + k) % n]
            if not t.queue:
                t.deficit = 0.0
                continue
            if t.inflight >= cfg.max_inflight:
                continue
            t.deficit += cfg.quantum_bytes * t.weight
            while (t.queue and t.inflight < cfg.max_inflight
                   and t.queue[0].cost <= t.deficit):
                w = t.queue.popleft()
                t.deficit -= w.cost
                t.queued_bytes -= w.cost
                t.inflight += 1
                picks.append((t, w))
            if not t.queue:
                t.deficit = 0.0
        if n:
            self._rr = (self._rr + 1) % n
        self.stats.inc("dispatched", len(picks))
        return picks

    def _scheduler_loop(self):
        hb = self.heartbeats.heartbeat("scheduler")
        try:
            while True:
                hb.beat()
                with self._cv:
                    while not self._stop.is_set() \
                            and not self._eligible_locked():
                        hb.beat()   # idle polls are forward progress
                        self._cv.wait(self.cfg.idle_poll_s)
                    if self._stop.is_set() \
                            and not self._eligible_locked():
                        return
                    picks = self._pick_locked()
                for tenant, work in picks:
                    self._dispatch(tenant, work)
        finally:
            hb.park()

    def _dispatch(self, tenant: _Tenant, work: _Work):
        now = time.perf_counter()
        self._hist_queue.record(now - work.t_admit)
        if work.trace is not None:
            work.trace.add_span("gateway/queue", work.t_admit, now,
                                tenant=tenant.name)
        try:
            if work.op == OP_WRITE:
                fut = tenant.sai.write_async(work.fields["path"],
                                             work.fields["data"],
                                             trace=work.trace)
            else:
                fut = tenant.sai.read_async(work.fields["path"],
                                            work.fields["version"],
                                            work.fields["verify"],
                                            trace=work.trace)
        except BaseException as e:
            self._finish(tenant, work, encode_response(
                ST_ERROR, work.op, work.rid, errtype=type(e).__name__,
                msg=str(e)), error=True)
            return
        tenant.completion_q.put((work, fut))

    # -- completion ----------------------------------------------------
    def _completer_loop(self, tenant: _Tenant):
        """Per-tenant completion drain: waits dispatch-order futures and
        frames the responses.  Per-tenant (not gateway-wide) so one
        tenant's slow read never head-of-line blocks another tenant's
        finished requests."""
        hb = self.heartbeats.heartbeat(f"completer_{tenant.name}")
        while True:
            hb.park()                # idle until the next completion
            item = tenant.completion_q.get()
            if item is None:
                return               # heartbeat stays parked
            hb.beat()
            work, fut = item
            nbytes = {}
            try:
                res = fut.result(timeout=600)
                if work.op == OP_WRITE:
                    frame = encode_response(
                        ST_OK, OP_WRITE, work.rid,
                        total_bytes=res.total_bytes,
                        new_bytes=res.new_bytes,
                        new_blocks=res.new_blocks,
                        dup_blocks=res.dup_blocks)
                    nbytes["bytes_in"] = res.total_bytes
                else:
                    frame = encode_response(ST_OK, OP_READ, work.rid,
                                            data=res)
                    nbytes["bytes_out"] = len(res)
                self._finish(tenant, work, frame, **nbytes)
            except BaseException as e:
                self._finish(tenant, work, encode_response(
                    ST_ERROR, work.op, work.rid,
                    errtype=type(e).__name__, msg=str(e)), error=True)

    def _finish(self, tenant: _Tenant, work: _Work, frame: bytes,
                error: bool = False, **nbytes: int):
        now = time.perf_counter()
        hist = self._hist_write if work.op == OP_WRITE else self._hist_read
        hist.record(now - work.t_admit)
        self._hist_qos[tenant.qos].record(now - work.t_admit)
        if work.trace is not None:
            work.trace.meta["error"] = bool(error)
            self.tracer.finish(work.trace, now)
        work.reply._resolve(frame)
        tenant.stats.inc("errors" if error else "completed")
        for k, v in nbytes.items():
            tenant.stats.inc(k, v)
        with self._cv:
            tenant.inflight -= 1
            self._cv.notify_all()

    # -- observability -------------------------------------------------
    def _base_stats(self) -> Dict[str, Any]:
        """The point-in-time stats tree (what the MetricsSampler
        snapshots): per-tenant throughput/queue/rejection counters, the
        engine's launch/coalesce counters (``launches < jobs`` across a
        concurrent burst is the cross-client coalescing signature), the
        owned runtime's counters when scrubbing is on, and every
        layer's thread heartbeats."""
        with self._cv:
            tenants = {
                t.name: {**t.stats, "queue_depth": len(t.queue),
                         "queued_bytes": t.queued_bytes,
                         "inflight": t.inflight, "weight": t.weight,
                         "qos": t.qos,
                         "heartbeats": t.sai.heartbeats.snapshot()}
                for t in self._order}
            out: Dict[str, Any] = {
                "tenants": tenants,
                "sessions": len(self._sessions),
                "frames": self.stats["frames"],
                "dispatched": self.stats["dispatched"],
                "admission_rejections":
                    self.stats["admission_rejections"],
                "stats_truncated": self.stats["stats_truncated"],
            }
        out["heartbeats"] = self.heartbeats.snapshot()
        eng = self._engine
        if eng is not None and eng._alive:
            es = eng.snapshot_stats()
            out["engine"] = es
            out["jobs"] = es["jobs"]
            out["launches"] = es["launches"]
            out["queue_depths"] = {lane: eng.queue_depth(lane)
                                   for lane in crystal_mod.LANES}
        if self.runtime is not None:
            out["runtime"] = self.runtime.snapshot_stats()
        out["obs"] = {
            "request": {"write": self._hist_write.summary(),
                        "read": self._hist_read.summary(),
                        "queue_wait": self._hist_queue.summary()},
            "qos": {q: {**h.summary(), "buckets": list(h.buckets())}
                    for q, h in self._hist_qos.items()},
            "traces": self.tracer.stats(),
        }
        wal = getattr(self.manager, "wal", None)
        if wal is not None:
            out["wal"] = wal.snapshot_stats()
        stores = [n.store for n in getattr(self.manager, "nodes", [])
                  if getattr(n, "store", None) is not None]
        if stores:
            agg: Dict[str, int] = {}
            for st in stores:
                for k, v in st.stats.items():
                    agg[k] = agg.get(k, 0) + v
            out["blockstore"] = agg
        return out

    def snapshot_stats(self) -> Dict[str, Any]:
        """The base tree plus the health plane's derived blocks: a
        ``timeseries`` block of windowed rates and a ``health`` block
        with the latest rule verdicts (present once the sampler has at
        least one sample)."""
        out = self._base_stats()
        if self.sampler.samples:
            out["timeseries"] = self.sampler.snapshot()
            out["health"] = self.health.snapshot()
        return out

    def health_report(self) -> Dict[str, Any]:
        """Fresh health verdicts.  With the background plane running
        this evaluates against the live ring; without it, each call
        takes one sample first, so repeated OP_HEALTH polls still
        accumulate a window."""
        if not self.sampler.running:
            self.sampler.sample_once()
        return self.health.evaluate()

    # -- lifecycle -----------------------------------------------------
    def close(self, timeout: float = 60.0):
        """Graceful shutdown: stop admitting (late requests get
        ``ST_RETRY``), drain every queued/in-flight request, then stop
        the scheduler, completers, tenant SAIs, and the owned runtime.
        The engine is NOT shut down — the gateway shares it with other
        users (callers that created a private engine own its shutdown).
        Idempotent."""
        with self._cv:
            already = self._closed
            self._closed = True
            tenants = list(self._order)  # snapshot: teardown below is unlocked
            if not already:
                deadline = time.monotonic() + timeout
                while not self._drained_locked() \
                        and time.monotonic() < deadline:
                    self._cv.wait(0.1)
                # drain deadline expired with work still queued: bounce
                # it with RetryLater now, BEFORE the completer sentinels
                # go in — a reply must never be left unresolved behind a
                # stopping scheduler
                for t in self._order:
                    while t.queue:
                        w = t.queue.popleft()
                        t.queued_bytes -= w.cost
                        t.stats.inc("rejected")
                        w.reply._resolve(encode_response(
                            ST_RETRY, w.op, w.rid,
                            reason="gateway closing"))
        self._stop.set()
        with self._cv:
            self._cv.notify_all()
        self._scheduler.join(timeout=10)
        if already:
            return
        # tear the health plane down before the layers it samples
        if self.http is not None:
            self.http.close()
        self.sampler.stop()
        for t in tenants:
            t.completion_q.put(None)
        for t in tenants:
            if t.completer is not None:
                t.completer.join(timeout=10)
            t.sai.close()
        if self.runtime is not None:
            self.runtime.stop()
        if self._owns_store:
            self.manager.close()
