"""Tenant auth tokens for the storage gateway.

Once frames cross a real socket the gateway cannot trust the tenant
name a client claims in ``OP_OPEN`` — any connection could bill its
traffic to another tenant's fair-share bucket (or open the admin
tenant).  This module is the shared-secret scheme that closes that
hole:

  token   — ``mint_token(tenant, secret)`` packs ``version | tenant |
            expiry | nonce`` and appends an HMAC-SHA256 signature over
            those bytes keyed by the tenant's shared secret.  The
            tenant name is *inside* the signed payload, so a token
            minted for tenant A cannot open a session as tenant B.
  expiry  — tokens carry an absolute expiry (``time.time() + ttl_s``);
            verification rejects expired tokens, so a leaked frame is
            only useful for a short window.
  nonce   — 16 random bytes, remembered (per tenant) by the verifier
            until the token expires; presenting the same token twice
            is rejected, so a captured ``OP_OPEN`` frame cannot be
            replayed to open more sessions.

:class:`TokenAuthenticator` is the gateway-side verifier: it holds the
per-tenant secret table and a nonce replay cache, and ``verify()``
returns the *authenticated* tenant name — the gateway uses that, never
the claimed field, to create the session.  All verification failures
raise :class:`AuthError` (a ``PermissionError``), which the gateway
answers with ``ST_ERROR``.
"""
from __future__ import annotations

import hashlib
import heapq
import hmac
import math
import os
import struct
import threading
import time
from typing import Dict, List, Optional, Tuple

TOKEN_VERSION = 1
NONCE_BYTES = 16
SIG_BYTES = hashlib.sha256().digest_size       # 32

_VER = struct.Struct("!B")
_U16 = struct.Struct("!H")
_F64 = struct.Struct("!d")


class AuthError(PermissionError):
    """Token verification failed (malformed, forged, expired, replayed,
    or for an unknown/mismatched tenant)."""


def _signed_body(tenant_utf8: bytes, expiry: float, nonce: bytes) -> bytes:
    return (_VER.pack(TOKEN_VERSION) + _U16.pack(len(tenant_utf8))
            + tenant_utf8 + _F64.pack(expiry)
            + _U16.pack(len(nonce)) + nonce)


def mint_token(tenant: str, secret: bytes, ttl_s: float = 30.0,
               now: Optional[float] = None,
               nonce: Optional[bytes] = None) -> bytes:
    """Mint a signed open-token for ``tenant``.  ``now``/``nonce`` are
    injectable for tests (expired tokens, replay)."""
    tenant_utf8 = tenant.encode("utf-8")
    if len(tenant_utf8) > 0xFFFF:
        raise ValueError("tenant name too long")
    if now is None:
        now = time.time()
    if nonce is None:
        nonce = os.urandom(NONCE_BYTES)
    body = _signed_body(tenant_utf8, now + float(ttl_s), nonce)
    sig = hmac.new(bytes(secret), body, hashlib.sha256).digest()
    return body + sig


def parse_token(token: bytes) -> Tuple[str, float, bytes, bytes, bytes]:
    """-> (tenant, expiry, nonce, signature, signed_body); raises
    :class:`AuthError` on any malformed layout (never ``struct.error``
    / ``IndexError`` — tokens arrive off the wire)."""
    try:
        off = 0
        (ver,) = _VER.unpack_from(token, off)
        off += _VER.size
        if ver != TOKEN_VERSION:
            raise AuthError(f"unsupported token version {ver}")
        (tlen,) = _U16.unpack_from(token, off)
        off += _U16.size
        if off + tlen > len(token):
            raise AuthError("truncated token tenant")
        tenant = token[off:off + tlen].decode("utf-8")
        off += tlen
        (expiry,) = _F64.unpack_from(token, off)
        off += _F64.size
        (nlen,) = _U16.unpack_from(token, off)
        off += _U16.size
        if off + nlen + SIG_BYTES != len(token):
            raise AuthError("truncated token nonce/signature")
        nonce = bytes(token[off:off + nlen])
        off += nlen
        sig = bytes(token[off:])
    except AuthError:
        raise
    except (struct.error, UnicodeDecodeError, IndexError, TypeError) as e:
        raise AuthError(f"malformed token: {e}") from None
    return tenant, expiry, nonce, sig, bytes(token[:-SIG_BYTES])


class TokenAuthenticator:
    """Gateway-side verifier: per-tenant shared secrets + a nonce
    replay cache.  Thread-safe — ``OP_OPEN`` frames arrive on many
    connection reader threads at once."""

    def __init__(self, secrets: Dict[str, bytes]):
        self._secrets = {t: bytes(s) for t, s in secrets.items()}  # guarded by self._lock
        self._lock = threading.Lock()
        self._seen: Dict[Tuple[str, bytes], float] = {}  # nonce -> expiry; guarded by self._lock
        # expiry-ordered heap over _seen keys: pruning pops only the
        # already-expired head instead of scanning the whole cache under
        # the lock on every open
        self._expiries: List[Tuple[float, Tuple[str, bytes]]] = []  # guarded by self._lock
        # unknown tenants still pay for a full HMAC against this dummy
        # secret, so a timing probe on the open path can't distinguish
        # "tenant exists" from "tenant doesn't"
        self._decoy = os.urandom(32)

    def add_tenant(self, tenant: str, secret: bytes):
        with self._lock:
            self._secrets[tenant] = bytes(secret)

    def verify(self, token: bytes, claimed: Optional[str] = None,
               now: Optional[float] = None) -> str:
        """Verify a token and return the authenticated tenant name.
        Signature is checked *first* (forged tokens never touch the
        replay cache), then expiry, then replay."""
        if not token:
            raise AuthError("missing auth token")
        if now is None:
            now = time.time()
        tenant, expiry, nonce, sig, body = parse_token(token)
        with self._lock:
            secret = self._secrets.get(tenant)
        # always do the HMAC (decoy-keyed for unknown tenants) and share
        # one error message, so neither timing nor the reply text tells
        # a prober whether a tenant name exists
        want = hmac.new(self._decoy if secret is None else secret,
                        body, hashlib.sha256).digest()
        if not hmac.compare_digest(sig, want) or secret is None:
            raise AuthError("unknown tenant or bad token signature")
        if claimed is not None and claimed != tenant:
            raise AuthError(
                f"token is for tenant {tenant!r}, not {claimed!r}")
        # the wire expiry is a raw f64: NaN slips past `expiry <= now`
        # and then stalls the expiry heap at its root forever (inf pins
        # its cache entry forever) — reject both before caching
        if not math.isfinite(expiry):
            raise AuthError("non-finite token expiry")
        if expiry <= now:
            raise AuthError("token expired")
        key = (tenant, nonce)
        with self._lock:
            while self._expiries and self._expiries[0][0] <= now:
                exp, k = heapq.heappop(self._expiries)
                # the heap may hold a stale entry for a nonce that was
                # re-recorded with a later expiry; only drop the cache
                # entry if it really is expired
                if self._seen.get(k, now + 1.0) <= now:
                    del self._seen[k]
            if key in self._seen:
                raise AuthError("token replayed (nonce already used)")
            self._seen[key] = expiry
            heapq.heappush(self._expiries, (expiry, key))
        return tenant
