"""Continuous-batching request scheduler (serving substrate).

Production serving at decode_32k scale interleaves requests: new prompts
prefill into free cache slots while resident requests decode every step.
This implements the slot-based variant matching the framework's
fixed-capacity decode caches:

  * a fixed pool of B cache slots (the decode batch — the jitted graphs
    stay fixed-shape, so continuous batching costs no recompiles);
  * arriving requests queue; a free slot triggers a single-sequence
    prefill whose cache rows are written into the slot;
  * every engine step decodes ALL active slots in one batched call with
    a per-slot position vector (the model's ragged decode path:
    one-hot masked cache writes + per-slot attention masks);
  * finished requests (max-tokens or EOS) free their slot.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = dataclasses.field(default_factory=time.time)
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None


class ContinuousBatcher:
    def __init__(self, model, params, batch_slots: int, capacity: int,
                 eos_token: int = -1):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.capacity = capacity
        self.eos = eos_token
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}        # slot -> request
        self.finished: List[Request] = []
        self.slot_pos = np.zeros((batch_slots,), np.int64)
        self.cache = compat.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            model.cache_shapes(batch_slots, capacity))
        self._prefill_one = jax.jit(
            lambda p, t: model.prefill(p, t, capacity=capacity))
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self.steps = 0
        self._next_rid = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(rid=self._next_rid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new=max_new)
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _admit(self):
        for slot in range(self.B):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.popleft()
            cache1, logits = self._prefill_one(self.params,
                                               req.prompt[None, :])

            def put(full, one):
                return full.at[:, slot:slot + 1].set(one.astype(full.dtype))

            self.cache = compat.tree_map(put, self.cache, cache1)
            req.out_tokens.append(int(jnp.argmax(logits, -1)[0]))
            req.first_token_s = time.time()
            self.slot_pos[slot] = len(req.prompt)
            self.active[slot] = req

    def _retire(self):
        for slot, req in list(self.active.items()):
            if len(req.out_tokens) >= req.max_new or \
                    req.out_tokens[-1] == self.eos:
                req.done_s = time.time()
                self.finished.append(req)
                del self.active[slot]

    def step(self):
        """One engine step: admit -> batched ragged decode -> retire."""
        self._admit()
        self._retire()
        if not self.active:
            return
        toks = np.zeros((self.B, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out_tokens[-1]
        pos_vec = jnp.asarray(self.slot_pos, jnp.int32)      # [B]
        self.cache, logits = self._decode(self.params, self.cache,
                                          jnp.asarray(toks), pos_vec)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for slot, req in self.active.items():
            req.out_tokens.append(int(nxt[slot]))
            self.slot_pos[slot] += 1
        self.steps += 1
        self._retire()

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()
        return self.finished

    def stats(self) -> dict:
        done = [r for r in self.finished]
        return {
            "steps": self.steps,
            "finished": len(done),
            "queued": len(self.queue),
            "active": len(self.active),
            "mean_ttft_s": float(np.mean(
                [r.first_token_s - r.submitted_s for r in done]))
            if done else 0.0,
        }
