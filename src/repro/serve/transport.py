"""TCP socket transport for the storage gateway.

PR 4 built the framed wire codec and an in-process channel with exactly
the contract a socket needs (``request(frame) -> ReplyFuture``); this
module carries those same frames over a real stream so clients in other
processes/hosts reach the gateway — and their hash bursts still fuse on
one shared engine (the paper's cross-client offload argument only pays
off when many *remote* clients' requests coalesce on one device).

Stream framing is length-prefixed: every codec frame is sent as a
``!I`` byte-count header followed by the frame bytes.  The length
prefix is attacker-controlled on the server side, so both ends refuse
to allocate past ``max_frame_bytes`` — a hostile prefix kills the
connection instead of the process.

  SocketChannel  — client endpoint.  ``request(frame)`` registers the
                   frame's rid, sends it, and returns a
                   :class:`ReplyFuture`; a reader thread matches
                   response frames back to futures by rid (responses
                   may arrive out of request order — the gateway
                   completes tenants independently).  Abrupt disconnect
                   resolves every in-flight future with an ``ST_ERROR``
                   (``ConnectionError``) frame; graceful ``close()``
                   half-closes the write side and drains outstanding
                   replies before tearing down.
  GatewayServer  — accept loop + per-connection reader/writer threads.
                   The reader decodes stream frames and feeds
                   ``gateway.handle_frame``; the writer sends each
                   connection's replies back in request order.  A
                   client half-close (EOF after its last request) still
                   gets all pending responses; an abrupt disconnect
                   just drains the futures without writing.  Server
                   ``close()`` stops accepting, half-closes every
                   connection, and joins the drain.

``GatewayClient`` works unchanged over either transport — pass it a
``GatewayServer``/``SocketChannel``/address instead of a
``StorageGateway``.
"""
from __future__ import annotations

import queue
import socket
import struct
import threading
from typing import Dict, Optional, Tuple, Union

from repro.serve.storage_service import (MAX_FRAME_BYTES, ST_ERROR,
                                         ReplyFuture, StorageGateway,
                                         _REQ_HDR, _RSP_HDR,
                                         encode_response)

_LEN = struct.Struct("!I")

Address = Union[str, Tuple[str, int]]


class FrameError(ConnectionError):
    """The stream violated the framing protocol (oversized length
    prefix, or EOF in the middle of a frame)."""


def parse_address(address: Address) -> Tuple[str, int]:
    if isinstance(address, str):
        host, _, port = address.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad address {address!r}; want host:port")
        return host, int(port)
    host, port = address
    return host, int(port)


def send_frame(sock: socket.socket, frame: bytes,
               max_frame_bytes: int = MAX_FRAME_BYTES):
    """Callers must serialize sends per socket (client write lock /
    single server writer thread) — the prefix and body are two writes
    for large frames, so interleaved senders would corrupt the stream."""
    if len(frame) > max_frame_bytes:
        raise FrameError(
            f"refusing to send {len(frame)}-byte frame "
            f"(max_frame_bytes={max_frame_bytes})")
    if len(frame) <= 1 << 16:
        sock.sendall(_LEN.pack(len(frame)) + frame)
    else:
        # don't copy a large payload just to prepend 4 bytes
        sock.sendall(_LEN.pack(len(frame)))
        sock.sendall(frame)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes; None on clean EOF at a frame boundary,
    FrameError on EOF mid-frame."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise FrameError("connection closed mid-frame")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket,
               max_frame_bytes: int = MAX_FRAME_BYTES
               ) -> Optional[bytes]:
    """Read one length-prefixed frame; None on clean EOF.  The length
    prefix is validated BEFORE any allocation — a hostile peer cannot
    make us reserve an unbounded buffer."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > max_frame_bytes:
        raise FrameError(
            f"peer announced {n}-byte frame "
            f"(max_frame_bytes={max_frame_bytes})")
    if n == 0:
        return b""
    got = _recv_exact(sock, n)
    if got is None:
        raise FrameError("connection closed mid-frame")
    return got


# ----------------------------------------------------------------------
# client endpoint
# ----------------------------------------------------------------------
class SocketChannel:
    """Client side of one TCP connection to a :class:`GatewayServer`.

    Implements the in-process ``GatewayChannel`` contract —
    ``request(frame) -> ReplyFuture`` — so :class:`~repro.serve.
    storage_client.GatewayClient` is transport-agnostic.  Request ids
    must be unique per connection (``GatewayClient`` already counts
    them per session); replies are matched by rid, so they may resolve
    in any order.
    """

    def __init__(self, address: Address,
                 max_frame_bytes: int = MAX_FRAME_BYTES,
                 connect_timeout_s: float = 10.0):
        self._max = max_frame_bytes
        self._sock = socket.create_connection(parse_address(address),
                                              timeout=connect_timeout_s)
        self._sock.settimeout(None)
        self._lock = threading.Lock()
        self._wlock = threading.Lock()
        self._pending: Dict[int, Tuple[int, ReplyFuture]] = {}
        self._closing = False          # no NEW requests
        self._dead = False             # reader gone; nothing in flight
        self._reader = threading.Thread(target=self._reader_loop,
                                        daemon=True,
                                        name="socket-channel-rx")
        self._reader.start()

    # -- transport contract --------------------------------------------
    def request(self, frame: bytes) -> ReplyFuture:
        op, _session, rid = _REQ_HDR.unpack_from(frame)
        reply = ReplyFuture()
        with self._lock:
            if self._closing or self._dead:
                reply._resolve(self._error_frame(
                    op, rid, "socket channel is closed"))
                return reply
            if rid in self._pending:
                raise ValueError(f"duplicate in-flight rid {rid}")
            self._pending[rid] = (op, reply)
        try:
            with self._wlock:
                send_frame(self._sock, frame, self._max)
        except OSError as e:
            with self._lock:
                self._pending.pop(rid, None)
            reply._resolve(self._error_frame(op, rid, f"send failed: {e}"))
        return reply

    def close(self, timeout_s: float = 10.0):
        """Graceful: half-close the write side so the server sees EOF
        after our last request, wait for it to drain our outstanding
        replies, then release the socket.  Idempotent."""
        with self._lock:
            if self._closing:
                return
            self._closing = True
        with self._wlock:        # let an in-progress send finish: a
            try:                 # mid-frame SHUT_WR would look like a
                self._sock.shutdown(socket.SHUT_WR)   # protocol abort
            except OSError:      # to the server and drop that reply
                pass
        self._reader.join(timeout=timeout_s)
        try:
            self._sock.close()
        except OSError:
            pass

    # -- internals -----------------------------------------------------
    @staticmethod
    def _error_frame(op: int, rid: int, msg: str) -> bytes:
        return encode_response(ST_ERROR, op, rid,
                               errtype="ConnectionError", msg=msg)

    def _reader_loop(self):
        why = "server closed the connection"
        try:
            while True:
                frame = recv_frame(self._sock, self._max)
                if frame is None:
                    break
                if len(frame) < _RSP_HDR.size:
                    why = "short response frame"
                    break
                _status, _op, rid = _RSP_HDR.unpack_from(frame)
                with self._lock:
                    entry = self._pending.pop(rid, None)
                if entry is not None:
                    entry[1]._resolve(frame)
        except (OSError, FrameError) as e:
            why = f"connection lost: {e}"
        finally:
            with self._lock:
                self._dead = True
                stranded = list(self._pending.items())
                self._pending.clear()
            # abrupt disconnect: every in-flight future resolves to an
            # ST_ERROR frame instead of hanging its waiter forever
            for rid, (op, reply) in stranded:
                reply._resolve(self._error_frame(op, rid, why))


# ----------------------------------------------------------------------
# server
# ----------------------------------------------------------------------
class _Connection:
    def __init__(self, server: "GatewayServer", sock: socket.socket,
                 peer):
        self.server = server
        self.sock = sock
        self.peer = peer
        self.aborted = False           # peer vanished: drain, don't send
        self.writeq: "queue.Queue" = queue.Queue()
        self.reader = threading.Thread(target=self._reader_loop,
                                       daemon=True,
                                       name=f"gw-conn-rx-{peer}")
        self.writer = threading.Thread(target=self._writer_loop,
                                       daemon=True,
                                       name=f"gw-conn-tx-{peer}")
        self.reader.start()
        self.writer.start()

    def _reader_loop(self):
        srv = self.server
        try:
            while True:
                frame = recv_frame(self.sock, srv.max_frame_bytes)
                if frame is None:      # half-close: no more requests,
                    break              # writer still drains responses
                with srv._lock:
                    srv.stats["frames"] += 1
                self.writeq.put(srv.gateway.handle_frame(frame))
        except FrameError:
            # protocol violation (hostile length prefix, EOF mid-frame):
            # stop reading and tell the writer to drain in-flight
            # replies without touching the untrusted stream
            self.aborted = True
            with srv._lock:
                srv.stats["frame_errors"] += 1
        except OSError:
            # routine abrupt disconnect (RST, crashed client) — not a
            # protocol violation; counted separately so frame_errors
            # stays a clean hostile-peer signal
            self.aborted = True
            with srv._lock:
                srv.stats["disconnects"] += 1
        finally:
            self.writeq.put(None)

    def _writer_loop(self):
        srv = self.server
        try:
            while True:
                reply = self.writeq.get()
                if reply is None:
                    break
                try:
                    frame = reply.result(timeout=srv.reply_timeout_s)
                except TimeoutError:
                    # a stuck gateway reply: the connection is wedged
                    # (responses are written in request order); abort
                    self.aborted = True
                    break
                if self.aborted:
                    continue           # keep draining futures
                try:
                    send_frame(self.sock, frame, srv.max_frame_bytes)
                except OSError:
                    self.aborted = True
        finally:
            self.half_close(read=True)
            try:
                self.sock.close()
            except OSError:
                pass
            srv._forget(self)

    def half_close(self, read: bool = True):
        try:
            self.sock.shutdown(socket.SHUT_RD if read
                               else socket.SHUT_WR)
        except OSError:
            pass

    def join(self, timeout_s: float):
        self.reader.join(timeout=timeout_s)
        self.writer.join(timeout=timeout_s)


class GatewayServer:
    """Accept loop serving a :class:`StorageGateway` over TCP.

    ``port=0`` binds an ephemeral port; ``address`` is the bound
    ``(host, port)``.  ``connect()`` returns a :class:`SocketChannel`
    to this server, so ``GatewayClient(server, ...)`` works exactly
    like ``GatewayClient(gateway, ...)``.  The server owns its
    connections but NOT the gateway (callers may front one gateway
    with several listeners, or keep serving in-process clients).
    """

    def __init__(self, gateway: StorageGateway, host: str = "127.0.0.1",
                 port: int = 0,
                 max_frame_bytes: Optional[int] = None,
                 backlog: int = 64, reply_timeout_s: float = 600.0):
        self.gateway = gateway
        self.max_frame_bytes = (gateway.cfg.max_frame_bytes
                                if max_frame_bytes is None
                                else max_frame_bytes)
        self.reply_timeout_s = reply_timeout_s
        self._lock = threading.Lock()
        self._conns: set = set()
        self._closed = False
        self.stats = {"connections": 0, "frames": 0, "frame_errors": 0,
                      "disconnects": 0}
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, port))
        self._lsock.listen(backlog)
        self.address: Tuple[str, int] = self._lsock.getsockname()[:2]
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          daemon=True,
                                          name="gw-server-accept")
        self._acceptor.start()

    def connect(self) -> SocketChannel:
        return SocketChannel(self.address,
                             max_frame_bytes=self.max_frame_bytes)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _accept_loop(self):
        while True:
            try:
                sock, peer = self._lsock.accept()
            except OSError:            # listener closed
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                if self._closed:
                    try:
                        sock.close()
                    except OSError:
                        pass
                    continue
                self.stats["connections"] += 1
                self._conns.add(_Connection(self, sock, peer))

    def _forget(self, conn: _Connection):
        with self._lock:
            self._conns.discard(conn)

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return {**self.stats, "open_connections": len(self._conns)}

    def close(self, timeout_s: float = 30.0):
        """Graceful: stop accepting, half-close every connection's read
        side (reader sees EOF), and join the writers — each drains its
        in-flight replies before the socket closes.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns)
        try:
            self._lsock.close()
        except OSError:
            pass
        self._acceptor.join(timeout=timeout_s)
        for conn in conns:
            conn.half_close(read=True)
        for conn in conns:
            conn.join(timeout_s)
