"""TCP socket transport for the storage gateway.

PR 4 built the framed wire codec and an in-process channel with exactly
the contract a socket needs (``request(frame) -> ReplyFuture``); this
module carries those same frames over a real stream so clients in other
processes/hosts reach the gateway — and their hash bursts still fuse on
one shared engine (the paper's cross-client offload argument only pays
off when many *remote* clients' requests coalesce on one device).

Stream framing is length-prefixed: every codec frame is sent as a
``!I`` byte-count header followed by the frame bytes.  The length
prefix is attacker-controlled on the server side, so both ends refuse
to allocate past ``max_frame_bytes`` — a hostile prefix kills the
connection instead of the process.

  SocketChannel  — client endpoint.  ``request(frame)`` registers the
                   frame's rid, sends it, and returns a
                   :class:`ReplyFuture`; a reader thread matches
                   response frames back to futures by rid (responses
                   may arrive out of request order — the gateway
                   completes tenants independently).  Abrupt disconnect
                   resolves every in-flight future with an ``ST_ERROR``
                   (``ConnectionError``) frame; graceful ``close()``
                   half-closes the write side and drains outstanding
                   replies before tearing down.
  GatewayServer  — accept loop + per-connection reader/writer threads.
                   The reader decodes stream frames and feeds
                   ``gateway.handle_frame``; the writer sends each
                   connection's replies back in request order.  A
                   client half-close (EOF after its last request) still
                   gets all pending responses; an abrupt disconnect
                   just drains the futures without writing.  Server
                   ``close()`` stops accepting, half-closes every
                   connection, and joins the drain.

``GatewayClient`` works unchanged over either transport — pass it a
``GatewayServer``/``SocketChannel``/address instead of a
``StorageGateway``.
"""
from __future__ import annotations

import queue
import select
import socket
import struct
import threading
from typing import Dict, Optional, Tuple, Union

from repro.obs import MetricsRegistry
from repro.serve.storage_service import (MAX_FRAME_BYTES, ST_ERROR,
                                         ReplyFuture, StorageGateway,
                                         _REQ_HDR, _RSP_HDR,
                                         encode_response)

_LEN = struct.Struct("!I")

# per-call non-blocking send flag (Linux/BSD; 0 elsewhere degrades the
# server writer's abortable send back to a blocking one)
_MSG_DONTWAIT = getattr(socket, "MSG_DONTWAIT", 0)

if hasattr(select, "poll"):
    # poll has no FD_SETSIZE ceiling — select.select raises ValueError
    # for fds >= 1024, which a busy server crosses routinely
    def _wait_writable(sock: socket.socket, timeout_s: float) -> bool:
        p = select.poll()
        p.register(sock.fileno(), select.POLLOUT)
        return bool(p.poll(timeout_s * 1000.0))
else:                                             # pragma: no cover
    def _wait_writable(sock: socket.socket, timeout_s: float) -> bool:
        _r, w, _x = select.select([], [sock], [], timeout_s)
        return bool(w)

Address = Union[str, Tuple[str, int]]


class FrameError(ConnectionError):
    """The stream violated the framing protocol (oversized length
    prefix, or EOF in the middle of a frame)."""


def parse_address(address: Address) -> Tuple[str, int]:
    """``(host, port)`` pass through; strings split on the LAST colon,
    with IPv6 literals in brackets (``[::1]:8080``).  An unbracketed
    multi-colon host is rejected rather than guessed at."""
    if isinstance(address, str):
        host, _, port = address.rpartition(":")
        if host.startswith("[") and host.endswith("]"):
            host = host[1:-1]
        elif ":" in host:
            raise ValueError(
                f"ambiguous IPv6 address {address!r}; use [host]:port")
        if not host or not port.isdigit():
            raise ValueError(f"bad address {address!r}; want host:port")
        return host, int(port)
    host, port = address
    return host, int(port)


def send_frame(sock: socket.socket, frame: bytes,
               max_frame_bytes: int = MAX_FRAME_BYTES,
               sendall=None):
    """Callers must serialize sends per socket (client write lock /
    single server writer thread) — the prefix and body are two writes
    for large frames, so interleaved senders would corrupt the stream.
    ``sendall`` overrides how the bytes go out (the server writer
    passes its abortable send) without duplicating the framing
    policy."""
    if sendall is None:
        sendall = sock.sendall
    if len(frame) > max_frame_bytes:
        raise FrameError(
            f"refusing to send {len(frame)}-byte frame "
            f"(max_frame_bytes={max_frame_bytes})")
    if len(frame) <= 1 << 16:
        sendall(_LEN.pack(len(frame)) + frame)
    else:
        # don't copy a large payload just to prepend 4 bytes
        sendall(_LEN.pack(len(frame)))
        sendall(frame)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes; None on clean EOF at a frame boundary,
    FrameError on EOF mid-frame."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise FrameError("connection closed mid-frame")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket,  # ra: decode-boundary
               max_frame_bytes: int = MAX_FRAME_BYTES
               ) -> Optional[bytes]:
    """Read one length-prefixed frame; None on clean EOF.  The length
    prefix is validated BEFORE any allocation — a hostile peer cannot
    make us reserve an unbounded buffer."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > max_frame_bytes:
        raise FrameError(
            f"peer announced {n}-byte frame "
            f"(max_frame_bytes={max_frame_bytes})")
    if n == 0:
        return b""
    got = _recv_exact(sock, n)
    if got is None:
        raise FrameError("connection closed mid-frame")
    return got


# ----------------------------------------------------------------------
# client endpoint
# ----------------------------------------------------------------------
class SocketChannel:
    """Client side of one TCP connection to a :class:`GatewayServer`.

    Implements the in-process ``GatewayChannel`` contract —
    ``request(frame) -> ReplyFuture`` — so :class:`~repro.serve.
    storage_client.GatewayClient` is transport-agnostic.  Request ids
    must be unique per connection (``GatewayClient`` already counts
    them per session); replies are matched by rid, so they may resolve
    in any order.
    """

    def __init__(self, address: Address,
                 max_frame_bytes: int = MAX_FRAME_BYTES,
                 connect_timeout_s: float = 10.0):
        self._max = max_frame_bytes
        self._sock = socket.create_connection(parse_address(address),
                                              timeout=connect_timeout_s)
        self._sock.settimeout(None)
        self._lock = threading.Lock()
        self._wlock = threading.Lock()
        self._pending: Dict[int, Tuple[int, ReplyFuture]] = {}  # guarded by self._lock
        self._closing = False  # no NEW requests; guarded by self._lock
        self._dead = False  # reader gone, nothing in flight; guarded by self._lock
        self._reader = threading.Thread(target=self._reader_loop,
                                        daemon=True,
                                        name="socket-channel-rx")
        self._reader.start()

    # -- transport contract --------------------------------------------
    def request(self, frame: bytes) -> ReplyFuture:
        op, _session, rid, _trace = _REQ_HDR.unpack_from(frame)  # ra: disable=RA03(frame was encoded by our own codec one call up; not wire bytes)
        reply = ReplyFuture()
        with self._lock:
            if self._closing or self._dead:
                reply._resolve(self._error_frame(
                    op, rid, "socket channel is closed"))
                return reply
            if rid in self._pending:
                raise ValueError(f"duplicate in-flight rid {rid}")
            self._pending[rid] = (op, reply)
        try:
            with self._wlock:
                send_frame(self._sock, frame, self._max)  # ra: disable=RA04(_wlock exists solely to serialise frame writes; never nested)
        except OSError as e:
            with self._lock:
                self._pending.pop(rid, None)
            reply._resolve(self._error_frame(op, rid, f"send failed: {e}"))
        return reply

    def close(self, timeout_s: float = 10.0):
        """Graceful: half-close the write side so the server sees EOF
        after our last request, wait for it to drain our outstanding
        replies, then release the socket.  Idempotent."""
        with self._lock:
            if self._closing:
                return
            self._closing = True
        with self._wlock:        # let an in-progress send finish: a
            try:                 # mid-frame SHUT_WR would look like a
                self._sock.shutdown(socket.SHUT_WR)   # protocol abort
            except OSError:      # to the server and drop that reply
                pass
        self._reader.join(timeout=timeout_s)
        try:
            self._sock.close()
        except OSError:
            pass

    # -- internals -----------------------------------------------------
    @staticmethod
    def _error_frame(op: int, rid: int, msg: str) -> bytes:
        return encode_response(ST_ERROR, op, rid,
                               errtype="ConnectionError", msg=msg)

    def _reader_loop(self):  # ra: disable=RA05(per-connection thread; lifetime == socket lifetime, exits on EOF)
        why = "server closed the connection"
        try:
            while True:
                frame = recv_frame(self._sock, self._max)
                if frame is None:
                    break
                if len(frame) < _RSP_HDR.size:
                    why = "short response frame"
                    break
                _status, _op, rid = _RSP_HDR.unpack_from(frame)
                with self._lock:
                    entry = self._pending.pop(rid, None)
                if entry is not None:
                    entry[1]._resolve(frame)
        except (OSError, FrameError) as e:
            why = f"connection lost: {e}"
        finally:
            with self._lock:
                self._dead = True
                stranded = list(self._pending.items())
                self._pending.clear()
            # abrupt disconnect: every in-flight future resolves to an
            # ST_ERROR frame instead of hanging its waiter forever
            for rid, (op, reply) in stranded:
                reply._resolve(self._error_frame(op, rid, why))


# ----------------------------------------------------------------------
# server
# ----------------------------------------------------------------------
class _Connection:
    # writer send-poll interval: an abort (server close kicking a
    # connection wedged on a non-draining client) is noticed within
    # this long even while the peer's receive window is closed
    SEND_POLL_S = 0.2

    def __init__(self, server: "GatewayServer", sock: socket.socket,
                 peer):
        self.server = server
        self.sock = sock
        self.peer = peer
        self.aborted = False           # peer vanished: drain, don't send
        # bounded: once max_pipeline replies are queued ahead of the
        # writer the reader blocks in put() and stops pulling frames off
        # the socket — TCP flow control pushes back on the client, so a
        # connection that pipelines requests without draining responses
        # holds at most max_pipeline reply frames of server memory
        # instead of growing without bound
        self.writeq: "queue.Queue" = queue.Queue(
            maxsize=server.max_pipeline)
        self.reader = threading.Thread(target=self._reader_loop,
                                       daemon=True,
                                       name=f"gw-conn-rx-{peer}")
        self.writer = threading.Thread(target=self._writer_loop,
                                       daemon=True,
                                       name=f"gw-conn-tx-{peer}")
        self.reader.start()
        self.writer.start()

    def _reader_loop(self):  # ra: disable=RA05(per-connection thread; lifetime == socket lifetime, exits on EOF)
        srv = self.server
        try:
            while True:
                frame = recv_frame(self.sock, srv.max_frame_bytes)
                if frame is None:      # half-close: no more requests,
                    break              # writer still drains responses
                srv.stats.inc("frames")
                # owner=self: sessions opened on this connection are
                # usable only from this connection — another client
                # naming the same session id gets UnknownSession
                self.writeq.put(srv.gateway.handle_frame(frame,
                                                         owner=self))
        except FrameError:
            # protocol violation (hostile length prefix, EOF mid-frame):
            # stop reading and tell the writer to drain in-flight
            # replies without touching the untrusted stream
            self.aborted = True
            srv.stats.inc("frame_errors")
        except OSError:
            # routine abrupt disconnect (RST, crashed client) — not a
            # protocol violation; counted separately so frame_errors
            # stays a clean hostile-peer signal
            self.aborted = True
            srv.stats.inc("disconnects")
        finally:
            self.writeq.put(None)

    def _send_abortable(self, data: bytes):
        """sendall that a concurrent abort (server close) can interrupt:
        a blocking send() to a client that stopped draining its replies
        queues the whole buffer before returning and shutdown() cannot
        wake it, so it would wedge this thread forever.  Instead wait
        for writability in short slices, checking ``aborted`` between
        them, and send without blocking (MSG_DONTWAIT where available —
        a per-call flag, since O_NONBLOCK on a dup'd fd would leak to
        the reader's shared file description)."""
        view = memoryview(data)
        while view:
            if self.aborted:
                raise OSError("connection aborted during send")
            if not _wait_writable(self.sock, self.SEND_POLL_S):
                continue
            try:
                view = view[self.sock.send(view, _MSG_DONTWAIT):]
            except BlockingIOError:
                continue               # lost the race for buffer space

    def _writer_loop(self):  # ra: disable=RA05(per-connection thread; bounded writeq, exits on sentinel)
        srv = self.server
        got_sentinel = False
        try:
            while True:
                reply = self.writeq.get()
                if reply is None:
                    got_sentinel = True
                    break
                try:
                    frame = reply.result(timeout=srv.reply_timeout_s)
                except TimeoutError:
                    # a stuck gateway reply: the connection is wedged
                    # (responses are written in request order); abort
                    self.aborted = True
                    break
                if self.aborted:
                    continue           # keep draining futures
                try:
                    send_frame(self.sock, frame, srv.max_frame_bytes,
                               sendall=self._send_abortable)
                except OSError:
                    self.aborted = True
        finally:
            self.half_close(read=True)
            try:
                self.sock.close()
            except OSError:
                pass
            # on a timeout/abort exit the bounded writeq may still be
            # full with the reader blocked in put(); keep consuming
            # until the reader's sentinel so it can observe the closed
            # socket and exit instead of hanging forever
            while not got_sentinel:
                got_sentinel = self.writeq.get() is None
            # the connection's sessions die with it — the ids must not
            # stay live in the gateway table after the authenticated
            # connection is gone
            srv.gateway.drop_sessions(self)
            srv._forget(self)

    def half_close(self, read: bool = True):
        try:
            self.sock.shutdown(socket.SHUT_RD if read
                               else socket.SHUT_WR)
        except OSError:
            pass

    def join(self, timeout_s: float):
        self.reader.join(timeout=timeout_s)
        self.writer.join(timeout=timeout_s)


class GatewayServer:
    """Accept loop serving a :class:`StorageGateway` over TCP.

    ``port=0`` binds an ephemeral port; ``address`` is the bound
    ``(host, port)``.  ``connect()`` returns a :class:`SocketChannel`
    to this server, so ``GatewayClient(server, ...)`` works exactly
    like ``GatewayClient(gateway, ...)``.  The server owns its
    connections but NOT the gateway (callers may front one gateway
    with several listeners, or keep serving in-process clients).

    Sessions are connection-scoped: each frame is handled with its
    connection as the session owner, so a session id opened on one
    connection is dead weight on every other — guessing another
    client's (small, sequential) session id gets ``UnknownSession``,
    and a connection's sessions are dropped when it goes away.
    """

    def __init__(self, gateway: StorageGateway, host: str = "127.0.0.1",
                 port: int = 0,
                 max_frame_bytes: Optional[int] = None,
                 backlog: int = 64, reply_timeout_s: float = 600.0,
                 max_pipeline: int = 32):
        self.gateway = gateway
        self.max_frame_bytes = (gateway.cfg.max_frame_bytes
                                if max_frame_bytes is None
                                else max_frame_bytes)
        self.reply_timeout_s = reply_timeout_s
        # per-connection cap on replies queued ahead of the writer; the
        # worst case a non-draining client can pin is roughly
        # max_pipeline * max_frame_bytes of this server's memory
        if max_pipeline < 1:
            raise ValueError("max_pipeline must be >= 1")
        self.max_pipeline = max_pipeline
        self._lock = threading.Lock()
        self._conns: set = set()  # guarded by self._lock
        self._closed = False  # guarded by self._lock
        # atomic counters: connection reader threads bump these without
        # taking the server lock
        self.metrics = MetricsRegistry()
        self.stats = self.metrics.group(
            ("connections", "frames", "frame_errors", "disconnects"))
        # resolve the bind family from the host (AF_INET6 for IPv6
        # literals/names) instead of hard-coding AF_INET; "" means
        # wildcard, which getaddrinfo only understands as None
        family, _, _, _, sockaddr = socket.getaddrinfo(
            host or None, port, type=socket.SOCK_STREAM,
            flags=socket.AI_PASSIVE)[0]
        self._lsock = socket.socket(family, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        if family == socket.AF_INET6:
            # dual-stack where the platform allows it: a wildcard or
            # hostname bind that resolved to v6 must not silently stop
            # serving IPv4 clients (v6only defaults vary by platform)
            try:
                self._lsock.setsockopt(socket.IPPROTO_IPV6,
                                       socket.IPV6_V6ONLY, 0)
            except (OSError, AttributeError):
                pass
        self._lsock.bind(sockaddr)
        self._lsock.listen(backlog)
        self.address: Tuple[str, int] = self._lsock.getsockname()[:2]
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          daemon=True,
                                          name="gw-server-accept")
        self._acceptor.start()

    def connect(self) -> SocketChannel:
        return SocketChannel(self.address,
                             max_frame_bytes=self.max_frame_bytes)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _accept_loop(self):  # ra: disable=RA05(accept loop blocks in the kernel, not on our queues; exits on close)
        while True:
            try:
                sock, peer = self._lsock.accept()
            except OSError:            # listener closed
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                if self._closed:
                    try:
                        sock.close()
                    except OSError:
                        pass
                    continue
                self.stats.inc("connections")
                self._conns.add(_Connection(self, sock, peer))

    def _forget(self, conn: _Connection):
        with self._lock:
            self._conns.discard(conn)

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return {**self.stats, "open_connections": len(self._conns)}

    def close(self, timeout_s: float = 30.0):
        """Graceful: stop accepting, half-close every connection's read
        side (reader sees EOF), and join the writers — each drains its
        in-flight replies before the socket closes.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns)
        try:
            self._lsock.close()
        except OSError:
            pass
        self._acceptor.join(timeout=timeout_s)
        for conn in conns:
            conn.half_close(read=True)
        for conn in conns:
            conn.join(timeout_s)
            if conn.reader.is_alive() or conn.writer.is_alive():
                # the graceful drain didn't finish — e.g. the writer is
                # wedged sending to a client that pipelined big reads
                # and stopped draining (which also wedges the reader in
                # the bounded writeq).  Flag the abort: the writer's
                # send loop polls it (SEND_POLL_S), switches to
                # draining, and runs the teardown (session drop,
                # _forget); shutdown is a backstop for a reader still
                # blocked in recv.
                conn.aborted = True
                try:
                    conn.sock.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                conn.join(timeout_s)
