"""Thin framed client for the multi-tenant storage gateway.

Everything the client exchanges with the gateway is a codec frame
(bytes) pushed through a transport channel — the in-process
``GatewayChannel`` and the TCP ``SocketChannel`` implement the same
``request(frame) -> ReplyFuture`` contract, so the client works
unchanged over either (pass a ``StorageGateway``, a ``GatewayServer``,
a ready channel, or a ``host:port`` address).  Backpressure is a
first-class outcome: an over-budget tenant's request resolves to
:class:`RetryLater` (the gateway's admission control answering
``ST_RETRY``) rather than queueing without bound — callers either back
off themselves or use :meth:`GatewayClient.write_retrying`.

When the gateway enforces tenant auth, pass ``secret=`` (the tenant's
shared secret; a fresh signed token is minted for the open) or a
pre-minted ``token=``.
"""
from __future__ import annotations

import itertools
import json
import os
import time
from typing import Any, Dict, Optional

from repro.serve.auth import AuthError, mint_token
from repro.serve.storage_service import (OP_CLOSE, OP_DELETE, OP_HEALTH,
                                         OP_OPEN, OP_READ, OP_STAT,
                                         OP_STATS, OP_WRITE,
                                         ST_ERROR, ST_OK, ST_RETRY,
                                         decode_response, encode_request)


class RetryLater(RuntimeError):
    """Admission control pushed back: the tenant is over its in-flight
    or queued-byte budget.  Back off and resubmit."""


class GatewayError(RuntimeError):
    """A gateway-side failure that does not map to a builtin."""


_ERROR_TYPES = {
    "FileNotFoundError": FileNotFoundError,
    "IOError": IOError,
    "OSError": OSError,
    "TimeoutError": TimeoutError,
    "ValueError": ValueError,
    "KeyError": KeyError,
    "AuthError": AuthError,
    "PermissionError": PermissionError,
    "ConnectionError": ConnectionError,
}


def _raise_for(fields: Dict[str, Any]):
    exc = _ERROR_TYPES.get(fields["errtype"])
    if exc is not None:
        raise exc(fields["msg"])
    raise GatewayError(f"{fields['errtype']}: {fields['msg']}")


class PendingReply:
    """Handle for an in-flight gateway request; ``result()`` decodes the
    response frame and raises :class:`RetryLater` on backpressure or the
    mapped exception on gateway-side errors."""

    def __init__(self, future, op: int):
        self._future = future
        self._op = op

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = 120.0):
        status, op, _rid, fields = decode_response(
            self._future.result(timeout))
        if status == ST_RETRY:
            raise RetryLater(fields["reason"])
        if status == ST_ERROR:
            _raise_for(fields)
        assert status == ST_OK
        if op == OP_READ:
            return fields["data"]
        if op in (OP_STATS, OP_HEALTH):
            return json.loads(fields["data"].decode("utf-8"))
        return fields


class GatewayClient:
    """One client session against a storage gateway.

    ``target`` may be a :class:`~repro.serve.storage_service.
    StorageGateway` or :class:`~repro.serve.transport.GatewayServer`
    (anything with ``connect()``), an already-open channel (anything
    with ``request()``), or a TCP address (``"host:port"`` or
    ``(host, port)``) to dial.  The client owns its channel and closes
    it in :meth:`close`.

    ``tenant`` names the fair-share/admission bucket this session bills
    to; ``weight`` and ``qos`` ('interactive' | 'batch' | 'scrub') apply
    when this open creates the tenant (later sessions join it as-is).
    On an auth-enforcing gateway the open must carry a signed token:
    pass the tenant's shared ``secret`` (token minted here, expiring
    after ``token_ttl_s``) or a pre-minted ``token``.  ``submit_*``
    methods are asynchronous (returning :class:`PendingReply`); the
    plain verbs block on the reply.
    """

    def __init__(self, target, tenant: str, weight: float = 1.0,
                 qos: str = "interactive",
                 secret: Optional[bytes] = None,
                 token: Optional[bytes] = None,
                 token_ttl_s: float = 30.0):
        if hasattr(target, "connect"):
            self._channel = target.connect()
        elif hasattr(target, "request"):
            self._channel = target
        else:
            from repro.serve.transport import SocketChannel
            self._channel = SocketChannel(target)
        self._rid = itertools.count(1)
        # per-request trace ids: random 48-bit base + counter, so ids
        # from concurrent clients don't collide and are never 0
        # (0 = untraced on the wire)
        self._trace = itertools.count(
            (int.from_bytes(os.urandom(6), "big") << 16) | 1)
        self.tenant = tenant
        if token is None and secret is not None:
            token = mint_token(tenant, secret, ttl_s=token_ttl_s)
        try:
            resp = self._rpc(OP_OPEN, session=0, tenant=tenant,
                             weight=weight, qos=qos,
                             token=token or b"").result()
        except BaseException:
            self._close_channel()
            raise
        self._session = resp["session"]

    # -- framing -------------------------------------------------------
    def _rpc(self, op: int, session: Optional[int] = None,
             **fields: Any) -> PendingReply:
        if session is None:
            session = self._session
        if op in (OP_WRITE, OP_READ) and "trace" not in fields:
            fields["trace"] = next(self._trace) & 0xFFFFFFFFFFFFFFFF
        frame = encode_request(op, session, next(self._rid), **fields)
        return PendingReply(self._channel.request(frame), op)

    # -- async submission ----------------------------------------------
    def submit_write(self, path: str, data: bytes) -> PendingReply:
        return self._rpc(OP_WRITE, path=path, data=bytes(data))

    def submit_read(self, path: str, version: int = -1,
                    verify: bool = True) -> PendingReply:
        return self._rpc(OP_READ, path=path, version=version,
                         verify=verify)

    # -- blocking verbs ------------------------------------------------
    def write(self, path: str, data: bytes,
              timeout: Optional[float] = 120.0) -> Dict[str, int]:
        """Store ``data`` at ``path``; returns the gateway's write
        summary (total/new bytes, new/dup blocks).  Raises
        :class:`RetryLater` on admission backpressure."""
        return self.submit_write(path, data).result(timeout)

    def write_retrying(self, path: str, data: bytes,
                       timeout: float = 120.0,
                       backoff_s: float = 0.002) -> Dict[str, int]:
        """``write`` that absorbs :class:`RetryLater` with a small
        backoff until ``timeout`` — the well-behaved flooder.

        ``timeout`` is a total wall-clock deadline: each attempt is
        clamped to the time *remaining* (passing the full timeout per
        attempt used to let one retry overshoot the deadline by ~2x),
        and once the deadline is exhausted the loop raises
        :class:`RetryLater` instead of starting another attempt."""
        deadline = time.monotonic() + timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise RetryLater(
                    f"write_retrying deadline ({timeout}s) exhausted "
                    f"for {path}")
            try:
                return self.write(path, data, timeout=remaining)
            except RetryLater:
                if time.monotonic() + backoff_s >= deadline:
                    raise
                time.sleep(backoff_s)

    def read(self, path: str, version: int = -1, verify: bool = True,
             timeout: Optional[float] = 120.0) -> bytes:
        return self.submit_read(path, version, verify).result(timeout)

    def stat(self, path: str) -> Dict[str, int]:
        """{'versions', 'total_len', 'blocks'} for the latest version."""
        return self._rpc(OP_STAT, path=path).result()

    def stats(self) -> Dict[str, Any]:
        """Live gateway observability snapshot (the full
        ``snapshot_stats()`` tree: tenants, engine per-device
        histograms, WAL fsync percentiles, trace-ring counters) fetched
        over the wire via ``OP_STATS``.  Note JSON transit turns int
        dict keys (e.g. device indices) into strings."""
        return self._rpc(OP_STATS).result()

    def health(self) -> Dict[str, Any]:
        """The gateway's health report via ``OP_HEALTH``: overall
        ``status`` (``ok``/``warn``/``critical``) plus the rule
        verdicts — the same JSON the ``/health`` HTTP route serves."""
        return self._rpc(OP_HEALTH).result()

    def delete(self, path: str) -> int:
        """Delete every version of ``path``; returns orphaned digests."""
        return self._rpc(OP_DELETE, path=path).result()["orphans"]

    def _close_channel(self):
        close = getattr(self._channel, "close", None)
        if close is not None:
            close()

    def close(self):
        """Close the gateway session, then the transport channel (a
        no-op in-process; a graceful drain + disconnect over TCP)."""
        try:
            self._rpc(OP_CLOSE).result()
        finally:
            self._close_channel()
