"""Serving steps: batched prefill and single-token decode.

``decode_32k`` / ``long_500k`` lower the decode step (one new token
against a KV cache / SSM state of ``seq_len``); ``prefill_32k`` lowers the
prefill step.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    def prefill_step(params, tokens, embeds=None):
        cache, logits = model.prefill(params, tokens, embeds)
        next_tok = jnp.argmax(logits, axis=-1)
        return cache, logits, next_tok
    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, tokens, pos):
        cache, logits = model.decode_step(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1)
        return cache, logits, next_tok
    return decode_step


def greedy_generate(model, params, prompt_tokens, max_new: int,
                    capacity: Optional[int] = None):
    """Simple batched greedy decoding driver (used by examples/tests)."""
    B, S = prompt_tokens.shape
    capacity = capacity or model.capacity_for(S + max_new)
    cache, logits = model.prefill(params, prompt_tokens, capacity=capacity)
    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [tok]
    decode = jax.jit(model.decode_step)
    for i in range(max_new - 1):
        pos = jnp.asarray(S + i, jnp.int32)
        cache, logits = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
