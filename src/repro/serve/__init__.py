from repro.serve.servestep import make_prefill_step, make_decode_step  # noqa: F401
from repro.serve.auth import (AuthError, TokenAuthenticator,  # noqa: F401
                              mint_token)
from repro.serve.storage_service import (GatewayConfig,  # noqa: F401
                                         StorageGateway)
from repro.serve.storage_client import (GatewayClient,  # noqa: F401
                                        GatewayError, RetryLater)
from repro.serve.transport import (GatewayServer,  # noqa: F401
                                   SocketChannel)
