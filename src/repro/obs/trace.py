"""Per-request trace spans with a bounded completed-trace ring.

A ``Trace`` is minted when a request with a nonzero trace id (packed
into the request frame header by ``GatewayClient._rpc``) is admitted,
and spans are attached as the request crosses layers: transport
decode, WDRR queue wait, SAI chunk/hash/store, engine queue/launch
(per device, per lane), WAL group-commit fsync.  Span producers run on
different threads (scheduler, pipeline stages, manager threads), so
``add_span`` takes the per-trace lock.

Completed traces land in ``Tracer``'s bounded ring (``capacity``
newest survive); traces slower than ``slow_threshold_s`` additionally
have their full span tree serialized into the slow-request log ring,
which benchmarks dump to ``obs-slowlog.json`` for the CI artifact.

All timestamps are ``time.perf_counter()`` — monotonic, comparable
only within a process, which is all span nesting needs.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional


class Span:
    __slots__ = ("name", "t0", "t1", "meta")

    def __init__(self, name: str, t0: float, t1: float, meta: Optional[Dict] = None) -> None:
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.meta = meta or {}

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> Dict:
        d = {"name": self.name, "t0": self.t0, "t1": self.t1,
             "duration_s": self.t1 - self.t0}
        if self.meta:
            d["meta"] = dict(self.meta)
        return d


class Trace:
    __slots__ = ("trace_id", "name", "t0", "t1", "meta", "spans", "_lock")

    def __init__(self, trace_id: int, name: str, t0: Optional[float] = None,
                 **meta) -> None:
        self.trace_id = trace_id
        self.name = name
        self.t0 = time.perf_counter() if t0 is None else t0
        self.t1 = 0.0
        self.meta = dict(meta)
        self.spans: List[Span] = []
        self._lock = threading.Lock()

    def add_span(self, name: str, t0: float, t1: float, **meta) -> Span:
        span = Span(name, t0, t1, meta or None)
        with self._lock:
            self.spans.append(span)
        return span

    def finish(self, t1: Optional[float] = None) -> None:
        self.t1 = time.perf_counter() if t1 is None else t1

    @property
    def duration_s(self) -> float:
        return (self.t1 or time.perf_counter()) - self.t0

    def to_dict(self) -> Dict:
        with self._lock:
            spans = [s.to_dict() for s in self.spans]
        return {
            "trace_id": self.trace_id,
            "name": self.name,
            "t0": self.t0,
            "duration_s": self.duration_s,
            "meta": dict(self.meta),
            "spans": spans,
        }


class Tracer:
    """Bounded ring of completed traces + slow-request log."""

    def __init__(self, capacity: int = 256, slow_threshold_s: float = 1.0,
                 slow_capacity: int = 64) -> None:
        self.slow_threshold_s = float(slow_threshold_s)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=max(1, int(capacity)))
        self._slow: deque = deque(maxlen=max(1, int(slow_capacity)))
        self._finished = 0
        self._slow_count = 0

    def start(self, trace_id: int, name: str, t0: Optional[float] = None,
              **meta) -> Trace:
        return Trace(trace_id, name, t0=t0, **meta)

    def finish(self, trace: Trace, t1: Optional[float] = None) -> None:
        trace.finish(t1)
        slow = trace.duration_s >= self.slow_threshold_s
        with self._lock:
            self._ring.append(trace)
            self._finished += 1
            if slow:
                self._slow.append(trace.to_dict())
                self._slow_count += 1

    def completed(self) -> List[Trace]:
        with self._lock:
            return list(self._ring)

    def slow_entries(self) -> List[Dict]:
        with self._lock:
            return list(self._slow)

    def stats(self) -> Dict:
        with self._lock:
            return {
                "finished": self._finished,
                "in_ring": len(self._ring),
                "slow": self._slow_count,
                "slow_threshold_s": self.slow_threshold_s,
            }
