"""Thread-safe metric primitives backing every layer's ``self.stats``.

Three instrument kinds:

- ``Counter`` — monotonic (or settable) integer, atomic under its own
  lock.  ``CounterGroup`` exposes a set of counters through the old
  plain-dict interface (``stats["jobs"]``, ``dict(stats)``,
  ``{**stats}``) so ``snapshot_stats()`` signatures stay
  backward-compatible while mutation becomes race-free
  (``stats.inc("jobs")``).
- ``Gauge`` — point-in-time value, either set explicitly or computed
  from a callable at read time.
- ``Histogram`` — log-bucketed latency histogram with power-of-two
  nanosecond buckets: ``record()`` is O(1) (one ``bit_length`` + one
  array bump under the histogram lock), ``percentile(p)`` walks the 64
  cumulative buckets and returns the geometric bucket midpoint.  Good
  to ~±41% per bucket, which is what you want from p99 at nanosecond-
  to-minute dynamic range without per-sample storage.

A ``MetricsRegistry`` is the get-or-create namespace each subsystem
owns; ``registry.snapshot()`` renders everything JSON-safe.
"""

from __future__ import annotations

import math
import threading
from collections.abc import MutableMapping
from typing import Callable, Dict, Iterator, Optional, Tuple

_NBUCKETS = 64  # bucket i covers [2^(i-1), 2^i) nanoseconds; bucket 0 = sub-ns


class Counter:
    """Atomic integer counter."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, value: int) -> None:
        with self._lock:
            self._value = value

    def max_update(self, value: int) -> None:
        with self._lock:
            if value > self._value:
                self._value = value

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Point-in-time value; ``fn`` makes it computed at read time."""

    __slots__ = ("name", "_lock", "_value", "_fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0
        self._fn = fn

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    def get(self) -> float:
        if self._fn is not None:
            return self._fn()
        return self._value


class Histogram:
    """Log-bucketed latency histogram (seconds in, pow-2 ns buckets)."""

    __slots__ = ("name", "_lock", "_buckets", "_count", "_sum", "_max")

    def __init__(self, name: str = "") -> None:
        self.name = name
        self._lock = threading.Lock()
        self._buckets = [0] * _NBUCKETS
        self._count = 0
        self._sum = 0.0
        self._max = 0.0

    def record(self, seconds: float) -> None:
        ns = int(seconds * 1e9)
        idx = ns.bit_length() if ns > 0 else 0
        if idx >= _NBUCKETS:
            idx = _NBUCKETS - 1
        with self._lock:
            self._buckets[idx] += 1
            self._count += 1
            self._sum += seconds
            if seconds > self._max:
                self._max = seconds

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum_s(self) -> float:
        return self._sum

    def percentile(self, p: float) -> float:
        """Approximate p-th percentile in seconds (geometric bucket mid)."""
        with self._lock:
            n = self._count
            if n == 0:
                return 0.0
            rank = max(1, math.ceil(n * p / 100.0))
            cum = 0
            for i, c in enumerate(self._buckets):
                cum += c
                if cum >= rank:
                    if i == 0:
                        return 0.0
                    return (2.0 ** (i - 0.5)) / 1e9
            return self._max

    def summary(self) -> Dict[str, float]:
        with self._lock:
            count, total, peak = self._count, self._sum, self._max
        return {
            "count": count,
            "sum_s": total,
            "max_s": peak,
            "p50_s": self.percentile(50.0),
            "p95_s": self.percentile(95.0),
            "p99_s": self.percentile(99.0),
        }

    def buckets(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(self._buckets)


class CounterGroup(MutableMapping):
    """Plain-dict facade over a set of registry counters.

    Reads (`stats["k"]`, iteration, `dict(stats)`) behave exactly like
    the ad-hoc dicts they replace; writes go through atomic counters:
    ``inc(k, n)`` for the hot `+= 1` sites, ``stats[k] = v`` for the
    rare absolute sets (owner-lock callers), ``max_update`` for
    high-water marks.
    """

    def __init__(self, registry: "MetricsRegistry", keys=(), prefix: str = "") -> None:
        self._registry = registry
        self._prefix = prefix
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        for k in keys:
            self._counters[k] = registry.counter(prefix + k)

    def _ensure(self, key: str) -> Counter:
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.get(key)
                if c is None:
                    c = self._registry.counter(self._prefix + key)
                    self._counters[key] = c
        return c

    def inc(self, key: str, n: int = 1) -> None:
        self._ensure(key).inc(n)

    def max_update(self, key: str, value: int) -> None:
        self._ensure(key).max_update(value)

    def __getitem__(self, key: str) -> int:
        return self._counters[key].value

    def __setitem__(self, key: str, value: int) -> None:
        self._ensure(key).set(value)

    def __delitem__(self, key: str) -> None:
        with self._lock:
            del self._counters[key]

    def __iter__(self) -> Iterator[str]:
        return iter(list(self._counters))

    def __len__(self) -> int:
        return len(self._counters)

    def __eq__(self, other) -> bool:
        if isinstance(other, (dict, CounterGroup)):
            return dict(self) == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __repr__(self) -> str:
        return f"CounterGroup({dict(self)!r})"


class MetricsRegistry:
    """Get-or-create namespace of counters, gauges, and histograms."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str, fn: Optional[Callable[[], float]] = None) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, fn)
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(name)
            return h

    def group(self, keys=(), prefix: str = "") -> CounterGroup:
        return CounterGroup(self, keys, prefix)

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        return {
            "counters": {n: c.value for n, c in counters.items()},
            "gauges": {n: g.get() for n, g in gauges.items()},
            "histograms": {n: h.summary() for n, h in hists.items()},
        }
